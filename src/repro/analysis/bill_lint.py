"""Verb-bill conservation lint (analysis pass 2, DESIGN.md §11).

The paper's whole argument is a *bill* argument: CIDER wins because its
verb bill is smaller where it counts (MN NIC IOPS).  That argument breaks
silently the day someone adds an ``IOMetrics`` counter that the cost model
never prices or the docs never explain — the new verb "vanishes" from
``modeled_mops`` while still being claimed as metered.  This AST-based lint
makes that impossible:

* every ``IOMetrics`` field *written* in ``core/engine.py`` / ``stores/*``
  (keyword or positional constructor argument) must be
* *documented* — a row in the §1 table of ``docs/METRICS.md`` — and
* *consumed* by the cost model — read (directly or through the ``mn_iops``
  derived property) inside ``runner.modeled_throughput`` /
  ``runner.modeled_latency`` — **or** whitelisted in
  ``CONSUMED_WHITELIST`` with a stated reason (observable-only counters:
  rates, recovery diagnostics, client-NIC traffic that is free at the MN
  by design).

The whitelist is the honesty mechanism, not an escape hatch: each entry
says *why* the field is deliberately outside the priced bill, and the lint
fails if a whitelist entry goes stale (names a field that no longer
exists) so the list cannot rot.

Satellite enforcement: capability rejections in ``stores/*`` must raise
the shared typed ``UnsupportedOpError`` (``core/types.py``), never a bare
``NotImplementedError`` — callers distinguish "wrong index for this
workload" from an unimplemented code path.

All lint logic takes sources/markdown as *strings* (``lint_sources``), so
``tests/test_analysis.py`` injects violating fixtures without touching the
real tree; ``run()`` binds the real files.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path

from repro.analysis import Violation
from repro.core.types import IOMetrics

__all__ = ["CONSUMED_WHITELIST", "documented_fields", "written_fields",
           "consumed_fields", "derived_field_map", "bad_rejections",
           "lint_sources", "run"]

# IOMetrics fields that are deliberately NOT priced by modeled_throughput/
# modeled_latency.  Every entry needs a reason; the lint fails on a stale
# entry (field gone) and on any written field that is neither consumed nor
# listed here.  docs/METRICS.md §1 marks these as observable-only.
CONSUMED_WHITELIST: dict[str, str] = {
    "cn_msgs": "client<->client messages ride CN NICs, free at the MN by "
               "design (ShiftLock's point, §2.3); CN hops are priced in "
               "modeled_latency via the per-mode chain terms, not the bill",
    "retries": "waste diagnostic (paper Fig 1); every failed CAS is also "
               "folded into `cas`, so mn_iops already prices it",
    "combined": "WC-rate numerator (Fig 21) — a rate observable; the "
                "surviving writes are priced through `writes`",
    "executed": "post-combining write count (WC-rate denominator's "
                "complement); priced through `writes`",
    "repair_cas": "recovery-bill observable gated by BENCH_recovery; each "
                  "repair verb is also folded into `reads`/`cas`, so "
                  "mn_iops prices it",
    "orphan_windows": "time-to-repair observable (slot-windows, not "
                      "verbs); feeds `windows_to_repair`, no NIC cost",
}

_MD_ROW = re.compile(r"^\|\s*`(\w+)`\s*\|")


def iometrics_fields() -> set[str]:
    return {f.name for f in dataclasses.fields(IOMetrics)}


def documented_fields(metrics_md: str) -> set[str]:
    """Field rows of the §1 IOMetrics table in docs/METRICS.md."""
    section = metrics_md.split("## 1.", 1)
    body = section[1].split("\n## ", 1)[0] if len(section) > 1 else ""
    return {m.group(1) for line in body.splitlines()
            if (m := _MD_ROW.match(line.strip()))}


def _ctor_fields(call: ast.Call, field_order: list[str]) -> set[str]:
    out = {kw.arg for kw in call.keywords if kw.arg}
    for i, arg in enumerate(call.args):
        if i < len(field_order) and not isinstance(arg, ast.Starred):
            out.add(field_order[i])
    return out


def written_fields(source: str) -> set[str]:
    """Fields assigned by any ``IOMetrics(...)`` constructor call in
    ``source`` (keyword or positional), plus fields replaced via
    ``dataclasses.replace(<io>, field=...)`` on an IOMetrics value."""
    order = [f.name for f in dataclasses.fields(IOMetrics)]
    out: set[str] = set()
    for node in ast.walk(ast.parse(source)):
        if not isinstance(node, ast.Call):
            continue
        fn = node.func
        name = fn.id if isinstance(fn, ast.Name) else (
            fn.attr if isinstance(fn, ast.Attribute) else "")
        if name == "IOMetrics":
            out |= _ctor_fields(node, order)
    return out


def derived_field_map(types_source: str) -> dict[str, set[str]]:
    """Map each ``IOMetrics`` property (derived metric) to the concrete
    fields its body reads — e.g. ``mn_iops -> {reads, writes, cas, faa}`` —
    so consumption through a derived metric credits its inputs."""
    tree = ast.parse(types_source)
    fields = iometrics_fields()
    out: dict[str, set[str]] = {}
    for node in ast.walk(tree):
        if not isinstance(node, ast.ClassDef) or node.name != "IOMetrics":
            continue
        for item in node.body:
            if not isinstance(item, ast.FunctionDef):
                continue
            is_prop = any(isinstance(d, ast.Name) and d.id == "property"
                          for d in item.decorator_list)
            if not is_prop:
                continue
            reads = {n.attr for n in ast.walk(item)
                     if isinstance(n, ast.Attribute) and n.attr in fields}
            out[item.name] = reads
    return out


def consumed_fields(runner_source: str, fn_names: tuple[str, ...] = (
        "modeled_throughput", "modeled_latency"),
        derived: dict[str, set[str]] | None = None) -> set[str]:
    """Fields the cost model reads: attribute accesses inside ``fn_names``
    on parameters *annotated* ``IOMetrics`` (so a same-named ``Results``
    field cannot masquerade as bill consumption); derived properties
    expand to the fields they read."""
    fields = iometrics_fields()
    derived = derived if derived is not None else {}
    names = fields | set(derived)
    tree = ast.parse(runner_source)
    direct: set[str] = set()
    for node in ast.walk(tree):
        if not (isinstance(node, ast.FunctionDef) and node.name in fn_names):
            continue
        io_params = {a.arg for a in (node.args.args + node.args.kwonlyargs)
                     if a.annotation is not None
                     and "IOMetrics" in ast.unparse(a.annotation)}
        for n in ast.walk(node):
            if (isinstance(n, ast.Attribute) and n.attr in names
                    and isinstance(n.value, ast.Name)
                    and n.value.id in io_params):
                direct.add(n.attr)
    out = set()
    for name in direct:
        out |= derived.get(name, {name} & fields)
        if name in fields:
            out.add(name)
    return out


def bad_rejections(source: str, path: str) -> list[tuple[str, int]]:
    """``raise NotImplementedError`` sites — capability rejections must use
    the shared typed ``UnsupportedOpError`` instead."""
    out = []
    for node in ast.walk(ast.parse(source)):
        if not isinstance(node, ast.Raise) or node.exc is None:
            continue
        exc = node.exc
        name = ""
        if isinstance(exc, ast.Call) and isinstance(exc.func, ast.Name):
            name = exc.func.id
        elif isinstance(exc, ast.Name):
            name = exc.id
        if name == "NotImplementedError":
            out.append((path, exc.lineno))
    return out


def lint_sources(writer_sources: dict[str, str], metrics_md: str,
                 runner_source: str, types_source: str,
                 store_sources: dict[str, str] | None = None,
                 whitelist: dict[str, str] | None = None) -> list[Violation]:
    """The conservation lint over in-memory sources (fixture-injectable).

    ``writer_sources``: path -> source for every file allowed to construct
    ``IOMetrics``; ``store_sources``: the subset additionally subject to
    the UnsupportedOpError rule (defaults to paths containing ``stores/``).
    """
    wl = CONSUMED_WHITELIST if whitelist is None else whitelist
    fields = iometrics_fields()
    documented = documented_fields(metrics_md)
    derived = derived_field_map(types_source)
    consumed = consumed_fields(runner_source, derived=derived)
    out = []

    for name in sorted(set(wl) - fields):
        out.append(Violation(
            "bill_lint", "CONSUMED_WHITELIST",
            f"stale whitelist entry '{name}': no such IOMetrics field — "
            f"remove it so the list cannot rot"))

    for path, src in sorted(writer_sources.items()):
        written = written_fields(src)
        for name in sorted(written - fields):
            out.append(Violation(
                "bill_lint", path,
                f"IOMetrics(...) constructed with unknown field '{name}'"))
        written &= fields
        for name in sorted(written - documented):
            out.append(Violation(
                "bill_lint", path,
                f"IOMetrics field '{name}' is written here but has no row "
                f"in docs/METRICS.md §1 — every metered verb counter must "
                f"be documented"))
        for name in sorted(written - consumed - set(wl)):
            out.append(Violation(
                "bill_lint", path,
                f"IOMetrics field '{name}' is written here but never "
                f"consumed by modeled_throughput/modeled_latency and not "
                f"whitelisted — the verb would vanish from the cost model"))

    if store_sources is None:
        store_sources = {p: s for p, s in writer_sources.items()
                        if "stores/" in p.replace("\\", "/")}
    for path, src in sorted(store_sources.items()):
        for where, line in bad_rejections(src, path):
            out.append(Violation(
                "bill_lint", f"{where}:{line}",
                "capability rejection raises bare NotImplementedError — "
                "stores must raise the shared typed UnsupportedOpError "
                "(core/types.py)"))
    return out


def run(notes: list[str] | None = None,
        repo_root: Path | None = None) -> list[Violation]:
    """The lint over the real tree: engine + every store vs docs + runner."""
    root = repo_root or Path(__file__).resolve().parents[3]
    src = root / "src" / "repro"
    writers = {"src/repro/core/engine.py":
               (src / "core" / "engine.py").read_text()}
    stores = {}
    for p in sorted((src / "stores").glob("*.py")):
        rel = f"src/repro/stores/{p.name}"
        stores[rel] = p.read_text()
        writers[rel] = stores[rel]
    out = lint_sources(
        writers,
        metrics_md=(root / "docs" / "METRICS.md").read_text(),
        runner_source=(src / "core" / "runner.py").read_text(),
        types_source=(src / "core" / "types.py").read_text(),
        store_sources=stores)
    if notes is not None:
        notes.append(f"bill_lint: {len(writers)} writer files, "
                     f"{len(iometrics_fields())} IOMetrics fields, "
                     f"{len(CONSUMED_WHITELIST)} whitelisted")
    return out
