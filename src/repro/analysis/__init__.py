"""Static-analysis layer: prove the repro's invariants instead of sampling them.

Every number this reproduction publishes rests on invariants that example
tests can only sample — that the fused scans really donate their buffers,
that the shard_map path issues exactly the documented collectives, that
every ``IOMetrics`` increment is documented and priced into the cost model,
and that the per-mode lock protocols (including the §4.6 orphan repair) are
race-free.  This package checks each of those properties over the *whole*
artifact — jaxpr/HLO graph, source AST, or exhaustive interleaving space —
and ``tools/analyze.py`` gates CI on the result (``make analyze``).

Three passes (DESIGN.md §11):

* ``jaxpr_check`` — traces the engine/runner/dist entry points and audits
  the closed jaxpr + compiled HLO: buffer donation, dtype discipline (no
  f64 / weak-typed outputs), no host callbacks, the exact credit-plane
  collective contract, and jit-cache stability across the dispatch seam.
* ``bill_lint`` — AST conservation lint: every ``IOMetrics`` field written
  by the engine/stores is documented in docs/METRICS.md and consumed by the
  cost model (or explicitly whitelisted with a reason), and unsupported-op
  rejections raise the shared ``UnsupportedOpError``.
* ``race_check`` — an explicit-state model checker that exhaustively
  enumerates interleavings (≤3 CNs × ≤2 keys, all OpKinds, crash at any
  step) of the per-mode protocol machines and asserts mutual exclusion,
  oracle-consistent serialization, and that §4.6 repair never breaks a
  live lock.

``analysis_provenance()`` is recorded into every ``BENCH_*.json`` config
block (via ``benchmarks/provenance.py``) so committed baselines state which
invariants they were generated under.
"""
from __future__ import annotations

import dataclasses

__all__ = ["ANALYSIS_VERSION", "PASSES", "Violation", "analysis_provenance"]

# Bump when a pass's invariants change meaningfully — committed BENCH_*.json
# config blocks record this so baselines state what was proven about the
# code that generated them.
ANALYSIS_VERSION = "1.0"

PASSES = ("jaxpr_check", "bill_lint", "race_check")


@dataclasses.dataclass(frozen=True)
class Violation:
    """One analyzer finding.  ``passes == not violations`` everywhere."""
    pass_name: str   # which pass found it (one of PASSES)
    target: str      # what was audited (function, file, scenario)
    message: str     # what is wrong, in one sentence

    def __str__(self) -> str:
        return f"[{self.pass_name}] {self.target}: {self.message}"


def analysis_provenance() -> dict:
    """The pass list + version stamped into BENCH_*.json config blocks."""
    return {"version": ANALYSIS_VERSION, "passes": list(PASSES)}
