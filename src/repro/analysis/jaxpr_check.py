"""Jaxpr/HLO invariant auditor (analysis pass 1, DESIGN.md §11).

Traces the three entry points every benchmark number flows through —
``engine.apply_batch``, ``runner.run_windows`` (+ traced), and
``dist.store.run_windows_sharded`` (+ ``apply_batch_sharded``) — for all
four ``SyncMode``s and both kernel backends, then audits the closed jaxpr
and the compiled HLO:

* **dtype discipline** — the engine graph is integer/bool arithmetic with
  one documented f32 island (SPIN's truncated-exponential backoff): any
  f64/f16/bf16/complex value, or a weak-typed *output* (a promotion hazard
  for every downstream consumer), is a violation.
* **no host callbacks** — a ``pure_callback``/``io_callback`` inside the
  fused scan would serialize every window through the host and invalidate
  the wall-clock floors.
* **buffer donation** — the store/credit carries of the fused scans are
  declared donated (``donate_argnums``); this pass proves donation *took
  effect* by counting ``input_output_alias`` pairs in the compiled module
  (one per Store/Credit leaf) and by treating any "donated buffer was not
  usable" compile warning as a violation.  A silent copy here doubles
  steady-state memory and breaks the ROADMAP's multi-million-key sizing.
* **collective contract** — the sharded path's credit plane is replicated,
  so the ONLY cross-shard traffic is the final result/bill assembly: one
  ``psum`` per ``Results`` field + one per ``IOMetrics`` field (counts
  derived from the dataclasses, so adding a field updates the contract),
  nothing inside the window scan body, and nothing but ``all-reduce`` in
  the optimized HLO (audited via ``rooflines.hlo_parser``).
* **jit-cache stability** — configs that must share a compile cache
  (``kernel_backend="auto"`` vs its resolved backend; same-shape streams
  with different contents) must produce byte-identical jaxprs; a hash
  mismatch means a silent recompile per window/stream.

Pure functions (``audit_graph``/``collective_census``/``donation_pairs``/
``jaxpr_digest``) are exported for the injected-violation fixtures in
``tests/test_analysis.py``; ``run()`` applies them to the real codebase.
"""
from __future__ import annotations

import dataclasses
import hashlib
import warnings
from collections import Counter

import numpy as np

import jax
import jax.numpy as jnp

from repro.analysis import Violation
from repro.core import engine, runner
from repro.core.combine import resolve_backend
from repro.core.credits import CreditState, credit_init
from repro.core.engine import Results, StoreState
from repro.core.types import EngineConfig, IOMetrics, OpBatch, SyncMode

try:  # jax >= 0.5 exposes the jaxpr types publicly
    from jax.extend import core as jcore  # type: ignore
except ImportError:  # jax 0.4.x: only the private module has them
    from jax._src import core as jcore

__all__ = [
    "ALLOWED_DTYPES", "FORBIDDEN_DTYPES", "CALLBACK_PRIMS", "COMM_PRIMS",
    "audit_graph", "collective_census", "donation_pairs", "jaxpr_digest",
    "expected_donation_pairs", "expected_psums", "run",
]

# The engine is int32/bool arithmetic end to end (exact verb counting needs
# no floats); SPIN's truncated-exponential backoff is the one documented f32
# island and CIDER's combine kernels stage uint32 sort keys.  Everything
# else — and especially f64, which would silently double mn_bytes-adjacent
# buffer traffic and break bit-equality across backends — is a violation.
ALLOWED_DTYPES = frozenset({"bool", "int32", "uint32", "float32"})
FORBIDDEN_DTYPES = frozenset({
    "float64", "int64", "uint64", "float16", "bfloat16",
    "complex64", "complex128",
})
# Host-callback primitives: any of these inside the engine graph serializes
# the fused scan through Python once per window.
CALLBACK_PRIMS = frozenset({
    "pure_callback", "io_callback", "debug_callback", "callback",
})
# Cross-device communication primitives (jaxpr level).  ``axis_index`` is
# deliberately separate: it reads the mesh coordinate without traffic.
COMM_PRIMS = frozenset({
    "psum", "pmin", "pmax", "all_gather", "all_to_all", "ppermute",
    "psum_scatter", "pbroadcast", "reduce_scatter",
    # shard_map's check_rep rewrite renames psum to psum2 — same verb on
    # the wire; the census normalizes it back to "psum"
    "psum2",
})
_PRIM_ALIASES = {"psum2": "psum"}
# Primitives whose bodies execute once per carried iteration: a collective
# inside one would turn the per-stream assembly psum into per-window traffic.
_LOOP_PRIMS = frozenset({"scan", "while"})


def expected_psums() -> int:
    """The credit-plane collective contract, derived from the dataclasses:
    one result-assembly psum per ``Results`` field plus one bill psum per
    ``IOMetrics`` field (``dist.store._psum_results`` + the io tree-map)."""
    return len(dataclasses.fields(Results)) + len(dataclasses.fields(IOMetrics))


def expected_donation_pairs() -> int:
    """One ``input_output_alias`` pair per donated carry leaf: the whole
    ``StoreState`` + ``CreditState`` (both fused scans donate exactly
    these two trees)."""
    return (len(dataclasses.fields(StoreState))
            + len(dataclasses.fields(CreditState)))


def _as_jaxpr(obj):
    """Normalize make_jaxpr output / raw jaxprs to an open ``Jaxpr``."""
    if isinstance(obj, jcore.ClosedJaxpr):
        return obj.jaxpr
    return obj


def _sub_jaxprs(eqn):
    """All jaxprs nested in an eqn's params (scan/while/cond/pjit/pallas)."""
    subs = []
    for v in eqn.params.values():
        items = v if isinstance(v, (tuple, list)) else (v,)
        for it in items:
            if isinstance(it, (jcore.ClosedJaxpr, jcore.Jaxpr)):
                subs.append(_as_jaxpr(it))
    return subs


def iter_eqns(closed, in_loop: bool = False):
    """Yield ``(eqn, in_loop)`` over a jaxpr and everything nested in it;
    ``in_loop`` is True inside any scan/while body (i.e. code that runs
    once per carried iteration)."""
    stack = [(_as_jaxpr(closed), in_loop)]
    while stack:
        jaxpr, loop = stack.pop()
        for eqn in jaxpr.eqns:
            yield eqn, loop
            sub_loop = loop or eqn.primitive.name in _LOOP_PRIMS
            for sub in _sub_jaxprs(eqn):
                stack.append((sub, sub_loop))


def _avals_of(eqn):
    for v in list(eqn.outvars) + list(eqn.invars):
        aval = getattr(v, "aval", None)
        if aval is not None and hasattr(aval, "dtype"):
            yield aval


def audit_graph(closed, target: str,
                allowed=ALLOWED_DTYPES) -> list[Violation]:
    """Dtype / weak-type / callback audit of one closed jaxpr.

    Flags (a) any value whose dtype is outside ``allowed`` (f64 promotion,
    x64 leaks, half-precision surprises), (b) weak-typed *outputs* — inner
    weak scalars are fine, but a weak output propagates promotion hazards
    to every consumer — and (c) host-callback primitives.
    """
    out = []
    bad_dtypes: set[str] = set()
    callbacks: set[str] = set()
    for eqn, _ in iter_eqns(closed):
        name = eqn.primitive.name
        if name in CALLBACK_PRIMS:
            callbacks.add(name)
        for aval in _avals_of(eqn):
            d = str(aval.dtype)
            if d not in allowed:
                bad_dtypes.add(d)
    for d in sorted(bad_dtypes):
        kind = "forbidden" if d in FORBIDDEN_DTYPES else "undeclared"
        out.append(Violation("jaxpr_check", target,
                             f"{kind} dtype {d} in engine graph "
                             f"(allowed: {sorted(allowed)})"))
    for name in sorted(callbacks):
        out.append(Violation("jaxpr_check", target,
                             f"host callback primitive '{name}' in engine "
                             f"graph — serializes the fused scan through "
                             f"the host"))
    avals = getattr(closed, "out_avals", None) or []
    weak = sorted({str(a.dtype) for a in avals
                   if getattr(a, "weak_type", False)})
    if weak:
        out.append(Violation("jaxpr_check", target,
                             f"weak-typed output(s) of dtype {weak} — "
                             f"promotion hazard for every consumer"))
    return out


def collective_census(closed, in_loop_only: bool = False) -> dict[str, int]:
    """Count communication primitives (plus ``axis_index``) in a jaxpr.
    ``in_loop_only=True`` restricts to scan/while bodies — code that would
    pay the collective once per window."""
    census: Counter[str] = Counter()
    for eqn, loop in iter_eqns(closed):
        if in_loop_only and not loop:
            continue
        name = eqn.primitive.name
        if name in COMM_PRIMS or name == "axis_index":
            census[_PRIM_ALIASES.get(name, name)] += 1
    return dict(census)


def donation_pairs(hlo_text: str) -> int:
    """Number of input/output buffer aliases the compiled module declares.

    Donation that *took effect* shows up in the optimized module header as
    ``input_output_alias={ {0}: (0, {}, may-alias), ... }`` — one pair per
    successfully-donated leaf.  A donated-but-copied buffer is absent here,
    which is exactly the silent failure this check exists to catch.
    """
    header = hlo_text.split("\n", 1)[0]
    if "input_output_alias" not in header:
        # some jax versions put the alias map on its own frontend_attributes
        # line; fall back to scanning the whole text's first occurrence
        idx = hlo_text.find("input_output_alias")
        if idx < 0:
            return 0
        header = hlo_text[idx:hlo_text.find("}}", idx) + 2]
    import re
    return len(re.findall(r"\(\d+,\s*\{", header))


def jaxpr_digest(closed) -> str:
    """Stable digest of a traced graph: equal digests <=> the two traces
    share a jit cache entry's program (same eqns, shapes, consts)."""
    return hashlib.sha256(str(closed).encode()).hexdigest()


# ---------------------------------------------------------------------------
# Real-codebase audit
# ---------------------------------------------------------------------------

_MODES = (SyncMode.OSYNC, SyncMode.SPIN, SyncMode.MCS, SyncMode.CIDER)


def _cfg(mode: SyncMode, backend: str = "auto",
         scan_max: int = 0) -> EngineConfig:
    return EngineConfig(n_slots=64, heap_slots=128, mode=mode,
                        kernel_backend=backend, scan_max=scan_max)


def _batch(scan_max: int = 0, seed: int = 0, b: int = 16,
           n_cns: int = 4) -> OpBatch:
    """A small deterministic batch covering every OpKind with key contention
    (collisions on 8 slots) so the queue/combine paths are in the graph."""
    rng = np.random.default_rng(seed)
    kinds = np.array([0, 1, 2, 2, 3, 2, 0, 4] * (b // 8), np.int32)
    if scan_max:
        kinds[5::8] = 5  # SCAN lanes only when the probe pass is compiled in
    keys = (rng.integers(0, 8, size=b) * 2).astype(np.int32)
    values = rng.integers(0, 100, size=b).astype(np.int32)
    return OpBatch.make(kinds, keys, np.where(kinds == 5, 3, values),
                        n_cns=n_cns)


def _engine_args(cfg: EngineConfig, seed: int = 0, n_cns: int = 4):
    batch = _batch(cfg.scan_max, seed=seed, n_cns=n_cns)
    state = engine.store_init(cfg)
    state = engine.populate(cfg, state, np.arange(0, 16, 2, np.int32),
                            np.arange(8, dtype=np.int32))
    credits = credit_init(cfg.n_slots)
    alive = np.ones((n_cns,), bool)
    alive[-1] = False  # a dead CN keeps the §4.6 repair path in the graph
    died = np.zeros((n_cns,), bool)
    died[-1] = True
    valid = batch.kinds != 4
    return state, credits, batch, valid, jnp.asarray(alive), jnp.asarray(died)


def _trace_apply_batch(cfg: EngineConfig, seed: int = 0):
    state, credits, batch, valid, alive, died = _engine_args(cfg, seed)
    fn = lambda st, cr, b, v, a, d: engine.apply_batch(  # noqa: E731
        cfg, st, cr, b, valid=v, alive=a, died=d)
    return jax.make_jaxpr(fn)(state, credits, batch, valid, alive, died)


def _check_engine_graphs() -> list[Violation]:
    """Dtype/callback/collective audit of ``engine.apply_batch`` for every
    SyncMode x kernel backend x {point-only, SCAN-enabled} engine."""
    out = []
    for mode in _MODES:
        for backend in ("jnp", "pallas"):
            for scan_max in (0, 2):
                cfg = _cfg(mode, backend, scan_max)
                tgt = (f"engine.apply_batch[mode={mode.name},"
                       f"backend={backend},scan_max={scan_max}]")
                closed = _trace_apply_batch(cfg)
                out += audit_graph(closed, tgt)
                census = collective_census(closed)
                if census:
                    out.append(Violation(
                        "jaxpr_check", tgt,
                        f"single-device engine graph contains collectives "
                        f"{census} — cross-device traffic belongs only in "
                        f"dist.store"))
                prims = {e.primitive.name for e, _ in iter_eqns(closed)}
                wants_pallas = resolve_backend(backend)[0] == "pallas"
                if wants_pallas and "pallas_call" not in prims:
                    out.append(Violation(
                        "jaxpr_check", tgt,
                        "kernel_backend resolves to pallas but the graph "
                        "has no pallas_call — the dispatch seam is dead"))
                if not wants_pallas and "pallas_call" in prims:
                    out.append(Violation(
                        "jaxpr_check", tgt,
                        "kernel_backend resolves to jnp but the graph "
                        "contains pallas_call"))
    return out


def _stream(cfg: EngineConfig, w: int = 3, seed: int = 0):
    b, n_cns = 16, 4
    rng = np.random.default_rng(seed)
    kinds = np.stack([np.asarray(_batch(cfg.scan_max, seed=seed + i).kinds)
                      for i in range(w)])
    keys = rng.integers(0, 16, size=(w, b)).astype(np.int32)
    values = rng.integers(0, 100, size=(w, b)).astype(np.int32)
    alive = np.ones((w, n_cns), bool)
    alive[-1, -1] = False  # one CN dies at the last window
    return runner.make_stream(kinds, keys, np.where(kinds == 5, 2, values),
                              n_cns=n_cns, alive=alive)


def _compile_capture(lower_fn):
    """Lower + compile, capturing jax's donation warnings: a 'donated buffer
    was not usable' warning means the alias silently degraded to a copy."""
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        compiled = lower_fn().compile()
    donation_warns = [str(c.message) for c in caught
                      if "donat" in str(c.message).lower()]
    return compiled, donation_warns


def _check_runner() -> list[Violation]:
    """Donation + dtype + cache-stability audit of the fused window scan."""
    out = []
    want = expected_donation_pairs()
    for mode in _MODES:
        cfg = _cfg(mode)
        stream = _stream(cfg)
        state = engine.store_init(cfg)
        credits = credit_init(cfg.n_slots)
        prev = np.ones((4,), bool)
        tgt = f"runner.run_windows[mode={mode.name}]"
        for io_pw, traced in ((False, False), (True, True)):
            label = tgt if not traced else tgt + ".traced"
            compiled, warns = _compile_capture(
                lambda: runner._scan_windows.lower(
                    cfg, state, credits, stream, jnp.asarray(prev),
                    io_pw, traced))
            got = donation_pairs(compiled.as_text())
            if got < want:
                out.append(Violation(
                    "jaxpr_check", label,
                    f"only {got}/{want} donated carry leaves aliased in the "
                    f"compiled module — the scan is silently copying "
                    f"store/credit buffers"))
            for w in warns:
                out.append(Violation("jaxpr_check", label,
                                     f"donation degraded to a copy: {w}"))
            if "f64[" in compiled.as_text():
                out.append(Violation("jaxpr_check", label,
                                     "f64 buffer in compiled HLO"))
        closed = jax.make_jaxpr(
            lambda st, cr: runner.run_windows(cfg, st, cr, stream))(
                state, credits)
        out += audit_graph(closed, tgt)
        census = collective_census(closed)
        if census:
            out.append(Violation(
                "jaxpr_check", tgt,
                f"single-device runner graph contains collectives {census}"))
    return out


def _check_cache_stability() -> list[Violation]:
    """Traces that must share a jit cache entry must hash identically:
    (a) ``kernel_backend='auto'`` vs its resolved concrete backend — the
    dispatch seam promises 'auto' adds no recompiles; (b) same-shape
    streams with different contents — contents must never leak into the
    traced program (a leak = one recompile per window batch)."""
    out = []
    resolved = resolve_backend("auto")[0]
    for mode in (SyncMode.CIDER, SyncMode.OSYNC):
        d_auto = jaxpr_digest(_trace_apply_batch(_cfg(mode, "auto")))
        d_conc = jaxpr_digest(_trace_apply_batch(_cfg(mode, resolved)))
        tgt = f"engine.apply_batch[mode={mode.name}]"
        if d_auto != d_conc:
            out.append(Violation(
                "jaxpr_check", tgt,
                f"kernel_backend='auto' traces a different program than its "
                f"resolved backend '{resolved}' — the seam costs a recompile"))
        d_a = jaxpr_digest(_trace_apply_batch(_cfg(mode), seed=1))
        d_b = jaxpr_digest(_trace_apply_batch(_cfg(mode), seed=2))
        if d_a != d_b:
            out.append(Violation(
                "jaxpr_check", tgt,
                "same-shape batches with different contents trace different "
                "programs — batch contents leaked into the compile cache key"))
    return out


def _check_sharded(notes: list[str]) -> list[Violation]:
    """Donation + exact collective contract on the shard_map path."""
    from jax.sharding import Mesh

    from repro.dist import store as dstore

    n_dev = len(jax.devices())
    if n_dev < 2:
        notes.append(
            "sharded-path audit SKIPPED: single device (run via tools/"
            "analyze.py, which forces a multi-device host platform)")
        return []
    n_shards = 4 if n_dev >= 4 else 2
    mesh = Mesh(np.array(jax.devices()[:n_shards]), ("data",))
    out = []
    want_psum = expected_psums()
    want_alias = expected_donation_pairs()
    for mode in _MODES:
        cfg = _cfg(mode)
        stream = _stream(cfg)
        state = dstore.sharded_store_init(cfg, n_shards)
        credits = credit_init(cfg.n_slots)
        prev = jnp.ones((4,), bool)
        for traced in (False, True):
            tgt = (f"dist.run_windows_sharded[mode={mode.name}"
                   + (",traced]" if traced else "]"))
            fn = dstore._sharded_stream_fn(cfg, mesh, "data", traced, traced)
            closed = jax.make_jaxpr(fn)(state, credits, stream, prev)
            out += audit_graph(closed, tgt)
            census = collective_census(closed)
            expect = {"axis_index": 1, "psum": want_psum}
            if census != expect:
                out.append(Violation(
                    "jaxpr_check", tgt,
                    f"collective census {census} != documented credit-plane "
                    f"contract {expect} (one psum per Results field + one "
                    f"per IOMetrics field, axis_index once)"))
            in_scan = collective_census(closed, in_loop_only=True)
            if in_scan:
                out.append(Violation(
                    "jaxpr_check", tgt,
                    f"collectives {in_scan} inside the window scan body — "
                    f"the contract pays collectives once per stream, not "
                    f"per window"))
            compiled, warns = _compile_capture(
                lambda: fn.lower(state, credits, stream, prev))
            text = compiled.as_text()
            got = donation_pairs(text)
            if got < want_alias:
                out.append(Violation(
                    "jaxpr_check", tgt,
                    f"only {got}/{want_alias} donated carry leaves aliased "
                    f"in the compiled sharded module"))
            for w in warns:
                out.append(Violation("jaxpr_check", tgt,
                                     f"donation degraded to a copy: {w}"))
            from repro.rooflines.hlo_parser import parse_hlo
            kinds = set(parse_hlo(text).coll_by_kind)
            if not kinds <= {"all-reduce"}:
                out.append(Violation(
                    "jaxpr_check", tgt,
                    f"compiled HLO contains collective kinds {sorted(kinds)} "
                    f"— the contract allows only all-reduce (psum)"))
        # single-window variant shares the same contract
        tgt = f"dist.apply_batch_sharded[mode={mode.name}]"
        state2 = dstore.sharded_store_init(cfg, n_shards)
        batch = _batch()
        valid = batch.kinds != 4
        fn1 = dstore._sharded_fn(cfg, mesh, "data")
        closed = jax.make_jaxpr(fn1)(state2, credit_init(cfg.n_slots), batch, valid)
        census = collective_census(closed)
        expect = {"axis_index": 1, "psum": want_psum}
        if census != expect:
            out.append(Violation(
                "jaxpr_check", tgt,
                f"collective census {census} != contract {expect}"))
        out += audit_graph(closed, tgt)
    return out


def run(notes: list[str] | None = None) -> list[Violation]:
    """Audit the real codebase; returns all violations (empty == pass)."""
    notes = notes if notes is not None else []
    out = []
    out += _check_engine_graphs()
    out += _check_runner()
    out += _check_cache_stability()
    out += _check_sharded(notes)
    return out
