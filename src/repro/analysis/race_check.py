"""Exhaustive protocol race-checker (analysis pass 3, DESIGN.md §11).

An explicit-state model checker for the per-mode synchronization machines
of ``core/protocol.py`` / ``core/sim.py``: a small, faithful model (2 keys,
2-3 clients, one op each, at most one crash injected at *any* step) is
explored over **every** interleaving, and every reachable state is checked
against the invariants the paper's argument rests on:

* **mutual exclusion** — at most one live client inside a key's critical
  section (SPIN lock word, MCS/CIDER ticket) at any reachable state;
* **no lost updates / lost deletes** — every completed live client has
  committed exactly one event, and replaying the committed events in commit
  order through ``core/oracle.OracleStore`` reproduces every per-op
  ``ok``/value/row-count *and* the final store;
* **wait-queue rank order** — per key, ticketed (pessimistic) ops commit in
  strictly increasing ticket order, i.e. queue order is serialization
  order (the serialization contract of DESIGN.md §2.2);
* **liveness** — no live client is stuck once no real step remains;
* **§4.6 orphan repair never breaks a live lock** — every recorded repair
  names a crashed owner.

The model abstracts time (no backoff/lease counters — any enabled step may
fire next, which only *adds* interleavings), folds CIDER's combined write
into one atomic action (faithful: the combined result is installed by a
single pointer CAS) and replaces Algorithm 1's credit dynamics with a
per-key ``hot`` flag choosing the optimistic vs pessimistic UPDATE path
(both settings are explored).  INSERT is always the optimistic slot-claim
CAS (§4.2.2); SEARCH/SCAN are lock-free atomic reads.

``ModelFlags`` re-introduces three seeded bugs so ``tests/test_analysis.py``
can prove the checker *detects* what it claims to:

* ``combine_covers_deletes=True`` — the lost-delete race this checker
  originally surfaced in ``protocol.py`` (a queued DELETE covered by a
  coordinator's combined batch completes without its own MCAS; fixed by
  the ``del_q`` coordinator gate);
* ``repair_requires_dead_holder=False`` — §4.6 repair that may break a
  live lock (mutual-exclusion and skipped-waiter violations follow);
* ``stale_replica_read=True`` — a replicated read served from one
  arbitrary replica instead of the max-version resolution the
  client-centric replication contract requires (DESIGN.md §13).

A second, replicated machine (``ReplScenario`` / ``explore_replicated``)
models the DESIGN.md §13 client-centric replication plane over
``N_REPLICAS = 2`` memory nodes: a write commits at the primary CAS,
then fans out to the secondary as a separate step guarded by a
last-writer-wins version CAS, and the injected crash may land *between*
the two — leaving the replicas divergent.  Real reads resolve the
max-version cell across all replicas and repair laggards (roll-forward);
the seeded ``stale_replica_read`` bug serves whichever single replica
the scheduler picks, and the checker catches the divergence twice over
(the oracle replay and an explicit stale-read record naming the
divergent replicas).

``run()`` additionally executes a tick-level conformance scenario on the
*real* ``protocol.tick`` machine, proving the model's delete gate and the
shipped ``del_q`` gate agree.
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import Counter
from typing import NamedTuple

from repro.analysis import Violation
from repro.core.oracle import OracleStore
from repro.core.types import OpKind, SyncMode

__all__ = ["ModelFlags", "Scenario", "explore", "scenarios", "run",
           "ReplScenario", "explore_replicated", "repl_scenarios",
           "N_KEYS", "SCAN_COUNT", "N_REPLICAS"]

N_KEYS = 2           # model key space {0, 1}
SCAN_COUNT = 2       # SCAN covers [0, 2) — both keys
N_REPLICAS = 2       # replicated-write model: primary + one secondary

# client program counters
START, OCAS, WAIT, CS, REL, DONE = range(6)
_PC_NAME = ("START", "OCAS", "WAIT", "CS", "REL", "DONE")


@dataclasses.dataclass(frozen=True)
class ModelFlags:
    """Protocol variants: the real machine, plus seeded-bug re-injections."""
    combine_covers_deletes: bool = False      # True = pre-fix lost-delete bug
    repair_requires_dead_holder: bool = True  # False = repair may break live locks
    stale_replica_read: bool = False          # True = read one arbitrary replica


REAL = ModelFlags()


class Cl(NamedTuple):
    pc: int
    ticket: int            # -1 = no ticket assigned
    aux: tuple | None      # ("snap", val, ver) optimistic | ("tail", t) coordinator
    ok: bool
    out: int


class Ev(NamedTuple):
    cid: int
    ticket: int            # -1 for lock-free / optimistic commits
    kind: int
    key: int
    value: int
    ok: bool
    out: int               # SEARCH: value read; SCAN: rows; else -1


class St(NamedTuple):
    store: tuple           # per key: (val | None, ver)
    locks: tuple           # per key: holder id (SPIN) | (next_ticket, now_serving)
    clients: tuple         # Cl per client
    crashed: tuple
    events: tuple          # Ev, commit order
    repairs: tuple         # (key, owner_cid, owner_was_crashed)


@dataclasses.dataclass(frozen=True)
class Scenario:
    mode: SyncMode
    ops: tuple                       # per client: (kind, key)
    init_keys: tuple                 # keys present at start (value 0)
    hot: tuple = (False,) * N_KEYS   # CIDER: per-key Algorithm-1 verdict
    flags: ModelFlags = REAL

    def value(self, cid: int) -> int:
        # SCAN carries its range count in the value lane (oracle contract)
        return SCAN_COUNT if self.ops[cid][0] == OpKind.SCAN else 100 + cid

    def describe(self) -> str:
        ops = ",".join(f"{OpKind(k).name}{key}" for k, key in self.ops)
        hot = f" hot={''.join('01'[h] for h in self.hot)}" \
            if self.mode == SyncMode.CIDER else ""
        return f"{self.mode.name}[{ops}] init={list(self.init_keys)}{hot}"


# ---------------------------------------------------------------- helpers
def _set(tup: tuple, i: int, v) -> tuple:
    return tup[:i] + (v,) + tup[i + 1:]


def _pess(sc: Scenario, cid: int) -> bool:
    kind, key = sc.ops[cid]
    if kind == OpKind.UPDATE:
        if sc.mode == SyncMode.OSYNC:
            return False
        if sc.mode == SyncMode.CIDER:
            return bool(sc.hot[key])
        return True
    if kind == OpKind.DELETE:
        return sc.mode != SyncMode.OSYNC
    return False   # SEARCH/SCAN lock-free; INSERT optimistic slot claim


def _apply(store: tuple, kind: int, key: int, value: int):
    """Sequential point/scan semantics (mirrors OracleStore.apply)."""
    val, ver = store[key]
    if kind == OpKind.SEARCH:
        return store, val is not None, (val if val is not None else -1)
    if kind == OpKind.SCAN:
        rows = sum(1 for k in range(key, min(key + SCAN_COUNT, N_KEYS))
                   if store[k][0] is not None)
        return store, rows > 0, rows
    if kind == OpKind.INSERT:
        if val is None:
            return _set(store, key, (value, ver + 1)), True, -1
        return store, False, -1
    if kind == OpKind.UPDATE:
        if val is not None:
            return _set(store, key, (value, ver + 1)), True, -1
        return store, False, -1
    if kind == OpKind.DELETE:
        if val is not None:
            return _set(store, key, (None, ver + 1)), True, -1
        return store, False, -1
    raise AssertionError(kind)


def _commit(st: St, cid: int, kind: int, key: int, value: int,
            ok: bool, out: int, store2: tuple) -> St:
    cl = st.clients[cid]
    ev = Ev(cid, cl.ticket, kind, key, value, ok, out)
    return st._replace(
        store=store2, events=st.events + (ev,),
        clients=_set(st.clients, cid,
                     cl._replace(pc=DONE, aux=None, ok=ok, out=out)))


def _ticket_owner(sc: Scenario, st: St, key: int, t: int) -> int | None:
    for i, c in enumerate(st.clients):
        if sc.ops[i][1] == key and c.ticket == t and c.pc != DONE:
            return i
    return None


def _queued_delete(sc: Scenario, st: St, key: int) -> bool:
    """Model of the ``del_q`` gate: a DELETE with an assigned, unreleased
    ticket on the key (crashed ones included — they never release)."""
    return any(sc.ops[i] == (OpKind.DELETE, key)
               and c.ticket >= 0 and c.pc != DONE
               for i, c in enumerate(st.clients))


def _combine(sc: Scenario, st: St, cid: int) -> St:
    """Coordinator commit + release: the combined result is installed by a
    SINGLE pointer CAS (§4.2.1), so own + covered writes apply atomically;
    events stay per-member in ticket order for the oracle replay."""
    kind, key = sc.ops[cid]
    cl = st.clients[cid]
    tail = cl.aux[1]
    store, events, clients = st.store, st.events, st.clients
    store, ok, out = _apply(store, kind, key, sc.value(cid))
    events = events + (Ev(cid, cl.ticket, kind, key, sc.value(cid), ok, out),)
    clients = _set(clients, cid, cl._replace(pc=DONE, aux=None, ok=ok, out=out))
    for t in range(cl.ticket + 1, tail + 1):
        m = _ticket_owner(sc, st, key, t)
        if m is None or st.crashed[m]:
            continue   # crashed member: ticket passed over, op never completes
        mkind = sc.ops[m][0]
        mcl = clients[m]
        if mkind == OpKind.DELETE:
            # only reachable with flags.combine_covers_deletes: the covered
            # DELETE "completes" without its own MCAS — the lost delete
            clients = _set(clients, m, mcl._replace(pc=DONE, ok=True, out=-1))
            continue
        store, mok, mout = _apply(store, mkind, key, sc.value(m))
        events = events + (Ev(m, mcl.ticket, mkind, key, sc.value(m), mok, mout),)
        clients = _set(clients, m, mcl._replace(pc=DONE, ok=mok, out=mout))
    nt, _ = st.locks[key]
    return st._replace(store=store, events=events, clients=clients,
                       locks=_set(st.locks, key, (nt, tail + 1)))


# ---------------------------------------------------------------- stepper
def _steps(sc: Scenario, st: St, cid: int) -> list[St]:
    """All real (non-crash) successor states from client ``cid``."""
    kind, key = sc.ops[cid]
    cl = st.clients[cid]
    value = sc.value(cid)
    out: list[St] = []

    if kind in (OpKind.SEARCH, OpKind.SCAN):
        store2, ok, res = _apply(st.store, kind, key, value)
        return [_commit(st, cid, kind, key, value, ok, res, store2)]

    if not _pess(sc, cid):
        if cl.pc == START:   # one-sided READ of the pointer (snapshot)
            val, ver = st.store[key]
            if kind == OpKind.INSERT and val is not None:
                out.append(_commit(st, cid, kind, key, value, False, -1, st.store))
            elif kind != OpKind.INSERT and val is None:
                out.append(_commit(st, cid, kind, key, value, False, -1, st.store))
            else:
                out.append(st._replace(clients=_set(
                    st.clients, cid, cl._replace(pc=OCAS, aux=("snap", val, ver)))))
        elif cl.pc == OCAS:  # the CAS linearization point
            val, ver = st.store[key]
            if kind == OpKind.INSERT:
                # slot-claim CAS: succeeds iff the slot is (still) empty —
                # a raced INSERT fails, it does not retry (§4.2.2)
                store2, ok, res = _apply(st.store, kind, key, value)
                out.append(_commit(st, cid, kind, key, value, ok, res, store2))
            elif ver == cl.aux[2]:
                store2, ok, res = _apply(st.store, kind, key, value)
                out.append(_commit(st, cid, kind, key, value, ok, res, store2))
            else:            # CAS lost: re-read and retry (§2.2)
                out.append(st._replace(clients=_set(
                    st.clients, cid, cl._replace(pc=START, aux=None))))
        return out

    if sc.mode == SyncMode.SPIN:
        holder = st.locks[key]
        if cl.pc == START:
            if holder == -1:
                out.append(st._replace(
                    locks=_set(st.locks, key, cid),
                    clients=_set(st.clients, cid, cl._replace(pc=CS))))
            elif holder != cid and (st.crashed[holder]
                                    or not sc.flags.repair_requires_dead_holder):
                out.append(st._replace(     # §4.6: break the orphaned lock
                    locks=_set(st.locks, key, -1),
                    repairs=st.repairs + ((key, holder, st.crashed[holder]),)))
        elif cl.pc == CS:
            store2, ok, res = _apply(st.store, kind, key, value)
            ev = Ev(cid, cl.ticket, kind, key, value, ok, res)
            out.append(st._replace(
                store=store2, events=st.events + (ev,),
                clients=_set(st.clients, cid,
                             cl._replace(pc=REL, ok=ok, out=res))))
        elif cl.pc == REL:   # unlock CAS (unconditional reset, as SUNL)
            out.append(st._replace(
                locks=_set(st.locks, key, -1),
                clients=_set(st.clients, cid, cl._replace(pc=DONE))))
        return out

    # MCS / CIDER ticket queue
    nt, ns = st.locks[key]
    if cl.pc == START:       # ENQ: fetch-and-add the tail
        out.append(st._replace(
            locks=_set(st.locks, key, (nt + 1, ns)),
            clients=_set(st.clients, cid, cl._replace(pc=WAIT, ticket=nt))))
    elif cl.pc == WAIT:
        if ns == cl.ticket:  # acquired
            aux = None
            if (sc.mode == SyncMode.CIDER and kind == OpKind.UPDATE
                    and nt - 1 > cl.ticket
                    and (sc.flags.combine_covers_deletes
                         or not _queued_delete(sc, st, key))):
                aux = ("tail", nt - 1)   # coordinator: tail latched at acquire
            out.append(st._replace(clients=_set(
                st.clients, cid, cl._replace(pc=CS, aux=aux))))
        else:                # §4.6: advance now_serving past a dead owner
            owner = _ticket_owner(sc, st, key, ns)
            if owner is not None and owner != cid and (
                    st.crashed[owner]
                    or not sc.flags.repair_requires_dead_holder):
                out.append(st._replace(
                    locks=_set(st.locks, key, (nt, ns + 1)),
                    repairs=st.repairs + ((key, owner, st.crashed[owner]),)))
    elif cl.pc == CS:
        if cl.aux is None:   # plain pessimistic: MW + MCAS
            store2, ok, res = _apply(st.store, kind, key, value)
            ev = Ev(cid, cl.ticket, kind, key, value, ok, res)
            out.append(st._replace(
                store=store2, events=st.events + (ev,),
                clients=_set(st.clients, cid,
                             cl._replace(pc=REL, ok=ok, out=res))))
        else:                # coordinator: combined CAS + release to tail
            out.append(_combine(sc, st, cid))
    elif cl.pc == REL:       # MFAA release
        out.append(st._replace(
            locks=_set(st.locks, key, (nt, cl.ticket + 1)),
            clients=_set(st.clients, cid, cl._replace(pc=DONE))))
    return out


def _successors(sc: Scenario, st: St) -> tuple[list[St], list[St]]:
    real: list[St] = []
    crash: list[St] = []
    can_crash = not any(st.crashed)   # at most one crash per run (§4.6 scope)
    for cid, cl in enumerate(st.clients):
        if st.crashed[cid] or cl.pc == DONE:
            continue
        real.extend(_steps(sc, st, cid))
        if can_crash:
            crash.append(st._replace(crashed=_set(st.crashed, cid, True)))
    return real, crash


# ---------------------------------------------------------------- checks
def _op_name(sc: Scenario, cid: int) -> str:
    kind, key = sc.ops[cid]
    return f"client {cid} ({OpKind(kind).name} key {key})"


def _check_state(sc: Scenario, st: St, msgs: set) -> None:
    for key in range(N_KEYS):
        holders = [i for i, c in enumerate(st.clients)
                   if not st.crashed[i] and c.pc in (CS, REL)
                   and sc.ops[i][1] == key]
        if len(holders) > 1:
            msgs.add(f"mutual exclusion broken on key {key}: live clients "
                     f"{holders} are inside the critical section together")


def _check_terminal(sc: Scenario, st: St, msgs: set) -> None:
    for i, c in enumerate(st.clients):
        if not st.crashed[i] and c.pc != DONE:
            msgs.add(f"liveness: {_op_name(sc, i)} is stuck at "
                     f"pc={_PC_NAME[c.pc]} with no step left")
    counts = Counter(ev.cid for ev in st.events)
    for i, c in enumerate(st.clients):
        if not st.crashed[i] and c.pc == DONE and counts.get(i, 0) != 1:
            msgs.add(f"{_op_name(sc, i)} completed with {counts.get(i, 0)} "
                     f"committed events — its op was lost (or duplicated)")
    for key in range(N_KEYS):
        ranks = [ev.ticket for ev in st.events
                 if ev.key == key and ev.ticket >= 0]
        if ranks != sorted(ranks):
            msgs.add(f"commit order breaks wait-queue rank order on key "
                     f"{key}: tickets committed as {ranks}")
    for key, owner, owner_was_crashed in st.repairs:
        if not owner_was_crashed:
            msgs.add(f"§4.6 repair broke a LIVE lock on key {key} "
                     f"(owner client {owner} had not crashed)")
    _replay_oracle(sc, st.events,
                   {k: v for k, (v, _) in enumerate(st.store)
                    if v is not None}, msgs)


def _replay_oracle(sc, events: tuple, model_kv: dict, msgs: set) -> None:
    """Oracle replay: commit order must be a correct sequential history
    and the terminal (resolved) store must match the oracle's."""
    oracle = OracleStore()
    oracle.populate(list(sc.init_keys), [0] * len(sc.init_keys))
    for ev in events:
        ok, out = oracle.apply([ev.kind], [ev.key], [ev.value],
                               scan_max=SCAN_COUNT)
        if bool(ok[0]) != ev.ok:
            msgs.add(f"oracle replay diverges: {_op_name(sc, ev.cid)} "
                     f"committed ok={ev.ok}, oracle says {bool(ok[0])}")
        elif ev.kind == OpKind.SEARCH and int(out[0]) != ev.out:
            msgs.add(f"oracle replay diverges: {_op_name(sc, ev.cid)} read "
                     f"{ev.out}, oracle says {int(out[0])}")
        elif ev.kind == OpKind.SCAN and int(oracle.rows[0]) != ev.out:
            msgs.add(f"oracle replay diverges: {_op_name(sc, ev.cid)} saw "
                     f"{ev.out} rows, oracle says {int(oracle.rows[0])}")
    if model_kv != oracle.kv:
        msgs.add(f"terminal store diverges from oracle replay: "
                 f"model={model_kv} oracle={oracle.kv}")


# ---------------------------------------------------------------- explore
def explore(sc: Scenario, allow_crash: bool = True,
            max_states: int = 500_000) -> tuple[list[Violation], int]:
    """DFS every interleaving of ``sc``; returns (violations, #states)."""
    init = St(
        store=tuple((0, 0) if k in sc.init_keys else (None, 0)
                    for k in range(N_KEYS)),
        locks=tuple((-1 if sc.mode == SyncMode.SPIN else (0, 0))
                    for _ in range(N_KEYS)),
        clients=tuple(Cl(START, -1, None, False, -1) for _ in sc.ops),
        crashed=(False,) * len(sc.ops), events=(), repairs=())
    seen = {init}
    stack = [init]
    msgs: set[str] = set()
    n = 0
    while stack:
        st = stack.pop()
        n += 1
        if n > max_states:
            msgs.add(f"state-space blowup: more than {max_states} states")
            break
        _check_state(sc, st, msgs)
        real, crash = _successors(sc, st)
        if not real:
            # terminal modulo crashes: no live client can take a real step
            _check_terminal(sc, st, msgs)
        for nxt in real + (crash if allow_crash else []):
            if nxt not in seen:
                seen.add(nxt)
                stack.append(nxt)
    return ([Violation("race_check", sc.describe(), m) for m in sorted(msgs)],
            len(seen))


def scenarios(quick: bool = True):
    """The checked scenario space: every mode x op-multiset x initial store
    (x CIDER hotness).  2 clients range over both keys and every OpKind;
    3 clients (the coordinator/member/straggler shapes) stay on key 0."""
    point = [OpKind.SEARCH, OpKind.INSERT, OpKind.UPDATE, OpKind.DELETE]
    ops = [(k, key) for k in point for key in range(N_KEYS)] \
        + [(OpKind.SCAN, 0)]
    stores2 = [(), (0,), (1,), (0, 1)]
    stores3 = [(), (0,), (0, 1)] if quick else stores2
    ops3 = [o for o in ops if o[1] == 0]
    for mode in SyncMode:
        hots = ([(True, True), (False, False)] if mode == SyncMode.CIDER
                else [(False,) * N_KEYS])
        for hot in hots:
            for pair in itertools.combinations_with_replacement(ops, 2):
                for init in stores2:
                    yield Scenario(mode, pair, tuple(init), hot)
            for trip in itertools.combinations_with_replacement(ops3, 3):
                for init in stores3:
                    yield Scenario(mode, trip, tuple(init), hot)


# ---------------------------------------------- replicated-write machine
# Client-centric MN replication (DESIGN.md §13): no replica runs a CPU —
# the WRITING client updates every replica itself.  The write commits at
# the primary CAS; the secondary fan-out is a separate step guarded by a
# last-writer-wins version CAS, and the (single) injected crash may land
# between the two, leaving the replicas divergent.  Real reads resolve
# the max-version cell across ALL replicas and write the laggards back
# (repair modeled atomic with the read — dropping interleavings never
# hides a bug, it only strengthens the machine the seeded fixture must
# still defeat).  ``ModelFlags.stale_replica_read`` serves whichever
# single replica the scheduler picks instead: after a partial fan-out the
# read returns the stale cell and the checker flags it twice — the oracle
# replay diverges, and an explicit record names the divergent replicas.

RSTART, RFAN, RDONE = range(3)
_R_PC_NAME = ("START", "FAN", "DONE")


@dataclasses.dataclass(frozen=True)
class ReplScenario:
    """A replicated-write model instance: per-client (kind, key) programs
    over ``N_REPLICAS`` replica stores."""
    ops: tuple                       # per client: (kind, key)
    init_keys: tuple                 # keys present at start on ALL replicas
    flags: ModelFlags = REAL

    def value(self, cid: int) -> int:
        return SCAN_COUNT if self.ops[cid][0] == OpKind.SCAN else 100 + cid

    def describe(self) -> str:
        ops = ",".join(f"{OpKind(k).name}{key}" for k, key in self.ops)
        bug = " stale_replica_read" if self.flags.stale_replica_read else ""
        return f"REPL[{ops}] init={list(self.init_keys)}{bug}"


class RSt(NamedTuple):
    stores: tuple          # per replica: per key (val | None, ver)
    clients: tuple         # Cl per client (ticket unused, -1)
    crashed: tuple
    events: tuple          # Ev, commit order (= primary CAS order)
    stale: tuple           # (key, replica, got_ver, best_replica, best_ver)


def _resolved(stores: tuple) -> tuple:
    """Per-key last-writer-wins resolution: the max-version cell wins."""
    return tuple(max((stores[r][k] for r in range(N_REPLICAS)),
                     key=lambda cell: cell[1])
                 for k in range(N_KEYS))


def _r_read_keys(kind: int, key: int) -> range:
    return range(key, min(key + SCAN_COUNT, N_KEYS)) \
        if kind == OpKind.SCAN else range(key, key + 1)


def _r_steps(sc: ReplScenario, st: RSt, cid: int) -> list[RSt]:
    """All real (non-crash) successor states from client ``cid``."""
    kind, key = sc.ops[cid]
    cl = st.clients[cid]
    value = sc.value(cid)

    if kind in (OpKind.SEARCH, OpKind.SCAN):
        if sc.flags.stale_replica_read:
            # seeded bug: serve ONE arbitrary replica, no resolution/repair
            best = _resolved(st.stores)
            outs = []
            for r in range(N_REPLICAS):
                _, ok, res = _apply(st.stores[r], kind, key, value)
                stale = st.stale
                for k in _r_read_keys(kind, key):
                    if st.stores[r][k][1] < best[k][1]:
                        br = max(range(N_REPLICAS),
                                 key=lambda i: st.stores[i][k][1])
                        stale = stale + (
                            (k, r, st.stores[r][k][1], br, best[k][1]),)
                ev = Ev(cid, -1, kind, key, value, ok, res)
                outs.append(st._replace(
                    events=st.events + (ev,), stale=stale,
                    clients=_set(st.clients, cid,
                                 cl._replace(pc=RDONE, ok=ok, out=res))))
            return outs
        # real machine: max-version resolution + roll-forward repair
        best = _resolved(st.stores)
        _, ok, res = _apply(best, kind, key, value)
        stores = tuple(
            tuple(best[k] if k in _r_read_keys(kind, key) else store[k]
                  for k in range(N_KEYS))
            for store in st.stores)
        ev = Ev(cid, -1, kind, key, value, ok, res)
        return [st._replace(
            stores=stores, events=st.events + (ev,),
            clients=_set(st.clients, cid,
                         cl._replace(pc=RDONE, ok=ok, out=res)))]

    if cl.pc == RSTART:
        # primary CAS: the commit point — the event lands here
        store2, ok, res = _apply(st.stores[0], kind, key, value)
        ev = Ev(cid, -1, kind, key, value, ok, res)
        nxt = cl._replace(pc=RFAN if ok else RDONE,
                          aux=("fan", key, store2[key]) if ok else None,
                          ok=ok, out=res)
        return [st._replace(
            stores=_set(st.stores, 0, store2),
            events=st.events + (ev,),
            clients=_set(st.clients, cid, nxt))]
    if cl.pc == RFAN:
        # secondary fan-out: last-writer-wins version CAS per replica
        _, fkey, cell = cl.aux
        stores = st.stores
        for r in range(1, N_REPLICAS):
            if stores[r][fkey][1] < cell[1]:
                stores = _set(stores, r, _set(stores[r], fkey, cell))
        return [st._replace(
            stores=stores,
            clients=_set(st.clients, cid,
                         cl._replace(pc=RDONE, aux=None)))]
    return []


def _r_check_terminal(sc: ReplScenario, st: RSt, msgs: set) -> None:
    for i, c in enumerate(st.clients):
        if not st.crashed[i] and c.pc != RDONE:
            msgs.add(f"liveness: {_op_name(sc, i)} is stuck at "
                     f"pc={_R_PC_NAME[c.pc]} with no step left")
    counts = Counter(ev.cid for ev in st.events)
    for i, c in enumerate(st.clients):
        if not st.crashed[i] and c.pc == RDONE and counts.get(i, 0) != 1:
            msgs.add(f"{_op_name(sc, i)} completed with {counts.get(i, 0)} "
                     f"committed events — its op was lost (or duplicated)")
    for k, r, got_ver, best_r, best_ver in st.stale:
        msgs.add(f"stale-replica read on key {k}: served replica {r} at "
                 f"version {got_ver} while replica {best_r} held version "
                 f"{best_ver} — replicas diverge and the read skipped "
                 f"last-writer-wins resolution")
    if not any(st.crashed):
        # no crash: every fan-out completed, so replicas must agree
        for k in range(N_KEYS):
            cells = {st.stores[r][k] for r in range(N_REPLICAS)}
            if len(cells) > 1:
                per = ", ".join(f"replica {r}={st.stores[r][k]}"
                                for r in range(N_REPLICAS))
                msgs.add(f"replicas diverge at quiescence on key {k} "
                         f"with no crash: {per}")
    best = _resolved(st.stores)
    _replay_oracle(sc, st.events,
                   {k: v for k, (v, _) in enumerate(best)
                    if v is not None}, msgs)


def explore_replicated(sc: ReplScenario, allow_crash: bool = True,
                       max_states: int = 200_000
                       ) -> tuple[list[Violation], int]:
    """DFS every interleaving of the replicated machine for ``sc``."""
    init = RSt(
        stores=tuple(tuple((0, 0) if k in sc.init_keys else (None, 0)
                           for k in range(N_KEYS))
                     for _ in range(N_REPLICAS)),
        clients=tuple(Cl(RSTART, -1, None, False, -1) for _ in sc.ops),
        crashed=(False,) * len(sc.ops), events=(), stale=())
    seen = {init}
    stack = [init]
    msgs: set[str] = set()
    n = 0
    while stack:
        st = stack.pop()
        n += 1
        if n > max_states:
            msgs.add(f"state-space blowup: more than {max_states} states")
            break
        real: list[RSt] = []
        crash: list[RSt] = []
        can_crash = allow_crash and not any(st.crashed)
        for cid, cl in enumerate(st.clients):
            if st.crashed[cid] or cl.pc == RDONE:
                continue
            real.extend(_r_steps(sc, st, cid))
            if can_crash:
                crash.append(st._replace(crashed=_set(st.crashed, cid, True)))
        if not real:
            _r_check_terminal(sc, st, msgs)
        for nxt in real + crash:
            if nxt not in seen:
                seen.add(nxt)
                stack.append(nxt)
    return ([Violation("race_check", sc.describe(), m) for m in sorted(msgs)],
            len(seen))


def repl_scenarios(quick: bool = True):
    """The replicated scenario space: every op pair over both keys plus
    the writer/writer/reader triples on key 0, each against every initial
    store — crash-at-any-step lands between primary CAS and fan-out."""
    point = [OpKind.SEARCH, OpKind.INSERT, OpKind.UPDATE, OpKind.DELETE]
    ops = [(k, key) for k in point for key in range(N_KEYS)] \
        + [(OpKind.SCAN, 0)]
    stores = [(), (0,), (0, 1)] if quick else [(), (0,), (1,), (0, 1)]
    ops3 = [o for o in ops if o[1] == 0]
    for pair in itertools.combinations_with_replacement(ops, 2):
        for init in stores:
            yield ReplScenario(pair, tuple(init))
    for trip in itertools.combinations_with_replacement(ops3, 3):
        for init in stores:
            yield ReplScenario(trip, tuple(init))


# ------------------------------------------------- tick-level conformance
def _sim_conformance(notes: list[str] | None) -> list[Violation]:
    """Prove the shipped ``del_q`` gate on the real ``protocol.tick``
    machine agrees with the model: with a DELETE queued behind two UPDATEs
    on one key, no combined batch may form (and the delete must drain the
    gate); the delete-free control still combines."""
    import numpy as np  # deferred: keeps the model checker import-light
    import jax.numpy as jnp
    from repro.core.sim import _run
    from repro.core.simnet import SimParams

    def streams(first):
        n, m = 3, 4
        kinds = np.full((n, m), OpKind.SEARCH, np.int32)
        kinds[:, 0] = first
        hkey = np.full((n, m), 9, np.int32)
        hkey[:, 0] = 5
        hc = np.tile(np.arange(n, dtype=np.int32)[:, None], (1, m))
        return {"kinds": jnp.asarray(kinds), "hkey": jnp.asarray(hkey),
                "hc": jnp.asarray(hc), "hl": jnp.asarray(hc.copy())}

    p = SimParams(n_lanes=3, lanes_per_cn=1, max_ops=4, ticks=400,
                  cas_off=True, local_wc=False, h_bits=4, hc_bits=2,
                  hl_bits=2)
    out = []
    s = _run(p, SyncMode.CIDER, streams(
        [OpKind.UPDATE, OpKind.UPDATE, OpKind.DELETE]), jnp.int32(3))
    if int(s.comb_g) != 0:
        out.append(Violation(
            "race_check", "protocol.tick del_q gate",
            f"combined batch formed over a queued DELETE "
            f"(comb_g={int(s.comb_g)}) — the lost-delete gate is broken"))
    if int(s.del_q[5]) != 0:
        out.append(Violation(
            "race_check", "protocol.tick del_q gate",
            f"del_q did not drain (del_q[5]={int(s.del_q[5])}) — "
            f"increments/decrements are unbalanced"))
    if int(s.deadlocks) != 0 or int(s.done) == 0:
        out.append(Violation(
            "race_check", "protocol.tick del_q gate",
            f"delete-gated run wedged (done={int(s.done)}, "
            f"deadlocks={int(s.deadlocks)})"))
    ctl = _run(p, SyncMode.CIDER, streams([OpKind.UPDATE] * 3), jnp.int32(3))
    if int(ctl.comb_g) == 0:
        out.append(Violation(
            "race_check", "protocol.tick del_q gate",
            "delete-free control never combined — the gate is firing "
            "without a queued DELETE (combining disabled outright)"))
    if notes is not None:
        notes.append(f"race_check: tick conformance comb_g="
                     f"{int(s.comb_g)}/{int(ctl.comb_g)} (delete/control)")
    return out


def run(notes: list[str] | None = None, quick: bool = True,
        max_report: int = 64) -> list[Violation]:
    """Model-check every scenario with the REAL protocol flags — the
    per-mode machines, then the replicated-write machine — then the
    tick-level conformance check against ``protocol.tick``."""
    out: list[Violation] = []
    n_sc = n_states = 0
    truncated = False
    for sc in scenarios(quick=quick):
        viols, states = explore(sc)
        out.extend(viols)
        n_sc += 1
        n_states += states
        if len(out) >= max_report:
            out.append(Violation("race_check", "(reporting)",
                                 f"truncated after {max_report} violations"))
            truncated = True
            break
    n_rsc = n_rstates = 0
    if not truncated:
        for rsc in repl_scenarios(quick=quick):
            viols, states = explore_replicated(rsc)
            out.extend(viols)
            n_rsc += 1
            n_rstates += states
            if len(out) >= max_report:
                out.append(Violation("race_check", "(reporting)",
                                     f"truncated after {max_report} "
                                     f"violations"))
                break
    if notes is not None:
        notes.append(f"race_check: {n_sc} scenarios, {n_states} states + "
                     f"{n_rsc} replicated scenarios, {n_rstates} states")
    out.extend(_sim_conformance(notes))
    return out
