"""Logical-axis -> PartitionSpec rules (see DESIGN.md §3.2).

One table maps every logical axis name used by ``models/*`` and the engine to
an ordered list of candidate mesh-axis tuples.  ``spec_for`` walks a shape's
logical axes left to right, assigning the first candidate whose mesh axes are
all present, unused so far, and whose product divides the dimension —
otherwise the dimension is replicated.  This gives FSDP ("embed" over
``data``), TP ("heads"/"mlp"/"experts"/"vocab" over ``model``), and DP
(batch/token axes over ``("pod", "data")``) on any mesh shape without
per-model spec tables, and degrades each axis independently to replication
when a reduced (smoke) dim is not divisible.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.common import Param

__all__ = ["spec_for", "param_shardings", "batch_shardings",
           "decode_state_shardings", "LOGICAL_RULES"]

# logical axis -> ordered candidate mesh-axis tuples (first fit wins)
_DP = (("pod", "data"), ("data",))
LOGICAL_RULES: dict[str, tuple[tuple[str, ...], ...]] = {
    # data-parallel axes (batch / token / per-row dispatch)
    "batch": _DP, "act_batch": _DP, "act_tokens": _DP, "act_rows": _DP,
    # FSDP: the embedding dim of weights shards over the data axis
    "embed": (("data",),),
    # tensor-parallel axes
    "heads": (("model",),), "kv": (("model",),), "mlp": (("model",),),
    "experts": (("model",),), "vocab": (("model",),),
    "act_heads": (("model",),), "act_kv": (("model",),),
    "act_mlp": (("model",),), "act_vocab": (("model",),),
    "act_experts": (("model",),),
    # decode KV-cache sequence axis (dist.decode_attn shards it)
    "act_cache_seq": (("model",),),
    # sharded CIDER dataplane: store slots / heap partition over data
    "slots": (("data",),), "heap": (("data",),),
    # replicated-only axes get no entry: layers, head_dim, conv, front, ...
}


def _mesh_shape(mesh: Any) -> dict[str, int]:
    # Mesh.shape is an OrderedDict axis->size; tests also pass bare objects
    # exposing just ``.shape`` as a dict.
    return dict(mesh.shape)


def spec_for(shape: tuple[int, ...], logical_axes, mesh: Any) -> P:
    """Map a shape's logical axes to a PartitionSpec on ``mesh``.

    Each mesh axis is assigned at most once; a dimension that cannot be
    sharded (unknown name, missing mesh axis, or not divisible by the mesh
    axes' product) is replicated.
    """
    sizes = _mesh_shape(mesh)
    logical_axes = tuple(logical_axes or ())
    if len(logical_axes) < len(shape):
        logical_axes = logical_axes + (None,) * (len(shape) - len(logical_axes))
    used: set[str] = set()
    out: list = []
    for dim, name in zip(shape, logical_axes):
        assigned = None
        for cand in LOGICAL_RULES.get(name, ()):  # type: ignore[arg-type]
            if any(a not in sizes or a in used for a in cand):
                continue
            prod = 1
            for a in cand:
                prod *= sizes[a]
            if prod > 1 and dim % prod == 0:
                assigned = cand if len(cand) > 1 else cand[0]
                used.update(cand)
                break
        out.append(assigned)
    return P(*out)


def param_shardings(boxed: Any, mesh):
    """NamedShardings for a boxed (``Param``) tree, e.g. ``init_abstract()``."""
    return jax.tree.map(
        lambda p: NamedSharding(mesh, spec_for(p.value.shape, p.axes, mesh)),
        boxed, is_leaf=lambda x: isinstance(x, Param))


def batch_shardings(bspec: Any, mesh):
    """Input-batch shardings: leading axis is the global batch, rest local."""
    def one(leaf):
        axes = ("batch",) + (None,) * (len(leaf.shape) - 1)
        return NamedSharding(mesh, spec_for(leaf.shape, axes, mesh))
    return jax.tree.map(one, bspec)


def decode_state_shardings(state_spec: Any, mesh):
    """Decode-state shardings: axis 0 is the stacked layers axis, axis 1 the
    batch; KV caches additionally shard their heads axis (-2) over model."""
    def one(path, leaf):
        nd = len(leaf.shape)
        axes: list = [None] * nd
        if nd >= 2:
            axes[1] = "batch"
        name = path[-1].key if path else ""
        if name in ("k", "v") and nd >= 4:
            axes[-2] = "kv"
        return NamedSharding(mesh, spec_for(leaf.shape, tuple(axes), mesh))
    return jax.tree_util.tree_map_with_path(one, state_spec)
