"""Write-combined sparse embedding gradients (the §4.2 idea applied to
training).

A token batch UPDATEs embedding rows exactly like concurrent KV writers
UPDATE a slot: duplicated ids are a wait queue on one row.  The dense
gradient scatters every (token, grad) pair — O(T) row writes; the combined
path groups by id with the same sort/segment primitive as
``core.combine.plan_combine`` and emits ONE summed row per unique id, so the
cross-node write traffic is proportional to *unique* ids (heavy-tailed token
distributions make this a large constant factor, exactly Fig 4's argument).

DESIGN.md §3.4 (cross-node traffic; the §2.1 combine primitive applied to
training): per-unique-id combined gradient writes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["dense_embed_grad", "combined_embed_grad", "apply_sparse_grad"]


def dense_embed_grad(ids, grads, vocab: int):
    """Reference: full (vocab, D) gradient via scatter-add of every token."""
    d = grads.shape[-1]
    return jnp.zeros((vocab, d), grads.dtype).at[ids].add(grads)


@jax.jit
def combined_embed_grad(ids, grads):
    """Combine per-token gradients by id: returns (hids, rows, uniq), all
    length-T, where ``uniq`` marks one representative per distinct id and
    ``rows[i]`` is the summed gradient of that id (zeros elsewhere)."""
    t = ids.shape[0]
    pos = jnp.arange(t, dtype=jnp.int32)
    order = jnp.lexsort((pos, ids))
    ids_s, g_s = ids[order], grads[order]
    is_first = jnp.concatenate([jnp.ones((1,), bool), ids_s[1:] != ids_s[:-1]])
    seg = jnp.cumsum(is_first.astype(jnp.int32)) - 1
    summed = jax.ops.segment_sum(g_s, seg, num_segments=t)
    rows = jnp.where(is_first[:, None], summed[seg], 0.0)
    return jnp.where(is_first, ids_s, 0), rows, is_first


@jax.jit
def apply_sparse_grad(table, hids, rows, uniq, lr: float = 1.0):
    """SGD-apply a combined sparse gradient: one row write per unique id."""
    vocab = table.shape[0]
    idx = jnp.where(uniq, hids, vocab)          # non-representatives drop
    return table.at[idx].add(-lr * rows.astype(table.dtype), mode="drop")
