"""Mesh context + activation sharding constraints.

``use_mesh`` installs a mesh for the duration of a ``with`` block;
``shard(x, logical_axes)`` is the single entry point models use to annotate
activations.  Without an installed mesh it is an exact no-op, so every model
runs unchanged on a single CPU device; with a mesh it lowers to
``with_sharding_constraint`` using the logical-axis rules of
``repro.dist.sharding`` (divisibility-checked, replication fallback).

DESIGN.md §3.2 (logical-axis rules): mesh context + in-line activation
sharding constraints.
"""
from __future__ import annotations

import contextlib

import jax
from jax.sharding import NamedSharding

__all__ = ["use_mesh", "current_mesh", "shard"]

_MESH_STACK: list = []


@contextlib.contextmanager
def use_mesh(mesh):
    """Install ``mesh`` as the ambient mesh for ``shard`` constraints."""
    _MESH_STACK.append(mesh)
    try:
        yield mesh
    finally:
        _MESH_STACK.pop()


def current_mesh():
    """The innermost installed mesh, or None outside any ``use_mesh``."""
    return _MESH_STACK[-1] if _MESH_STACK else None


def shard(x, logical_axes):
    """Constrain ``x`` to the sharding implied by ``logical_axes``.

    A no-op when no mesh is installed — models call this unconditionally.
    """
    mesh = current_mesh()
    if mesh is None:
        return x
    from repro.dist.sharding import spec_for
    spec = spec_for(x.shape, logical_axes, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
