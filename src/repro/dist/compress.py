"""int8 gradient compression with error feedback for cross-node traffic.

Each leaf is scaled to int8 by its max-abs (one f32 scale per leaf — an
Elias-Fano-style split of a tensor into a tiny high-order part and a dense
low-order payload), and the quantization residual is carried to the next
step (error feedback), so the *time-averaged* applied gradient is unbiased:
the bias of round-to-nearest is re-injected instead of lost, and the 4x
traffic reduction costs no asymptotic accuracy (tests check the running mean
converges to the true gradient).

DESIGN.md §3.4 (cross-node traffic): int8 + error-feedback gradient
compression, time-averaged unbiased.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["zeros_residuals", "ef_compress_tree", "ef_decompress_tree"]


def zeros_residuals(tree):
    """Initial (zero) error-feedback residuals for a gradient tree."""
    return jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), tree)


def _compress_leaf(g, r):
    x = g.astype(jnp.float32) + r
    scale = jnp.maximum(jnp.max(jnp.abs(x)) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale, x - q.astype(jnp.float32) * scale


def ef_compress_tree(grads, residuals):
    """(grads, residuals) -> (int8 tree, scale tree, new residuals)."""
    leaves, treedef = jax.tree.flatten(grads)
    res = treedef.flatten_up_to(residuals)
    out = [_compress_leaf(g, r) for g, r in zip(leaves, res)]
    qs, scales, new_res = zip(*out) if out else ((), (), ())
    return (treedef.unflatten(qs), treedef.unflatten(scales),
            treedef.unflatten(new_res))


def ef_decompress_tree(q_tree, scale_tree):
    """Inverse of ``ef_compress_tree``: int8 + per-leaf scale -> f32 tree."""
    return jax.tree.map(lambda q, s: q.astype(jnp.float32) * s,
                        q_tree, scale_tree)
