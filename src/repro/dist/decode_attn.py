"""One-pass shard_map decode attention over a sequence-sharded KV cache.

``models.attention.decode_attention`` is the XLA-SPMD reference: plain
reductions whose softmax max/sum lower to all-reduces.  This module is the
explicit-collective variant: the cache's ``smax`` axis is block-partitioned
over one mesh axis, each shard computes its local scores in one pass, and
exactly three collectives (pmax for the running max, psum for the normalizer
and the weighted values) produce the identical result — the communication
pattern the reference only reaches after XLA's partitioner gets it right.

DESIGN.md §3 (distribution layer): shard_map decode attention over a
sequence-sharded KV cache, exact vs the SPMD reference.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.models.attention import NEG_INF, decode_attention

__all__ = ["decode_attention_spmd"]


def decode_attention_spmd(mesh, q, k_cache, v_cache, length, *,
                          seq_axis: str = "model"):
    """q: (B, 1, H, D); caches: (B, Smax, K, D); attend over pos < ``length``.

    The cache sequence axis is sharded ``mesh.shape[seq_axis]`` ways; q is
    replicated (one token).  Falls back to the reference when Smax is not
    divisible by the mesh axis.
    """
    b, _, h, d = q.shape
    smax, kh = k_cache.shape[1], k_cache.shape[2]
    n = int(mesh.shape[seq_axis])
    if n <= 1 or smax % n != 0:
        return decode_attention(q, k_cache, v_cache, length)
    g = h // kh
    scale = d ** -0.5
    length = jnp.asarray(length, jnp.int32)

    def local(qs, ks, vs, ln):
        s_loc = ks.shape[1]
        offs = jax.lax.axis_index(seq_axis) * s_loc
        qg = qs.reshape(b, kh, g, d).astype(jnp.float32) * scale
        sc = jnp.einsum("bkgd,bskd->bkgs", qg, ks.astype(jnp.float32))
        pos = offs + jnp.arange(s_loc)
        sc = jnp.where(pos[None, None, None, :] < ln, sc, NEG_INF)
        m = jax.lax.pmax(jnp.max(sc, -1), seq_axis)
        p = jnp.exp(sc - m[..., None])
        denom = jax.lax.psum(jnp.sum(p, -1), seq_axis)
        num = jax.lax.psum(
            jnp.einsum("bkgs,bskd->bkgd", p, vs.astype(jnp.float32)), seq_axis)
        out = num / jnp.maximum(denom, 1e-30)[..., None]
        return out.reshape(b, 1, h, d).astype(qs.dtype)

    rep = P(None, None, None, None)
    kv = P(None, seq_axis, None, None)
    fn = shard_map(local, mesh=mesh, in_specs=(rep, kv, kv, P()),
                   out_specs=rep, check_rep=False)
    return fn(q, k_cache, v_cache, length)
