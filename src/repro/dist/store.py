"""The sharded CIDER dataplane: ``StoreState`` partitioned over a mesh axis.

FUSEE/DINOMO-style memory-pool partitioning: slot ``k`` (and its heap) is
owned by shard ``k // slots_per_shard`` along the ``data`` mesh axis.  One
synchronization window executes as a single ``shard_map``: every shard sees
the (replicated) op batch, masks the ops whose keys it owns, and runs the
unmodified ``engine.apply_batch`` on its slot/heap partition; the engine's
credit plane runs on the full batch on every shard (see ``apply_batch``'s
docstring), so the replicated credit table stays bit-identical and no
cross-shard traffic exists beyond the final psum that assembles per-op
results and the global I/O bill.

Equivalence contract (tested in ``tests/test_dist_store.py``): for any mesh
size that divides ``n_slots``/``heap_slots``, the logical store view
(exists/value per slot), ``ver``/``epoch``, per-op ``Results``, the credit
table, and every ``IOMetrics`` counter are identical to the single-device
engine, for all four ``SyncMode``s.  Only the physical heap layout differs
(each shard packs its own commits).

DESIGN.md §3.3 (sharded store): slot-partitioned StoreState under shard_map,
bit-equal to the single device — cross-shard SCAN runs included (§9.3).
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import engine
from repro.core.credits import CreditState
from repro.core.engine import Results, StoreState
from repro.core.runner import WindowStream, _prev_alive
from repro.core.types import (NULL_PTR, EngineConfig, IOMetrics, OpBatch,
                              OpKind)

__all__ = ["shard_extents", "sharded_store_init", "sharded_populate",
           "sharded_store_view", "apply_batch_sharded", "run_windows_sharded",
           "run_windows_sharded_traced", "failover_reown", "promote_replica",
           "host_rehome"]

_NONE = jnp.int32(-1)


def host_rehome(x) -> jax.Array:
    """Pull an array through the host so it sheds its committed device
    placement — required when state crosses mesh topologies (a failover's
    survivor mesh rejects buffers still committed to the dead one)."""
    return jnp.asarray(np.asarray(x))


def shard_extents(cfg: EngineConfig, n_shards: int) -> tuple[int, int]:
    """(slots_per_shard, heap_per_shard); raises unless both divide evenly."""
    if cfg.n_slots % n_shards or cfg.heap_slots % n_shards:
        raise ValueError(
            f"n_slots={cfg.n_slots} / heap_slots={cfg.heap_slots} must be "
            f"divisible by n_shards={n_shards}")
    return cfg.n_slots // n_shards, cfg.heap_slots // n_shards


def sharded_store_init(cfg: EngineConfig, n_shards: int) -> StoreState:
    """Like ``store_init`` but with a per-shard heap bump cursor (n_shards,).

    ``ptr`` holds *shard-local* heap indices; arrays keep their global length
    and are block-partitioned by the ``shard_map`` in ``apply_batch_sharded``.
    """
    shard_extents(cfg, n_shards)
    st = engine.store_init(cfg)
    return dataclasses.replace(st, heap_top=jnp.zeros((n_shards,), jnp.int32))


def sharded_populate(cfg: EngineConfig, n_shards: int, state: StoreState,
                     keys, values) -> StoreState:
    """Bulk-load distinct KV pairs, packing each shard's heap separately."""
    per, hper = shard_extents(cfg, n_shards)
    keys = jnp.asarray(keys, jnp.int32)
    values = jnp.asarray(values, jnp.int32)
    n = keys.shape[0]
    owner = keys // per
    pos = jnp.arange(n, dtype=jnp.int32)
    order = jnp.lexsort((pos, owner))
    own_s = owner[order]
    is_first = jnp.concatenate([jnp.ones((1,), bool), own_s[1:] != own_s[:-1]])
    seg = jnp.cumsum(is_first.astype(jnp.int32)) - 1
    seg_start = jax.ops.segment_min(pos, seg, num_segments=n)
    rank = jnp.zeros((n,), jnp.int32).at[order].set(pos - seg_start[seg])
    loc = state.heap_top[owner] + rank                    # shard-local index
    heap = state.heap.at[owner * hper + loc].set(values)
    ptr = state.ptr.at[keys].set(loc)
    counts = jnp.zeros((n_shards,), jnp.int32).at[owner].add(1)
    return dataclasses.replace(state, ptr=ptr, heap=heap,
                               heap_top=state.heap_top + counts)


def sharded_store_view(cfg: EngineConfig, n_shards: int, state: StoreState
                       ) -> tuple[jax.Array, jax.Array]:
    """Logical (exists, value) view of a sharded store (cf. ``store_view``)."""
    per, hper = shard_extents(cfg, n_shards)
    owner = jnp.arange(cfg.n_slots, dtype=jnp.int32) // per
    exists = state.ptr != NULL_PTR
    val = jnp.where(exists,
                    state.heap[owner * hper + jnp.clip(state.ptr, 0)], _NONE)
    return exists, val


def failover_reown(cfg: EngineConfig, n_from: int, state: StoreState,
                   survivors: tuple[int, ...]) -> tuple[StoreState, dict]:
    """Re-own dead shards' slot partitions onto the survivors.

    DINOMO-style elastic failover: when shards die, the surviving shards
    reconstruct the lost partitions from replicas and re-partition the
    store over ``len(survivors)`` shards (which must divide ``n_slots``/
    ``heap_slots``).  The *logical* store — (exists, value) per slot plus
    the slot-indexed ``ver``/``epoch``/``stranded`` planes — carries over
    unchanged; only the physical heap packing is rebuilt, which is exactly
    the freedom the sharded-equivalence contract already grants.  The
    replicated credit table is global, so it survives for free — pass the
    same ``CreditState`` to the post-failover runner.

    Returns ``(new_state, recovery_io)`` where ``new_state`` feeds the
    ``len(survivors)``-way runner and ``recovery_io`` is the control-plane
    recovery bill (replica reads to reconstruct the lost partitions), kept
    OUT of ``IOMetrics`` so the post-failover data-plane bill stays
    bit-equal to a single-device run with the same CN drop mask (asserted
    in ``benchmarks/recovery.py`` and ``tests/test_recovery.py``).
    """
    n_to = len(survivors)
    per_f, _ = shard_extents(cfg, n_from)
    shard_extents(cfg, n_to)
    dead = sorted(set(range(n_from)) - set(survivors))
    if len(set(survivors)) != n_to or any(s not in range(n_from)
                                          for s in survivors):
        raise ValueError(f"survivors {survivors!r} must be distinct shards "
                         f"of the {n_from}-way store")
    exists, val = sharded_store_view(cfg, n_from, state)
    exists, val = np.asarray(exists), np.asarray(val)
    keys = np.flatnonzero(exists)
    new = sharded_populate(cfg, n_to, sharded_store_init(cfg, n_to),
                           keys, val[keys])
    new = dataclasses.replace(new, meta=host_rehome(state.meta),
                              epoch=host_rehome(state.epoch))
    lost_live = int(exists.reshape(n_from, per_f)[dead].sum()) if dead else 0
    recovery_io = {
        "dead_shards": dead,
        "survivors": list(survivors),
        # one replica READ per lost pointer slot + one per live lost value
        "reown_reads": len(dead) * per_f + lost_live,
        "reown_bytes": (len(dead) * per_f * cfg.ptr_bytes
                        + lost_live * cfg.value_bytes),
    }
    return new, recovery_io


def promote_replica(cfg: EngineConfig, state: StoreState,
                    survivors: tuple[int, ...], dead_replicas: tuple[int, ...],
                    ) -> tuple[StoreState, dict]:
    """Promote a surviving replica MN after replica deaths (DESIGN.md §13).

    SNAPSHOT client-centric replication keeps every replica's logical store
    identical — each acked write hit all R replicas before completing, and
    window-granular execution means no write is mid-fan-out at a window
    boundary — so promotion moves **no data**: clients drop the dead
    replicas from their replica lists and re-point reads at the lowest
    surviving replica.  What failover must still do is re-run the §4.6
    orphaned-lock repair against the promoted replica: every lock the CN
    liveness plane has stranded (``StoreState.stranded``) was recorded
    against the old primary's lock words, so the promoted replica's copies
    are re-armed with one break CAS each, and the whole lock plane is swept
    (one lock-entry READ per slot) to certify that no acquisition was
    mid-fan-out when the replica died.

    Control-plane only: the returned state is the input state (the lazy
    in-band repair contract is untouched — the next locker of a stranded
    slot still breaks and bills it), and the sweep's bill is returned as a
    ``recovery_io`` dict kept OUT of ``IOMetrics`` — which is exactly why
    the post-failover data-plane bill is bit-equal to a plain segmented run
    that swaps ``EngineConfig.n_replicas`` at the crash window (asserted in
    ``benchmarks/replication.py`` and ``tests/test_replication.py``).
    """
    dead = sorted(dead_replicas)
    if not survivors:
        raise ValueError("promote_replica: no surviving replica")
    if set(dead) & set(survivors):
        raise ValueError(f"replicas {sorted(set(dead) & set(survivors))} "
                         f"listed both dead and surviving")
    stranded = int(np.asarray(state.stranded).sum())
    recovery_io = {
        "dead_replicas": dead,
        "survivors": sorted(survivors),
        "promoted": min(survivors),
        # one lock-entry READ per slot on the promoted replica (the
        # mid-fan-out certification sweep) ...
        "promote_reads": cfg.n_slots,
        "promote_bytes": cfg.n_slots * cfg.lock_bytes,
        # ... plus one break CAS re-arming each CN-stranded lock on every
        # surviving replica's copy of the word
        "repair_rearm_cas": stranded * len(survivors),
    }
    return state, recovery_io


def _psum_results(res: Results, axis: str) -> Results:
    """Reassemble exact per-op results across shards: non-owning shards emit
    each field's neutral element, so one psum (offset for the non-zero
    defaults) recovers the single-device values.  Elementwise, so it works
    unchanged on window-stacked ``(W, B)`` results."""
    def psum(x):
        return jax.lax.psum(x, axis)
    return Results(
        ok=psum(res.ok.astype(jnp.int32)) > 0,
        value=psum(res.value - _NONE) + _NONE,
        pessimistic=psum(res.pessimistic.astype(jnp.int32)) > 0,
        combined=psum(res.combined.astype(jnp.int32)) > 0,
        wc_batch=psum(res.wc_batch - 1) + 1,
        retries=psum(res.retries),
        rank=psum(res.rank),
        orphan_wait=psum(res.orphan_wait),
        # each shard counts the rows of its own sub-run of a cross-shard
        # SCAN (run split at partition boundaries, DESIGN.md §9)
        rows=psum(res.rows),
    )


def _store_spec(axis: str) -> StoreState:
    return StoreState(ptr=P(axis), meta=P(axis), epoch=P(axis),
                      heap=P(axis), heap_top=P(axis))


@functools.lru_cache(maxsize=None)
def _sharded_fn(cfg: EngineConfig, mesh, axis: str):
    n_shards = int(mesh.shape[axis])
    per, hper = shard_extents(cfg, n_shards)
    lcfg = dataclasses.replace(cfg, n_slots=per, heap_slots=hper)
    st_spec = _store_spec(axis)

    def run(state, credits, batch, valid):
        base = jax.lax.axis_index(axis).astype(jnp.int32) * per
        owned = (batch.keys >= base) & (batch.keys < base + per)
        st = dataclasses.replace(state, heap_top=state.heap_top[0])
        st2, cr2, res, io = engine.apply_batch(
            lcfg, st, credits, batch, valid=valid, owned=owned,
            slot_base=base)
        st2 = dataclasses.replace(st2, heap_top=st2.heap_top[None])
        return (st2, cr2, _psum_results(res, axis),
                jax.tree.map(lambda x: jax.lax.psum(x, axis), io))

    fn = shard_map(run, mesh=mesh,
                   in_specs=(st_spec, P(), P(), P()),
                   out_specs=(st_spec, P(), P(), P()),
                   check_rep=False)
    return jax.jit(fn)


@functools.lru_cache(maxsize=None)
def _sharded_stream_fn(cfg: EngineConfig, mesh, axis: str,
                       io_per_window: bool, traced: bool = False,
                       per_shard_io: bool = False):
    n_shards = int(mesh.shape[axis])
    per, hper = shard_extents(cfg, n_shards)
    lcfg = dataclasses.replace(cfg, n_slots=per, heap_slots=hper)
    st_spec = _store_spec(axis)

    def run(state, credits, stream, prev_alive):
        base = jax.lax.axis_index(axis).astype(jnp.int32) * per

        def step(carry, win):
            st, cr, prev = carry
            batch, valid, alive = win
            owned = (batch.keys >= base) & (batch.keys < base + per)
            died = prev & ~alive
            st, cr, res, io = engine.apply_batch(
                lcfg, st, cr, batch, valid=valid, owned=owned,
                slot_base=base, alive=alive, died=died)
            out = (res, io, jnp.sum(cr.credit)) if traced else (res, io)
            return (st, cr, alive), out

        st = dataclasses.replace(state, heap_top=state.heap_top[0])
        (st, cr, _), outs = jax.lax.scan(
            step, (st, credits, prev_alive),
            (stream.batch, stream.valid, stream.alive))
        ress, ios = outs[0], outs[1]
        st = dataclasses.replace(st, heap_top=st.heap_top[None])
        if not io_per_window:
            ios = jax.tree.map(lambda x: jnp.sum(x, axis=0), ios)
        if per_shard_io:
            # keep every field at exactly ONE psum (the collective census in
            # repro.analysis.jaxpr_check forbids all_gather): each shard
            # scatters its local bill into its own onehot slot, the psum
            # assembles the (..., n_shards) plane, and summing that plane
            # recovers the replicated global bill bit-exactly (asserted by
            # tests/test_dist_store.py) — the weak-scaling benchmark needs
            # the per-shard split because mesh throughput is bound by the
            # HOTTEST shard's NIC, not the sum.
            onehot = (jnp.arange(n_shards, dtype=jnp.int32)
                      == jax.lax.axis_index(axis)).astype(jnp.int32)
            ios = jax.tree.map(
                lambda x: jax.lax.psum(x[..., None] * onehot.astype(x.dtype),
                                       axis), ios)
        else:
            ios = jax.tree.map(lambda x: jax.lax.psum(x, axis), ios)
        res_io = (st, cr, _psum_results(ress, axis), ios)
        # credit mass is computed from the replicated credit table, so every
        # shard already holds the identical (W,) trajectory
        return res_io + (outs[2],) if traced else res_io

    out_specs = (st_spec, P(), P(), P()) + ((P(),) if traced else ())
    fn = shard_map(run, mesh=mesh,
                   in_specs=(st_spec, P(), P(), P()),
                   out_specs=out_specs,
                   check_rep=False)
    return jax.jit(fn, donate_argnums=(0, 1))


def apply_batch_sharded(cfg: EngineConfig, mesh, state: StoreState,
                        credits, batch: OpBatch,
                        valid: jax.Array | None = None, *, axis: str = "data"
                        ) -> tuple[StoreState, CreditState, Results, IOMetrics]:
    """``engine.apply_batch`` under shard_map on ``mesh.shape[axis]`` shards.

    Drop-in equivalent of the single-device engine (same signature modulo
    mesh); ``state`` must come from ``sharded_store_init``/``sharded_populate``.
    """
    if valid is None:
        valid = batch.kinds != OpKind.NOP
    return _sharded_fn(cfg, mesh, axis)(state, credits, batch, valid)


def run_windows_sharded(cfg: EngineConfig, mesh, state: StoreState,
                        credits, stream: WindowStream, *, axis: str = "data",
                        io_per_window: bool = False,
                        per_shard_io: bool = False,
                        prev_alive: jax.Array | None = None
                        ) -> tuple[StoreState, CreditState, Results, IOMetrics]:
    """Sharded ``repro.core.runner.run_windows``: every window of ``stream``
    executes inside one ``lax.scan`` under one ``shard_map``.

    The credit plane is replicated per window exactly as in
    ``apply_batch_sharded`` — each scan step re-derives its ``owned`` mask
    from that window's keys and runs the full-batch credit decision/feedback,
    so per-window ``Results``, per-window I/O (``io_per_window=True``), the
    credit table, and the store view are bit-identical to the single-device
    ``run_windows`` (tested in ``tests/test_runner.py``).  ``state`` and
    ``credits`` are donated.  ``prev_alive`` overrides the liveness row
    assumed before window 0 (see ``runner._prev_alive``) so a run split
    around a shard failover still strands crashes at the boundary.

    ``per_shard_io=True`` appends a trailing ``(n_shards,)`` axis to every
    ``IOMetrics`` field — shard ``s``'s slice is the bill its own partition
    served, and the sum over shards equals the replicated global bill.  The
    weak-scaling benchmark divides by the hottest shard's service time, since
    parallel MN NICs serve their partitions concurrently.
    """
    return _sharded_stream_fn(cfg, mesh, axis, io_per_window,
                              per_shard_io=per_shard_io)(
        state, credits, stream, _prev_alive(stream, prev_alive))


def run_windows_sharded_traced(cfg: EngineConfig, mesh, state: StoreState,
                               credits, stream: WindowStream, *,
                               axis: str = "data",
                               prev_alive: jax.Array | None = None
                               ) -> tuple[StoreState, CreditState, Results, IOMetrics,
                                          jax.Array]:
    """Sharded ``repro.core.runner.run_windows_traced``: returns
    ``(state, credits, results, io_per_window, credit_mass)`` with the
    ``(W,)`` per-window credit-table mass taken from the replicated credit
    plane (identical on every shard), matching the single-device trace
    bit-exactly.  ``state`` and ``credits`` are donated."""
    return _sharded_stream_fn(cfg, mesh, axis, True, traced=True)(
        state, credits, stream, _prev_alive(stream, prev_alive))
