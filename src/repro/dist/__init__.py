"""The distribution layer: mesh context, logical-axis sharding rules,
shard_map compute paths, cross-node gradient compression, and the sharded
CIDER dataplane.

Layering (see DESIGN.md §3):

  models/* ──shard(x, logical_axes)──▶ dist.ctx ──spec_for──▶ dist.sharding
  launch/* ──param/batch/state shardings───────────────────▶ dist.sharding
  dist.decode_attn   — one-pass shard_map decode attention (cache-sharded)
  dist.embed_grad    — write-combined sparse embedding gradients (§4.2 idea)
  dist.compress      — int8 + error-feedback gradient compression
  dist.store         — StoreState partitioned over the "data" mesh axis;
                       engine.apply_batch under shard_map, ops routed to
                       their owning shard

Everything degrades to a no-op / single-shard path without a mesh, so the
same model and engine code runs on one CPU device and on a multi-pod mesh.
"""
