"""Deterministic, resumable, per-host-sharded synthetic data pipeline.

Counter-based stateless RNG: batch ``i`` of host ``h`` is a pure function of
(seed, i, h) — restart-at-step-k needs no state beyond the step counter
(fault tolerance, DESIGN.md §5).  Token streams are Zipf-distributed (the
skewed-id regime the CIDER embedding-gradient combiner targets).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.workloads.zipf import sample_zipf_jax, zipf_cdf_table

__all__ = ["DataConfig", "Pipeline"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    n_hosts: int = 1
    host_id: int = 0
    theta: float = 1.0       # token-frequency skew (~natural language)
    seed: int = 0


class Pipeline:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        if cfg.global_batch % cfg.n_hosts:
            raise ValueError("global_batch must divide over hosts")
        self.per_host = cfg.global_batch // cfg.n_hosts
        self._cdf = jnp.asarray(zipf_cdf_table(cfg.vocab, cfg.theta))

    def batch_at(self, step: int) -> dict:
        """The (host-local) batch for ``step`` — pure function of step."""
        cfg = self.cfg
        key = jax.random.fold_in(jax.random.fold_in(
            jax.random.key(cfg.seed), step), cfg.host_id)
        toks = sample_zipf_jax(key, (self.per_host, cfg.seq_len + 1),
                               self._cdf, cfg.vocab)
        toks = toks.astype(jnp.int32) % cfg.vocab
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}
