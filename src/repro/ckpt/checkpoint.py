"""Sharded checkpointing with elastic restore.

Format: one ``.npz`` per (host) shard + a JSON manifest keyed by LOGICAL
leaf path — restore re-slices by logical shape, so a checkpoint written on
one mesh restores onto any other (elastic scaling).  ``save_async`` moves
serialization off the training critical path (the step only blocks on the
previous save's completion — checkpoint/restart per DESIGN.md §5).
"""
from __future__ import annotations

import json
import os
import threading
from typing import Any

import jax
import numpy as np

__all__ = ["save", "restore", "AsyncCheckpointer"]


def _flatten(tree) -> dict[str, Any]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out


def save(path: str, step: int, tree) -> None:
    os.makedirs(path, exist_ok=True)
    flat = _flatten(tree)
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    np.savez(os.path.join(path, f"step_{step:08d}.npz"), **arrays)
    manifest = {
        "step": step,
        "leaves": {k: {"shape": list(a.shape), "dtype": str(a.dtype)}
                   for k, a in arrays.items()},
    }
    tmp = os.path.join(path, "manifest.json.tmp")
    with open(tmp, "w") as f:
        json.dump(manifest, f, indent=1)
    os.replace(tmp, os.path.join(path, "manifest.json"))


def latest_step(path: str) -> int | None:
    mf = os.path.join(path, "manifest.json")
    if not os.path.exists(mf):
        return None
    with open(mf) as f:
        return json.load(f)["step"]


def restore(path: str, tree_like, shardings=None, step: int | None = None):
    """Restore into the structure of ``tree_like`` (values replaced).  With
    ``shardings`` (a matching tree of NamedShardings for the CURRENT mesh),
    arrays are placed shard-by-shard — the mesh may differ from the one that
    wrote the checkpoint (elastic restore)."""
    step = latest_step(path) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {path}")
    data = np.load(os.path.join(path, f"step_{step:08d}.npz"))
    flat_names = list(_flatten(tree_like).keys())
    leaves, treedef = jax.tree_util.tree_flatten(tree_like)
    sh_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                 if shardings is not None else [None] * len(leaves))
    out = []
    for name, like, sh in zip(flat_names, leaves, sh_leaves):
        arr = data[name]
        tgt = np.dtype(like.dtype)
        if arr.dtype.kind == "V" and arr.dtype.itemsize == tgt.itemsize:
            # npz stores ml_dtypes (bfloat16, ...) as raw void bytes; the
            # payload is exact, only the descriptor is lost — reinterpret
            arr = arr.view(tgt)
        if list(arr.shape) != list(like.shape):
            raise ValueError(f"{name}: ckpt {arr.shape} != model {like.shape}")
        a = jax.device_put(arr.astype(like.dtype), sh) if sh is not None \
            else jax.numpy.asarray(arr.astype(like.dtype))
        out.append(a)
    return jax.tree_util.tree_unflatten(treedef, out), step


class AsyncCheckpointer:
    """Fire-and-forget saves; ``wait()`` joins the in-flight save."""

    def __init__(self, path: str):
        self.path = path
        self._thread: threading.Thread | None = None

    def save_async(self, step: int, tree) -> None:
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot before mutation
        self._thread = threading.Thread(
            target=save, args=(self.path, step, host_tree), daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
