"""Continuous-batching serving scheduler with a CIDER-managed prefix cache.

Host-side control loop (the device side is ``serve_step``): admits requests
into free decode slots, allocates KV pages from a free list, consults the
``PageTable`` for shared-prefix hits (skipping prefill for cached blocks),
and recycles pages on completion (DELETE -> eviction when refcount drops).

DESIGN.md §1 (serving layer): host-side continuous-batching loop over the
CIDER-managed prefix cache (pagetable, §2.1).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.serving.pagetable import PageTable

__all__ = ["Request", "Scheduler"]


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray           # prompt
    max_new: int
    # runtime state
    pages: list = dataclasses.field(default_factory=list)
    pos: int = 0
    out: list = dataclasses.field(default_factory=list)
    done: bool = False
    cached_blocks: int = 0


class Scheduler:
    def __init__(self, n_slots: int, n_pages: int, page_size: int,
                 table: PageTable | None = None):
        self.n_slots = n_slots
        self.page_size = page_size
        self.free_pages = list(range(n_pages))
        self.slots: list[Request | None] = [None] * n_slots
        self.queue: list[Request] = []
        self.table = table or PageTable.create(block_tokens=page_size)
        self.stats = {"prefix_hits": 0, "prefix_misses": 0, "admitted": 0,
                      "completed": 0}

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self, req: Request) -> bool:
        keys = self.table.block_keys(req.tokens)
        pages_needed = (len(req.tokens) + req.max_new) // self.page_size + 1
        if len(self.free_pages) < pages_needed:
            return False
        if len(keys):
            page_ids, hits, _ = self.table.lookup(keys)
            n_hit = int(np.cumprod(hits).sum()) if len(hits) else 0
        else:
            page_ids, n_hit = np.array([]), 0
        req.cached_blocks = n_hit
        self.stats["prefix_hits"] += n_hit
        self.stats["prefix_misses"] += max(len(keys) - n_hit, 0)
        # reuse hit pages; allocate the rest
        req.pages = [int(page_ids[i]) for i in range(n_hit)]
        for _ in range(pages_needed - n_hit):
            req.pages.append(self.free_pages.pop())
        req.pos = len(req.tokens)
        # publish newly prefilled blocks (combined by CIDER under contention)
        fresh = keys[n_hit:]
        if len(fresh):
            self.table.publish(fresh, req.pages[n_hit:n_hit + len(fresh)])
        self.stats["admitted"] += 1
        return True

    def step_admit(self):
        for i in range(self.n_slots):
            if self.slots[i] is None and self.queue:
                req = self.queue[0]
                if self._admit(req):
                    self.queue.pop(0)
                    self.slots[i] = req
                else:
                    break

    def active(self) -> list[tuple[int, Request]]:
        return [(i, r) for i, r in enumerate(self.slots) if r is not None]

    def complete_token(self, slot: int, token: int):
        req = self.slots[slot]
        req.out.append(token)
        req.pos += 1
        if len(req.out) >= req.max_new:
            req.done = True
            self.stats["completed"] += 1
            # release non-shared pages (shared prefix pages stay published)
            for p in req.pages[req.cached_blocks:]:
                self.free_pages.append(p)
            self.slots[slot] = None
