"""The serving-side CIDER integration: a prefix-cache page table managed by
the CIDER store engine (DESIGN.md §2.1).

Page-table entries ARE data pointers: key = hash of a prefix block of
tokens; value = page id in the paged KV pool.  Concurrent requests from many
serving workers do SEARCH (prefix hit), INSERT (publish a prefilled page)
and DELETE (eviction) against a shared table with extreme skew (everyone
shares the system-prompt prefix) — exactly the workload of §2.2, so the
table runs on ``repro.core.engine`` with ``SyncMode.CIDER``: hot prefix
publishes get write-combined; cold entries stay optimistic.
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax.numpy as jnp

from repro.core.types import IOMetrics, OpBatch, OpKind, SyncMode
from repro.stores.pointer_array import PointerArray

__all__ = ["PageTable"]


def _prefix_hash(tokens: np.ndarray) -> int:
    h = 1469598103934665603
    for t in tokens.tolist():
        h = ((h ^ (t + 1)) * 1099511628211) & 0x7FFFFFFF
    return h


@dataclasses.dataclass
class PageTable:
    store: PointerArray
    block_tokens: int               # tokens per prefix block (== page size)

    @staticmethod
    def create(n_slots: int = 1 << 16, block_tokens: int = 16,
               mode: SyncMode = SyncMode.CIDER) -> "PageTable":
        return PageTable(store=PointerArray.create(n_slots, mode=mode),
                         block_tokens=block_tokens)

    def block_keys(self, tokens: np.ndarray) -> np.ndarray:
        """Rolling prefix-block keys for a token sequence."""
        n = len(tokens) // self.block_tokens
        return np.asarray([_prefix_hash(tokens[:(i + 1) * self.block_tokens])
                           % self.store.cfg.n_slots for i in range(n)],
                          np.int32)

    def lookup(self, keys) -> tuple[np.ndarray, np.ndarray, IOMetrics]:
        """Batch SEARCH: returns (page_ids, hit_mask, io)."""
        keys = np.asarray(keys, np.int32)
        kinds = np.full(keys.shape[0], OpKind.SEARCH, np.int32)
        batch = OpBatch.make(kinds, keys, np.zeros_like(keys))
        store, res, io = self.store.apply(batch)
        self.store = store
        return np.asarray(res.value), np.asarray(res.ok), io

    def publish(self, keys, pages, n_cns: int = 1
                ) -> tuple[np.ndarray, IOMetrics]:
        """Batch INSERT of freshly prefilled pages (combined under CIDER)."""
        keys = np.asarray(keys, np.int32)
        kinds = np.full(keys.shape[0], OpKind.INSERT, np.int32)
        batch = OpBatch.make(kinds, keys, np.asarray(pages, np.int32),
                             n_cns=n_cns)
        store, res, io = self.store.apply(batch)
        self.store = store
        return np.asarray(res.ok), io

    def evict(self, keys) -> tuple[np.ndarray, IOMetrics]:
        keys = np.asarray(keys, np.int32)
        kinds = np.full(keys.shape[0], OpKind.DELETE, np.int32)
        batch = OpBatch.make(kinds, keys, np.zeros_like(keys))
        store, res, io = self.store.apply(batch)
        self.store = store
        return np.asarray(res.ok), io
