"""Launcher-level fault tolerance — the paper's epoch protocol (§4.6) lifted
to the training fleet.

Each worker FAAs a heartbeat epoch after every step (exactly the lock-epoch
discipline: progress == epoch advance).  The monitor declares a worker dead
when its epoch is stale for ``max_wait_s`` — the deadlock-detection rule —
then shrinks the active set and signals a restore-from-checkpoint onto the
surviving mesh (elastic restore, see ``repro.ckpt``).  A worker that never
beats at all counts as stale from *monitor start*: silence is death, not
innocence (the engine-path analogue is a CN that crashes before its first
epoch FAA — ``repro.recovery``).

Straggler mitigation: per-step deadline = ``straggler_factor`` x that
worker's OWN EWMA step time; a worker that repeatedly misses it is excluded
(same mechanism, softer penalty).  The EWMA is per-worker and deadline-
missing samples are NOT folded into it — a fleet-global EWMA lets one slow
worker inflate the shared average and mask itself, and folding the strike
sample in lets a degrading worker ratchet its own deadline up.

DESIGN.md §8 (crash recovery): launcher-fleet heartbeat/straggler monitor —
the §4.6 epoch idea at training scale.
"""
from __future__ import annotations

import dataclasses
import time

__all__ = ["Heartbeat", "FleetMonitor"]


@dataclasses.dataclass
class Heartbeat:
    worker: int
    epoch: int = 0
    t: float = 0.0

    def beat(self, now: float | None = None):
        self.epoch += 1                      # the RDMA_FAA analogue
        self.t = time.monotonic() if now is None else now


class FleetMonitor:
    def __init__(self, n_workers: int, max_wait_s: float = 60.0,
                 straggler_factor: float = 3.0, strikes: int = 3,
                 now: float | None = None):
        t0 = time.monotonic() if now is None else now
        # never-beaten workers age from monitor start (epoch stays 0)
        self.hb = {w: Heartbeat(w, t=t0) for w in range(n_workers)}
        self.max_wait_s = max_wait_s
        self.straggler_factor = straggler_factor
        self.strikes = strikes
        self._miss: dict[int, int] = dict.fromkeys(range(n_workers), 0)
        self._ewma: dict[int, float | None] = dict.fromkeys(range(n_workers))
        self.excluded: set[int] = set()

    def beat(self, worker: int, step_time_s: float | None = None,
             now: float | None = None):
        self.hb[worker].beat(now)
        if step_time_s is None:
            return
        ewma = self._ewma[worker]
        if ewma is not None and step_time_s > self.straggler_factor * ewma:
            # a strike: count it, but keep the sample OUT of the EWMA so the
            # deadline doesn't drift up toward the degraded pace
            self._miss[worker] += 1
            if self._miss[worker] >= self.strikes:
                self.excluded.add(worker)    # straggler: route around it
        else:
            self._ewma[worker] = step_time_s if ewma is None \
                else 0.9 * ewma + 0.1 * step_time_s
            self._miss[worker] = 0

    def dead_workers(self, now: float | None = None) -> list[int]:
        """Epoch stale for max_wait -> deadlock/death declared (§4.6).
        A worker that never beat is stale relative to monitor start."""
        now = time.monotonic() if now is None else now
        return [w for w, h in self.hb.items()
                if w not in self.excluded and now - h.t > self.max_wait_s]

    def active_set(self, now: float | None = None) -> list[int]:
        dead = set(self.dead_workers(now))
        return [w for w in self.hb if w not in dead and w not in self.excluded]
