"""SMART-style adaptive radix tree index (Luo et al., OSDI'23), CIDER-integrated.

Layer: stores (DESIGN.md §1, §9) — contract: resolve string keys to engine
slots, account index-side I/O, and (radix only) resolve key ranges to
contiguous leaf-slot runs for SCAN.

SMART stores data pointers in radix-tree leaves; clients cache internal
nodes, so the common-case I/O is a leaf READ + the pointer swap — exactly
CIDER's integration point.  We model a fixed-span (8-bit), fixed-depth radix
tree over a ``key_bits``-sized key space:

* the leaf entry address is a *bijective* function of the key (the radix
  path IS the key), so the leaf entry is the engine slot — no reservation
  protocol is needed (unlike the hash index) and structural node splits
  never move leaves;
* radix paths sort in key order, so leaf entries sit in key order and the
  key range ``[k, k+c)`` is a *contiguous leaf-slot run* — the range-scan
  property that separates radix indexes from hash indexes (DESIGN.md §9)
  and the reason this store alone serves ``OpKind.SCAN``;
* per-op index I/O: ``path_misses`` uncached internal-node READs (client
  path cache, SMART §3) + the leaf read; defaults model a warm cache.

Simplifications vs SMART (documented): adaptive node sizes (ART Node4/16/48)
and path compression only change *node bytes*, not the leaf-level concurrency
CIDER optimizes; we fix 256-ary nodes and fold cache-miss traffic into
``index_read_iops``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import engine, runner
from repro.core.credits import CreditState, credit_init
from repro.core.types import EngineConfig, IOMetrics, OpBatch, SyncMode

__all__ = ["SmartART"]


def _radix_slot(keys: jax.Array, key_bits: int) -> jax.Array:
    """Leaf-entry address of a key: the radix path IS the key (fixed span,
    fixed depth), so leaf entries are laid out in key order and a key range
    maps to a contiguous slot run — the property SCAN traversal needs.
    Earlier revisions bit-reversed the path to spread adjacent keys across
    leaf nodes; that permutation is exactly what makes hash-style layouts
    range-incapable, and real radix trees do not do it."""
    return (keys.astype(jnp.int32)) & jnp.int32((1 << key_bits) - 1)


@dataclasses.dataclass
class SmartART:
    cfg: EngineConfig
    key_bits: int
    state: engine.StoreState
    credits: CreditState

    @staticmethod
    def create(key_bits: int = 20, mode: SyncMode = SyncMode.CIDER,
               path_misses: int = 0, credit_table: int = 4096,
               scan_max: int = 16, **kw) -> "SmartART":
        n_slots = 1 << key_bits
        cfg = EngineConfig(n_slots=n_slots, heap_slots=4 * n_slots, mode=mode,
                           index_read_iops=1 + path_misses,
                           index_read_bytes=8 + 256 * 8 * path_misses,
                           scan_max=scan_max, **kw)
        return SmartART(cfg=cfg, key_bits=key_bits,
                        state=engine.store_init(cfg),
                        credits=credit_init(credit_table))

    def slots(self, keys) -> jax.Array:
        return _radix_slot(jnp.asarray(keys, jnp.int32), self.key_bits)

    def populate(self, keys, values) -> "SmartART":
        state = engine.populate(self.cfg, self.state, self.slots(keys), values)
        return dataclasses.replace(self, state=state)

    def apply(self, kinds, keys, values, n_cns: int = 1
              ) -> tuple["SmartART", engine.Results, IOMetrics]:
        kinds = jnp.asarray(kinds, jnp.int32)
        values = jnp.asarray(values, jnp.int32)
        batch = OpBatch.make(kinds, self.slots(keys), values, n_cns=n_cns)
        state, credits, res, io = engine.apply_batch(
            self.cfg, self.state, self.credits, batch)
        return dataclasses.replace(self, state=state, credits=credits), res, io

    def apply_stream(self, kinds, keys, values, n_cns: int = 1,
                     io_per_window: bool = False
                     ) -> tuple["SmartART", engine.Results, IOMetrics]:
        """Fused multi-window execution of ``(W, B)`` op arrays: keys resolve
        through the radix path, then one ``run_windows`` scan executes every
        window on-device.  Buffers are donated — use the returned instance.
        """
        kinds = jnp.asarray(kinds, jnp.int32)
        values = jnp.asarray(values, jnp.int32)
        stream = runner.make_stream(kinds, self.slots(keys), values,
                                    n_cns=n_cns)
        state, credits, res, io = runner.run_windows(
            self.cfg, self.state, self.credits, stream,
            io_per_window=io_per_window)
        return dataclasses.replace(self, state=state, credits=credits), res, io

    def view(self):
        return engine.store_view(self.state)
