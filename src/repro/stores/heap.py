"""Out-of-place value heap notes.

The engine embeds a bump-allocated heap (``StoreState.heap``) because the
paper's out-of-place update protocol never reuses a block within a
synchronization window (writers allocate, then swing the pointer).  Value
*size* enters the system only through wire bytes (``EngineConfig.value_bytes``
/ ``SimParams.value_bytes``) — the paper's appendix (Fig 24) shows all
schemes are IOPS-bound, not bandwidth-bound, which our two-resource NIC model
(verb tokens + byte tokens) reproduces.

``reclaim`` is provided for long-running loops: compacts live blocks and
rewrites pointers (host-side, amortized; DM systems do this with epoch-based
GC off the critical path).

DESIGN.md §2 (engine conventions): out-of-place bump heap + offline reclaim
preserving the logical store view.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core.engine import NULL_PTR, StoreState

__all__ = ["reclaim"]


def reclaim(state: StoreState) -> StoreState:
    """Compact the heap: keep only blocks referenced by live pointers."""
    live = state.ptr != NULL_PTR
    n_slots = state.ptr.shape[0]
    order = jnp.nonzero(live, size=n_slots, fill_value=n_slots)[0]
    src = jnp.where(order < n_slots, state.ptr[jnp.clip(order, 0, n_slots - 1)], 0)
    n_live = jnp.sum(live.astype(jnp.int32))
    heap = jnp.full_like(state.heap, -1)
    idx = jnp.arange(n_slots)
    heap = heap.at[jnp.where(idx < n_live, idx, heap.shape[0])].set(
        state.heap[src], mode="drop")
    new_ptr = jnp.full_like(state.ptr, NULL_PTR)
    new_ptr = new_ptr.at[jnp.where(order < n_slots, order, n_slots)].set(
        idx.astype(jnp.int32), mode="drop")
    return dataclasses.replace(state, ptr=new_ptr, heap=heap, heap_top=n_live)
