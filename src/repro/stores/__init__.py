"""Array-backed store / index structures on the (simulated) memory pool.

* ``pointer_array`` — the paper's micro-benchmark object store (§5.2): one
  data pointer per key, zero index I/O beyond the pointer read.
* ``race_hash`` — RACE-style two-choice hash index (ATC'21): keys resolve to
  slots via two candidate buckets read per lookup.
* ``smart_art`` — SMART-style radix tree (OSDI'23): keys resolve through a
  fixed-span radix path with client-side path caching.

All indexes resolve keys to *slots* and meter their own index I/O; slot-level
synchronization (the paper's contribution) is delegated to
``repro.core.engine`` at the data-pointer level — exactly CIDER's integration
point ("all memory-disaggregated systems with optimistic out-of-place
modification", §4.4).

DESIGN.md §1 (stores layer): index structures resolving keys to engine
slots; only the radix store serves SCAN (§9.1).
"""
from repro.stores.pointer_array import PointerArray
from repro.stores.race_hash import RaceHash
from repro.stores.smart_art import SmartART

__all__ = ["PointerArray", "RaceHash", "SmartART"]
