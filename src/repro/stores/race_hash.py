"""RACE-style two-choice hash index (Zuo et al., ATC'21), CIDER-integrated.

RACE keeps KV pointers in hash *slots*; lookups read two candidate bucket
groups with one-sided READs and modify slots with RDMA_CAS.  We reproduce the
I/O pattern and the slot-level concurrency:

* two candidate buckets per key (h1/h2), ``ways`` slots per bucket;
* SEARCH/UPDATE/DELETE read both bucket groups (2 READs, bucket bytes each);
* INSERT claims a free way in the emptier candidate (two-choice), then runs
  the engine's INSERT on that slot — concurrent same-key INSERTs race on one
  slot (one winner, §4.2.2), concurrent distinct-key INSERTs into one bucket
  claim distinct ways (rank-ordered, as CAS order would).

Resizing (directory doubling) is out of scope: CIDER integrates at the
pointer-swap level (§4.4) and the paper holds table capacity fixed; inserts
into a full bucket pair fail with ``overflow``.

SCAN is rejected (DESIGN.md §9): a hash index scatters adjacent keys across
unrelated buckets, so a key range has no contiguous slot run to traverse —
the FlexKV/Outback motivation for pairing DM stores with a range-capable
radix index (``repro.stores.SmartART``) when the workload scans.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import combine as wc
from repro.core import engine
from repro.core.credits import CreditState, credit_init
from repro.core.types import (EngineConfig, IOMetrics, OpBatch, OpKind,
                              SyncMode, UnsupportedOpError)

__all__ = ["RaceHash"]

_EMPTY = jnp.int32(-1)


def _h(keys, seed, n_buckets):
    x = keys.astype(jnp.uint32) * jnp.uint32(seed)
    x = x ^ (x >> 15)
    x = x * jnp.uint32(0x2C1B3C6D)
    x = x ^ (x >> 12)
    return (x % jnp.uint32(n_buckets)).astype(jnp.int32)


@dataclasses.dataclass
class RaceHash:
    cfg: EngineConfig
    n_buckets: int
    ways: int
    slot_keys: jax.Array          # (n_buckets*ways,) stored key or -1
    state: engine.StoreState
    credits: CreditState

    @staticmethod
    def create(capacity: int, mode: SyncMode = SyncMode.CIDER, ways: int = 8,
               credit_table: int = 4096, **kw) -> "RaceHash":
        n_buckets = max(capacity // ways, 2)
        # +1: a permanently-empty tombstone slot that absent-key SEARCH /
        # UPDATE / DELETE ops resolve to (they must fail, and do — the engine
        # rejects non-INSERT ops on an empty slot)
        n_slots = n_buckets * ways + 1
        cfg = EngineConfig(n_slots=n_slots, heap_slots=4 * n_slots, mode=mode,
                           index_read_iops=2, index_read_bytes=16 * ways, **kw)
        return RaceHash(cfg=cfg, n_buckets=n_buckets, ways=ways,
                        slot_keys=jnp.full((n_buckets * ways,), _EMPTY, jnp.int32),
                        state=engine.store_init(cfg),
                        credits=credit_init(credit_table))

    # ------------------------------------------------------------------
    def _buckets(self, keys):
        return _h(keys, 0x9E3779B1, self.n_buckets), _h(keys, 0x85EBCA77, self.n_buckets)

    def locate(self, keys: jax.Array) -> tuple[jax.Array, jax.Array]:
        """Resolve keys to slots: returns (slot, found)."""
        b1, b2 = self._buckets(keys)
        w = self.ways
        rows = jnp.stack([b1, b2], 1)                       # (B, 2)
        cand = rows[:, :, None] * w + jnp.arange(w)         # (B, 2, w)
        ck = self.slot_keys[cand]                           # (B, 2, w)
        hit = ck == keys[:, None, None]
        found = hit.any((1, 2))
        flat = cand.reshape(keys.shape[0], -1)
        idx = jnp.argmax(hit.reshape(keys.shape[0], -1), axis=1)
        slot = jnp.take_along_axis(flat, idx[:, None], 1)[:, 0]
        return jnp.where(found, slot, 0).astype(jnp.int32), found

    def _reserve(self, keys, mask):
        """Two-choice slot reservation for INSERTs of not-present keys.
        Same-key ops share one candidate slot; distinct keys claiming one
        bucket get distinct free ways (rank order).  Returns (slot, ok)."""
        b = keys.shape[0]
        w = self.ways
        b1, b2 = self._buckets(keys)
        table = self.slot_keys.reshape(self.n_buckets, w)
        occ1 = jnp.sum(table[b1] != _EMPTY, 1)
        occ2 = jnp.sum(table[b2] != _EMPTY, 1)
        bucket = jnp.where(occ2 < occ1, b2, b1)
        # one representative per unique key
        pos = jnp.arange(b, dtype=jnp.int32)
        plan = wc.plan_combine(keys, pos, mask)
        rep_sorted = plan.is_first & mask[plan.perm]
        rep = jnp.zeros((b,), bool).at[plan.perm].set(rep_sorted)
        # rank of representatives within their chosen bucket
        stats = wc.per_key_stats(bucket, pos, rep)
        rank = stats.rank_of
        # rank-th free way of the bucket (ways sorted: free first)
        row = table[bucket]                                  # (B, w)
        way_order = jnp.argsort(jnp.where(row == _EMPTY, 0, 1) * w
                                + jnp.arange(w), axis=1)
        n_free = jnp.sum(row == _EMPTY, 1)
        ok_rep = rep & (rank < n_free)
        way = jnp.take_along_axis(way_order, jnp.minimum(rank, w - 1)[:, None],
                                  1)[:, 0]
        slot_rep = bucket * w + way
        # propagate representative slot to same-key duplicates
        slot_sorted = jnp.where(rep_sorted, slot_rep[plan.perm], -1)
        ok_sorted = jnp.where(rep_sorted, ok_rep[plan.perm], False)
        seg = jnp.cumsum(plan.is_first.astype(jnp.int32)) - 1
        slot_seg = jax.ops.segment_max(slot_sorted, seg, num_segments=b)
        ok_seg = jax.ops.segment_max(ok_sorted.astype(jnp.int32), seg,
                                     num_segments=b)
        slot = jnp.zeros((b,), jnp.int32).at[plan.perm].set(slot_seg[seg])
        ok = jnp.zeros((b,), bool).at[plan.perm].set(ok_seg[seg] > 0)
        return jnp.where(mask, slot, 0), ok & mask

    # ------------------------------------------------------------------
    def apply(self, kinds, keys, values, n_cns: int = 1
              ) -> tuple["RaceHash", engine.Results, IOMetrics, jax.Array]:
        """Resolve + execute one batch; returns (store', results, io, overflow)."""
        kinds = jnp.asarray(kinds, jnp.int32)
        if bool((kinds == OpKind.SCAN).any()):
            raise UnsupportedOpError(
                "RaceHash cannot serve SCAN: the hash scatters adjacent keys "
                "across unrelated buckets, so a key range has no contiguous "
                "slot run to traverse.  Use the radix index "
                "(repro.stores.SmartART) for range workloads (DESIGN.md §9).")
        keys = jnp.asarray(keys, jnp.int32)
        values = jnp.asarray(values, jnp.int32)
        b = kinds.shape[0]
        pos = jnp.arange(b, dtype=jnp.int32)
        slot, found = self.locate(keys)
        is_ins = kinds == OpKind.INSERT
        need = is_ins & ~found
        rslot, rok = self._reserve(keys, need)
        overflow = need & ~rok
        # Batch-local binding: every op on an absent key resolves to the slot
        # reserved by that key's INSERT in this batch (serialization inside
        # the engine then gives exact before/after-the-insert semantics);
        # absent keys with no INSERT resolve to the empty tombstone slot.
        plan = wc.plan_combine(keys, pos, ~found)
        rs = jnp.where(need & rok, rslot, -1)[plan.perm]
        seg = jnp.cumsum(plan.is_first.astype(jnp.int32)) - 1
        rs_seg = jax.ops.segment_max(rs, seg, num_segments=b)
        bound = jnp.zeros((b,), jnp.int32).at[plan.perm].set(rs_seg[seg])
        tomb = jnp.int32(self.cfg.n_slots - 1)
        slot = jnp.where(found, slot, jnp.where(bound >= 0, bound, tomb))
        valid = ~overflow
        batch = OpBatch.make(kinds, slot, values, n_cns=n_cns)
        state, credits, res, io = engine.apply_batch(
            self.cfg, self.state, self.credits, batch, valid=valid)
        # index maintenance: successful INSERT binds key->slot; successful
        # DELETE frees the slot
        ok_ins = res.ok & is_ins
        ok_del = res.ok & (kinds == OpKind.DELETE)
        nslots = self.slot_keys.shape[0]
        slot_keys = self.slot_keys.at[jnp.where(ok_ins, slot, nslots)].set(
            keys, mode="drop")
        slot_keys = slot_keys.at[jnp.where(ok_del, slot, nslots)].set(
            _EMPTY, mode="drop")
        new = dataclasses.replace(self, slot_keys=slot_keys, state=state,
                                  credits=credits)
        return new, res, io, overflow

    def populate(self, keys, values, chunk: int = 8192) -> "RaceHash":
        store = self
        keys = jnp.asarray(keys, jnp.int32)
        values = jnp.asarray(values, jnp.int32)
        kinds = jnp.full((chunk,), OpKind.INSERT, jnp.int32)
        for i in range(0, keys.shape[0], chunk):
            k = keys[i:i + chunk]
            v = values[i:i + chunk]
            if k.shape[0] < chunk:
                pad = chunk - k.shape[0]
                k = jnp.pad(k, (0, pad))
                v = jnp.pad(v, (0, pad))
                kd = kinds.at[chunk - pad:].set(OpKind.NOP)
            else:
                kd = kinds
            store, _, _, ovf = store.apply(kd, k, v)
        return store
