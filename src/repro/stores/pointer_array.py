"""The pointer-array micro-benchmark store (§5.2).

Layer: stores (DESIGN.md §1) — contract: key i *is* slot i, one pointer READ
of index I/O per op; point ops only (SCAN is rejected, DESIGN.md §9).

"A minimalistic object storage ... to quantify the pure performance
advancement brought about by CIDER": each slot holds a data pointer to an
out-of-place KV pair in the heap.
"""
from __future__ import annotations

import dataclasses

from repro.core import engine, runner
from repro.core.credits import CreditState, credit_init
from repro.core.types import (EngineConfig, IOMetrics, OpBatch, OpKind,
                              SyncMode, UnsupportedOpError)

__all__ = ["PointerArray"]


def _reject_scan(kinds) -> None:
    """Point-op stores cannot serve range reads — fail loudly, not with a
    silent 0-row result (DESIGN.md §9)."""
    if bool((kinds == OpKind.SCAN).any()):
        raise UnsupportedOpError(
            "PointerArray is a point-op object store: it has no key order, "
            "so SCAN has no contiguous leaf run to traverse.  Range reads "
            "need the radix index (repro.stores.SmartART), whose leaf "
            "entries sit in key order (DESIGN.md §9).")


@dataclasses.dataclass
class PointerArray:
    cfg: EngineConfig
    state: engine.StoreState
    credits: CreditState

    @staticmethod
    def create(n_keys: int, mode: SyncMode = SyncMode.CIDER,
               heap_slots: int | None = None, credit_table: int = 4096,
               **kw) -> "PointerArray":
        cfg = EngineConfig(n_slots=n_keys, heap_slots=heap_slots or 4 * n_keys,
                           mode=mode, index_read_iops=1, index_read_bytes=8,
                           **kw)
        return PointerArray(cfg=cfg, state=engine.store_init(cfg),
                            credits=credit_init(credit_table))

    def populate(self, keys, values) -> "PointerArray":
        state = engine.populate(self.cfg, self.state, keys, values)
        return dataclasses.replace(self, state=state)

    def apply(self, batch: OpBatch) -> tuple["PointerArray", engine.Results, IOMetrics]:
        _reject_scan(batch.kinds)
        state, credits, res, io = engine.apply_batch(
            self.cfg, self.state, self.credits, batch)
        return dataclasses.replace(self, state=state, credits=credits), res, io

    def apply_stream(self, stream: runner.WindowStream, io_per_window: bool = False
                     ) -> tuple["PointerArray", engine.Results, IOMetrics]:
        """Run every window of ``stream`` in one fused scan (``run_windows``).

        Store/credit buffers are donated to the scan — use the returned
        instance, not ``self``, afterwards.
        """
        _reject_scan(stream.batch.kinds)
        state, credits, res, io = runner.run_windows(
            self.cfg, self.state, self.credits, stream,
            io_per_window=io_per_window)
        return dataclasses.replace(self, state=state, credits=credits), res, io

    def view(self):
        return engine.store_view(self.state)
