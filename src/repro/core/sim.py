"""Protocol-simulator driver: reproduces the paper's figures on CPU.

``run_sim`` advances the vectorized client state machines of
``repro.core.protocol`` over ``SimParams.ticks`` microseconds and returns the
throughput / latency / I/O statistics that the paper's evaluation plots
(Figs 1-5, 11-15, 20-21).

DESIGN.md §4 (protocol simulator): drives the per-lane state machines and
reduces their histories to the paper's figures.
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.protocol import DEAD, SimState, sim_init, tick
from repro.core.simnet import SimParams
from repro.core.types import OpKind, SyncMode
from repro.workloads.ycsb import WORKLOADS, WorkloadSpec, generate_ops

__all__ = ["SimParams", "SimResult", "make_streams", "run_sim", "sweep_clients"]


@dataclasses.dataclass
class SimResult:
    mode: str
    n_clients: int
    ticks: int
    ops_done: int
    throughput_mops: float      # completed ops / simulated microsecond
    p50_us: float
    p99_us: float
    retries: int                # redundant CAS / lock polls (Fig 1)
    mn_iops_used: int           # total MN verbs
    cn_msgs: int
    wc_rate: float              # combined / writes (Fig 4, 21)
    wc_rate_local: float
    wc_rate_global: float
    avg_batch: float            # mean WC batch size (Fig 21)
    pess_ratio: float           # pessimistic writes / writes (Fig 14)
    ideal_pess_ratio: float     # writes with >= threshold retries / writes
    deadlocks: int

    def row(self) -> str:
        return (f"{self.mode},{self.n_clients},{self.throughput_mops:.4f},"
                f"{self.p50_us:.1f},{self.p99_us:.1f},{self.retries},"
                f"{self.wc_rate:.3f},{self.avg_batch:.2f},{self.pess_ratio:.3f}")


def make_streams(p: SimParams, spec: WorkloadSpec, n_keys: int,
                 theta: float | None = None, seed: int = 0) -> dict:
    """Pre-generate per-lane op streams with pre-hashed table slots."""
    n, m = p.n_lanes, p.max_ops
    ops = generate_ops(spec, n * m, n_keys, n, seed=seed, theta=theta)
    kinds = ops.kinds.reshape(m, n).T.astype(np.int32)
    keys = ops.keys.reshape(m, n).T
    h = ((keys * 2654435761) >> 7) & ((1 << p.h_bits) - 1)
    hc = ((keys * 0x85EBCA6B) >> 5) & ((1 << p.hc_bits) - 1)
    hl = ((keys * 0xC2B2AE35) >> 4) & ((1 << p.hl_bits) - 1)
    return {
        "kinds": jnp.asarray(kinds, jnp.int32),
        "hkey": jnp.asarray(h, jnp.int32),
        "hc": jnp.asarray(hc, jnp.int32),
        "hl": jnp.asarray(hl, jnp.int32),
    }


@functools.partial(jax.jit, static_argnames=("p", "mode"))
def _run(p: SimParams, mode: SyncMode, streams, n_active: jnp.ndarray) -> SimState:
    state = sim_init(p, streams)
    ids = jnp.arange(p.n_lanes, dtype=jnp.int32)
    state = dataclasses.replace(
        state, phase=jnp.where(ids < n_active, state.phase, DEAD))

    def body(s, t):
        return tick(p, mode, streams, s, t), None

    state, _ = jax.lax.scan(body, state, jnp.arange(p.ticks, dtype=jnp.int32))
    return state


def _pct(hist: np.ndarray, q: float) -> float:
    c = np.cumsum(hist)
    if c[-1] == 0:
        return float("nan")
    return float(np.searchsorted(c, q * c[-1]))


def run_sim(p: SimParams, mode: SyncMode, streams, n_clients: int) -> SimResult:
    s = _run(p, mode, streams, jnp.int32(n_clients))
    hist = np.asarray(s.hist)
    done = int(s.done)
    done_w = max(int(s.done_w), 1)
    verbs = np.asarray(s.verbs)
    comb = int(s.comb_g) + int(s.comb_l)
    return SimResult(
        mode=mode.name, n_clients=n_clients, ticks=p.ticks, ops_done=done,
        throughput_mops=done / p.ticks,
        p50_us=_pct(hist, 0.50), p99_us=_pct(hist, 0.99),
        retries=int(s.retries),
        mn_iops_used=int(verbs[:4].sum()), cn_msgs=int(verbs[4]),
        wc_rate=comb / done_w,
        wc_rate_local=int(s.comb_l) / done_w,
        wc_rate_global=int(s.comb_g) / done_w,
        avg_batch=float(int(s.batch_sum) / max(int(s.batch_cnt), 1)),
        pess_ratio=int(s.pess_w) / done_w,
        ideal_pess_ratio=int(s.hot_ideal) / done_w,
        deadlocks=int(s.deadlocks),
    )


def sweep_clients(p: SimParams, modes, workload: str, n_keys: int,
                  client_counts, theta: float | None = None,
                  seed: int = 0) -> list[SimResult]:
    spec = WORKLOADS[workload]
    streams = make_streams(p, spec, n_keys, theta=theta, seed=seed)
    out = []
    for mode in modes:
        for nc in client_counts:
            out.append(run_sim(p, mode, streams, nc))
    return out
