"""Network / cost model for the protocol-level simulator (§5 testbed model).

Models the disaggregated-memory fabric the paper measures on (CloudLab,
100 Gbps ConnectX-6):

* **Memory-pool NIC**: the bottleneck resource.  A token-bucket server with
  ``mn_cap`` verbs/tick of IOPS capacity and ``mn_bw`` bytes/tick of
  bandwidth; excess arrivals queue (FIFO by client id within a tick), so a
  verb issued under backlog *B* completes after ``rtt + B/cap`` ticks.  This
  is what optimistic retries saturate (§2.2, Fig 1).
* **Client NICs**: CN<->CN messages (MCS handoffs, WC coordination) cost
  ``cn_rtt`` ticks and are modeled *uncontended* — precisely ShiftLock's
  design point of shifting polling off the memory pool.

One tick == 1 microsecond; one-sided RDMA RTT ~2 us.

DESIGN.md §4 (protocol simulator): the MN-NIC token-bucket cost model shared
by the sim and the modeled metrics (§6-§7).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["SimParams", "NetState", "net_init", "issue_mn"]


@dataclasses.dataclass(frozen=True)
class SimParams:
    # population
    n_lanes: int = 1024          # physical client lanes (mask unused ones)
    lanes_per_cn: int = 4        # paper: 4 clients per (virtual) CN
    max_ops: int = 4096          # pregenerated ops per lane (wraps around)
    # time
    ticks: int = 32768           # simulated microseconds
    rtt: int = 2                 # MN verb round-trip (ticks)
    cn_rtt: int = 2              # client<->client message (ticks)
    think: int = 1               # client compute between ops (ticks)
    # memory-pool NIC — calibrated against the paper's headline ratios
    # (EXPERIMENTS.md §Calibration): O-SYNC collapse 2.7x, CIDER p99 ~13x
    mn_cap: int = 32             # capacity tokens/tick (reads: 1 token each)
    atomic_cost: int = 1         # CAS/FAA token cost (distinct-address atomics
                                 # pipeline fine on CX-6)
    addr_atomic_cap: int = 2     # same-ADDRESS atomics/tick — RNIC serializes
                                 # concurrent atomics to one address on a PCIe
                                 # read-modify-write (Kalia et al., ATC'16);
                                 # this is what hot-pointer CAS storms hit
    mn_bw: int = 12500           # bytes/tick (100 Gbps)
    value_bytes: int = 8
    index_bytes: int = 8
    index_reads: int = 1         # per-op index I/O (pointer array: 1)
    # synchronization parameters
    escape_retries: int = 8      # CIDER: optimistic retry budget before the
                                 # client re-runs the mode decision (see
                                 # DESIGN.md implementation notes)
    backoff_cap: int = 6         # SPIN truncated exponential backoff
    # factor-analysis switches (Fig 20)
    wc_off: bool = False         # CIDER w/o global WC (contention-aware only)
    cas_off: bool = False        # CIDER w/o contention-aware (always pess.)
    local_wc: bool = True        # local write combining (baselines, §5.1)
    initial_credit: int = 36     # §4.3 / Fig 15
    hotness_threshold: int = 2
    aimd_factor: int = 2
    # tables
    h_bits: int = 14             # key-state hash table (2^14)
    hc_bits: int = 10            # per-CN credit table
    hl_bits: int = 10            # per-CN local-WC table
    hist_buckets: int = 2048     # latency histogram (1 us buckets)
    # SNAPSHOT client-centric replication (FUSEE; DESIGN.md §13): every
    # write-class verb (WRITE/CAS/FAA) fans out from the client to all
    # n_replicas replica MNs — xR tokens and bytes on the shared MN fleet —
    # and the issuing lane additionally waits `replica_rtt` ticks for the
    # slowest replica's ack.  Reads go to one replica.  n_replicas=1
    # reproduces the pre-replication sim tick-exactly (static branch).
    n_replicas: int = 1
    replica_rtt: int = 2
    # fault tolerance (§4.6)
    fail_lane: int = -1          # lane that dies ...
    fail_tick: int = -1          # ... at this tick (-1 = no failure)
    fail_lanes: tuple[int, ...] = ()  # additional lanes dying at fail_tick —
                                 # multi-CN crash scenarios on the sim path
    max_wait: int = 4096         # deadlock detection: max lock-hold duration
    # engine-path modeled latency: lease a blocked queue waits out before an
    # orphaned (holder-dead, epoch-stale) lock may be broken with a repair
    # CAS (runner.modeled_latency; the sim path uses max_wait directly)
    lease_us: int = 512


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class NetState:
    backlog: jax.Array       # () i32 — queued MN verbs
    byte_backlog: jax.Array  # () i32 — queued MN bytes
    addr_backlog: jax.Array  # (H,) i32 — queued same-address atomics


def net_init(h_size: int) -> NetState:
    z = jnp.zeros((), jnp.int32)
    return NetState(backlog=z, byte_backlog=z,
                    addr_backlog=jnp.zeros((h_size,), jnp.int32))


def issue_mn(net: NetState, t, issue: jax.Array, nbytes: jax.Array,
             cost: jax.Array, is_atomic: jax.Array, hkey: jax.Array,
             p: SimParams) -> tuple[NetState, jax.Array]:
    """Issue MN verbs for masked lanes; returns (net', completion_tick).

    ``issue``: (N,) bool; ``nbytes``: (N,) i32 wire bytes; ``cost``: (N,) i32
    capacity tokens; ``is_atomic``/``hkey``: same-address serialization —
    concurrent atomics on one (hashed) address are limited to
    ``addr_atomic_cap`` per tick, with their own per-address FIFO backlog.
    Global queueing: FIFO by lane id within the tick, behind the backlog.
    """
    H = net.addr_backlog.shape[0]
    c = jnp.where(issue, cost, 0)
    rank = jnp.cumsum(c) - c
    iops_delay = (net.backlog + rank) // p.mn_cap
    nb_m = jnp.where(issue, nbytes, 0)
    byte_rank = jnp.cumsum(nb_m) - nb_m
    bw_delay = (net.byte_backlog + byte_rank) // p.mn_bw
    # per-address atomic serialization
    atom = issue & is_atomic
    ah = jnp.where(atom, hkey, H)
    ids = jnp.arange(issue.shape[0], dtype=jnp.int32)
    order = jnp.lexsort((ids, ah))
    ahs = ah[order]
    is_first = jnp.concatenate([jnp.ones((1,), bool), ahs[1:] != ahs[:-1]])
    pos = jnp.arange(issue.shape[0], dtype=jnp.int32)
    arank_sorted = pos - jax.lax.cummax(jnp.where(is_first, pos, 0))
    arank = jnp.zeros_like(pos).at[order].set(arank_sorted)
    addr_delay = jnp.where(
        atom, (net.addr_backlog[jnp.clip(hkey, 0, H - 1)] + arank)
        // p.addr_atomic_cap, 0)
    delay = jnp.maximum(jnp.maximum(iops_delay, bw_delay), addr_delay)
    done_at = t + p.rtt + jnp.where(issue, delay, 0)
    arrivals = jnp.zeros((H,), jnp.int32).at[ah].add(1, mode="drop")
    net2 = NetState(
        backlog=jnp.maximum(net.backlog + jnp.sum(c) - p.mn_cap, 0),
        byte_backlog=jnp.maximum(net.byte_backlog + jnp.sum(nb_m) - p.mn_bw, 0),
        addr_backlog=jnp.maximum(net.addr_backlog + arrivals - p.addr_atomic_cap, 0),
    )
    return net2, done_at.astype(jnp.int32)
