"""Sequential (single-client) oracle for the KV store semantics.

Applies ops strictly in batch-position order against a dict — the ground
truth that every synchronization mode must be equivalent to (linearizability
of the window with queue order == batch position).

DESIGN.md §2.2 (serialization contract): the batch-position-ordered ground
truth every SyncMode must match, SCAN included (§9.2).
"""
from __future__ import annotations

import numpy as np

from repro.core.types import OpKind

__all__ = ["OracleStore"]


class OracleStore:
    def __init__(self):
        self.kv: dict[int, int] = {}
        self.rows = np.zeros(0, np.int64)   # per-op SCAN row counts, last apply

    def populate(self, keys, values):
        for k, v in zip(np.asarray(keys).tolist(), np.asarray(values).tolist()):
            self.kv[int(k)] = int(v)

    def apply(self, kinds, keys, values, valid=None, scan_max=None):
        """Returns (ok[B], value[B]) per op, mutating the store.

        SCAN ops (count in ``values``, optionally clipped to ``scan_max`` —
        the engine's static probe bound) leave ``value`` at -1 and record
        their found-row counts in ``self.rows`` (B,), 0 for point ops, to
        match ``engine.Results.rows``.
        """
        kinds = np.asarray(kinds)
        keys = np.asarray(keys)
        values = np.asarray(values)
        b = kinds.shape[0]
        if valid is None:
            valid = np.ones(b, bool)
        ok = np.zeros(b, bool)
        out = np.full(b, -1, np.int64)
        self.rows = np.zeros(b, np.int64)
        for i in range(b):
            if not valid[i] or kinds[i] == OpKind.NOP:
                continue
            k, v = int(keys[i]), int(values[i])
            if kinds[i] == OpKind.SEARCH:
                if k in self.kv:
                    ok[i] = True
                    out[i] = self.kv[k]
            elif kinds[i] == OpKind.INSERT:
                if k not in self.kv:
                    ok[i] = True
                    self.kv[k] = v
            elif kinds[i] == OpKind.UPDATE:
                if k in self.kv:
                    ok[i] = True
                    self.kv[k] = v
            elif kinds[i] == OpKind.DELETE:
                if k in self.kv:
                    ok[i] = True
                    del self.kv[k]
            elif kinds[i] == OpKind.SCAN:
                count = v if scan_max is None else min(v, int(scan_max))
                rows = sum(1 for kk in range(k, k + count) if kk in self.kv)
                self.rows[i] = rows
                ok[i] = rows > 0
        return ok, out

    def view(self, n_slots):
        exists = np.zeros(n_slots, bool)
        val = np.full(n_slots, -1, np.int64)
        for k, v in self.kv.items():
            if 0 <= k < n_slots:
                exists[k] = True
                val[k] = v
        return exists, val
