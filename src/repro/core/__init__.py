"""The paper's primary contribution: the CIDER synchronization engine.

* ``engine``   — batched SPMD dataplane (4 sync modes, exact verb metering)
* ``runner``   — fused multi-window execution (one scan for W windows) and
  the MN-IOPS-modeled throughput metric
* ``combine``  — global write-combining primitives (sort / segment / rank)
* ``credits``  — contention-aware AIMD credit tables (Algorithm 1)
* ``protocol``/``simnet``/``sim`` — the testbed-calibrated protocol simulator
* ``oracle``   — sequential reference semantics

DESIGN.md §1 (core layer): engine + credits + fused runner + protocol
simulator behind one op vocabulary.
"""
from repro.core.types import EngineConfig, IOMetrics, OpBatch, OpKind, SyncMode

__all__ = ["EngineConfig", "IOMetrics", "OpBatch", "OpKind", "SyncMode"]
