"""Fused multi-window execution: W synchronization windows in ONE jitted scan.

The per-window Python loop (``for w: state, ... = apply_batch(...)``) pays one
jit dispatch plus a host round-trip per window, so host dispatch — not the
engine — dominates wall-clock at benchmark sizes and inverts the ordering the
paper measures.  ``run_windows`` replaces that loop with a single
``jax.lax.scan`` over a stacked ``WindowStream``: the store/credit carry never
leaves the device and the buffers are donated, so steady-state windows run
back-to-back at device speed.

Two throughput metrics (see DESIGN.md §6):

* **device wall-clock** — what ``time.time()`` around the fused scan measures;
  an artifact of the TPU/CPU adaptation, useful only as a regression signal.
* **MN-IOPS-modeled** — the paper's metric (§2.3, §5): on real disaggregated
  memory the bottleneck is memory-side NIC IOPS, which the engine meters
  *exactly* per window.  ``modeled_throughput`` converts the verb bill into
  ops/s under the testbed cost model (``SimParams``: ``mn_cap`` verbs/us,
  ``mn_bw`` bytes/us), the same accounting FUSEE/Outback evaluate with.

Modeled latency (the paper's second axis, Figs 11-12): ``modeled_latency``
derives a per-op completion time in microseconds from each op's exact verb
bill and wait-queue rank (``Results.rank``) under the same ``SimParams``
cost model the protocol simulator uses — critical-path RTTs per protocol
workflow (Figs 9-10) plus the memory-side NIC queueing delay of the window's
own verb backlog (``simnet.issue_mn``'s ``(backlog + rank) / cap`` rule).
``latency_stats`` reduces that to p50/p99 (``LatencyStats``); see
DESIGN.md §7 for the per-mode chains.

``run_windows_traced`` additionally returns the per-window credit-table mass
so CIDER's AIMD adaptation (§4.3) is observable as a trajectory without
leaving the fused scan.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine
from repro.core.credits import CreditState
from repro.core.engine import Results, StoreState
from repro.core.simnet import SimParams
from repro.core.types import (EngineConfig, IOMetrics, LatencyStats, OpBatch,
                              OpKind, SyncMode)

__all__ = ["WindowStream", "make_stream", "run_windows", "run_windows_traced",
           "io_window", "modeled_throughput", "modeled_latency",
           "latency_stats"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class WindowStream:
    """W stacked synchronization windows: every ``OpBatch`` leaf plus the
    validity mask carries a leading window axis ``(W, B)``.

    ``alive`` is the liveness plane (crash recovery, §4.6): row ``w`` masks
    the compute nodes alive through window ``w``.  A CN whose bit drops
    between consecutive windows *died at* the later window — its in-flight
    ops are dropped at the window boundary and its pessimistic writes strand
    orphaned locks (see ``engine.apply_batch``).  All-ones (the
    ``make_stream`` default) reproduces the failure-free behavior bit-exactly.
    """
    batch: OpBatch      # all leaves (W, B)
    valid: jax.Array    # (W, B) bool
    alive: jax.Array    # (W, n_cns) bool — CN liveness per window

    @property
    def shape(self) -> tuple[int, int]:
        return self.batch.kinds.shape


def make_stream(kinds, keys, values, n_cns: int = 1,
                lanes_per_cn: int | None = None,
                valid: jax.Array | None = None,
                alive: jax.Array | None = None,
                cn: jax.Array | None = None) -> WindowStream:
    """Stack ``(W, B)`` op arrays into a ``WindowStream``.

    Window ``w`` of the result is exactly ``OpBatch.make(kinds[w], keys[w],
    values[w], n_cns, lanes_per_cn)`` — same serialization priorities and CN
    assignment — so the fused scan sees the batches the per-window loop saw.
    ``alive`` (``(W, n_cns)`` bool, default all alive) attaches a liveness
    schedule; build one with ``repro.recovery.liveness``.

    ``cn`` (``(W, B)`` int32) overrides the default round-robin lane→CN map.
    Open-loop streams need it: a dense re-pack moves an op to a new lane, and
    only an explicit CN plane keeps its (key, cn) write-combining group — and
    hence its bill — identical to the padded original (DESIGN.md §12).
    """
    kinds = jnp.asarray(kinds, jnp.int32)
    keys = jnp.asarray(keys, jnp.int32)
    values = jnp.asarray(values, jnp.int32)
    w, b = kinds.shape
    pos = jnp.broadcast_to(jnp.arange(b, dtype=jnp.int32), (w, b))
    if lanes_per_cn is None:
        lanes_per_cn = max(b // max(n_cns, 1), 1)
    if cn is None:
        cn = (pos // lanes_per_cn) % max(n_cns, 1)
    else:
        cn = jnp.asarray(cn, jnp.int32)
        if cn.shape != (w, b):
            raise ValueError(f"cn plane is {cn.shape}, expected {(w, b)}")
    if valid is None:
        valid = kinds != OpKind.NOP
    if alive is None:
        alive = jnp.ones((w, max(n_cns, 1)), bool)
    else:
        alive = jnp.asarray(alive, bool)
        if alive.shape != (w, max(n_cns, 1)):
            raise ValueError(
                f"alive is {alive.shape}, expected ({w}, {max(n_cns, 1)}) — "
                f"the liveness schedule must match the stream's windows AND "
                f"its CN count (a mismatch would silently mis-drop ops)")
    batch = OpBatch(kinds=kinds, keys=keys, values=values, pos=pos, cn=cn)
    return WindowStream(batch=batch, valid=jnp.asarray(valid, bool),
                        alive=alive)


@functools.partial(jax.jit, static_argnames=("cfg", "io_per_window", "traced"),
                   donate_argnums=(1, 2))
def _scan_windows(cfg: EngineConfig, state: StoreState, credits: CreditState,
                  stream: WindowStream, prev_alive: jax.Array,
                  io_per_window: bool, traced: bool):
    """The one fused window scan behind ``run_windows``/``run_windows_traced``
    (and mirrored by ``dist.store``'s sharded variant)."""
    def step(carry, win):
        st, cr, prev, = carry
        batch, valid, alive = win
        # CNs alive at window start but not through this window died HERE —
        # their in-flight pessimistic writes strand locks (engine step 5b)
        died = prev & ~alive
        st, cr, res, io = engine.apply_batch(cfg, st, cr, batch, valid=valid,
                                             alive=alive, died=died)
        out = (res, io, jnp.sum(cr.credit)) if traced else (res, io)
        return (st, cr, alive), out

    (state, credits, _), outs = jax.lax.scan(
        step, (state, credits, prev_alive),
        (stream.batch, stream.valid, stream.alive))
    results, ios = outs[0], outs[1]
    if not io_per_window:
        ios = jax.tree.map(lambda x: jnp.sum(x, axis=0), ios)
    if traced:
        return state, credits, results, ios, outs[2]
    return state, credits, results, ios


def _prev_alive(stream: WindowStream, prev_alive) -> jax.Array:
    """Default liveness at stream start: deaths 'at window 0' cannot strand
    anything (nothing was in flight before the stream began), so the initial
    previous-alive row is row 0 itself.  ``repro.recovery`` passes the last
    alive row of the preceding segment when a run is split (e.g. around a
    shard failover), so a crash at a segment boundary still strands."""
    return stream.alive[0] if prev_alive is None else jnp.asarray(prev_alive,
                                                                  bool)


def run_windows(cfg: EngineConfig, state: StoreState, credits: CreditState,
                stream: WindowStream, io_per_window: bool = False,
                prev_alive: jax.Array | None = None,
                ) -> tuple[StoreState, CreditState, Results, IOMetrics]:
    """Execute every window of ``stream`` in one fused ``lax.scan``.

    Bit-exact per-window semantics: window ``w``'s ``Results`` row, I/O bill,
    and credit-table transition are identical to calling ``apply_batch`` W
    times from Python (asserted in ``tests/test_runner.py``).  ``state`` and
    ``credits`` are donated — callers must use the returned buffers.

    Returns ``(state, credits, results, io)`` with ``results`` stacked over
    the window axis and ``io`` summed across windows (``io_per_window=True``
    keeps the per-window bill, leaves shaped ``(W,)``).
    """
    return _scan_windows(cfg, state, credits, stream,
                         _prev_alive(stream, prev_alive), io_per_window, False)


def run_windows_traced(cfg: EngineConfig, state: StoreState,
                       credits: CreditState, stream: WindowStream,
                       prev_alive: jax.Array | None = None,
                       ) -> tuple[StoreState, CreditState, Results, IOMetrics,
                                  jax.Array]:
    """``run_windows`` with the AIMD trajectory kept: returns
    ``(state, credits, results, io, credit_mass)`` where ``io`` is always
    per-window (leaves ``(W,)``) and ``credit_mass`` is the total credit-table
    mass AFTER each window (``(W,)`` int32) — the §4.3 adaptation signal the
    dynamic-contention scenarios plot.  Same bit-exact per-window semantics
    and donation contract as ``run_windows``.
    """
    return _scan_windows(cfg, state, credits, stream,
                         _prev_alive(stream, prev_alive), True, True)


def io_window(ios: IOMetrics, w: int) -> IOMetrics:
    """Window ``w``'s bill out of a stacked (``io_per_window=True``) bill."""
    return jax.tree.map(lambda x: x[w], ios)


def modeled_throughput(io: IOMetrics, p: SimParams, n_ops: int
                       ) -> dict[str, Any]:
    """MN-IOPS-bound throughput of ``n_ops`` ops with verb bill ``io``.

    The memory-pool NIC serves ``mn_cap`` verbs and ``mn_bw`` bytes per tick
    (1 tick == 1 us, ``repro.core.simnet``); CN<->CN messages ride client
    NICs and are free here — exactly ShiftLock's design point.  The modeled
    service time is the binding constraint, so throughput in Mops/s is
    ``n_ops / ticks`` directly.
    """
    mn_iops = int(np.asarray(io.mn_iops))
    mn_bytes = int(np.asarray(io.mn_bytes))
    iops_ticks = mn_iops / p.mn_cap
    bw_ticks = mn_bytes / p.mn_bw
    ticks = max(iops_ticks, bw_ticks)
    return {
        "modeled_ticks_us": round(ticks, 2),
        "modeled_mops": round(n_ops / ticks, 4) if ticks > 0 else float("inf"),
        "bound": "iops" if iops_ticks >= bw_ticks else "bandwidth",
        "mn_cap_per_us": p.mn_cap,
        "mn_bw_bytes_per_us": p.mn_bw,
    }


def modeled_latency(cfg: EngineConfig, kinds, res: Results, p: SimParams,
                    valid=None, scan_counts=None) -> np.ndarray:
    """Per-op modeled completion time in microseconds (host-side, numpy).

    Two additive components, mirroring ``repro.core.simnet``'s service model
    (DESIGN.md §7 tabulates the per-mode chains):

    * **critical-path RTTs** — the op's sequential verb chain per the
      protocol workflows (Figs 9-10), scaled by ``p.rtt``; queue waits enter
      through ``Results.rank``: a rank-r optimistic writer pays r failed
      CAS rounds, a rank-r SPIN/MCS waiter sits behind r lock holders, while
      a CIDER combined queue completes with its single executor *regardless
      of rank* — exactly why global WC flattens the tail.
    * **MN NIC queueing** — ``simnet.issue_mn``'s ``(backlog + rank) / cap``
      rule applied to the window's own arrivals: each op waits behind the MN
      verbs of the ops preceding it in the window (serialization order ==
      batch position), so retry storms inflate everyone's tail, not just the
      retrying op's.

    * **lease waits** (crash recovery, §4.6) — an op whose wait queue found
      an orphaned (holder-dead) lock waits ``Results.orphan_wait`` lease
      expirations (``p.lease_us`` each) plus the stale-epoch READ + repair
      CAS round trips before its queue can proceed.  MCS queues wait once
      per dead chain node; CIDER/SPIN once per key — the repair asymmetry
      the recovery benchmark measures.

    * **SCAN chains** (DESIGN.md §9) — a scan's leaf-run READs are
      doorbell-batched (one round trip for the run, one for the found
      values), so its chain is short while its *verb* footprint — which
      feeds everyone's MN queueing — is per-leaf (``scan_counts``, the
      per-op scan length; defaults to ``Results.rows`` when not given,
      undercounting absent-row leaves).  Per mode: OSYNC adds the
      validation re-read round; SPIN/MCS readers wait behind
      ``Results.rank`` exclusive holders on the anchor leaf; CIDER's
      cold scans skip the queue entirely and a credit-hot anchor waits
      for at most its queue's ONE combined executor.

    Aggregate ``IOMetrics`` stay the *exact* bill; this per-op split is the
    documented approximation (locally-combined baseline writers are billed
    as rank-0 writers, CN<->CN hops cost ``p.cn_rtt`` uncontended; a scan's
    per-mode sync verbs are charged per anchor, not per leaf).  Works
    on flat ``(B,)`` or window-stacked ``(W, B)`` results; invalid lanes are
    NaN (``latency_stats`` ignores them).  When a liveness schedule dropped
    ops, pass the post-drop validity (``recovery.liveness`` provides it) so
    dead lanes are masked out.
    """
    kinds = np.asarray(kinds)
    ok = np.asarray(res.ok)
    pess = np.asarray(res.pessimistic)
    comb = np.asarray(res.combined)
    rank = np.asarray(res.rank).astype(np.float64)
    polls = np.asarray(res.retries).astype(np.float64)
    m = np.asarray(res.wc_batch).astype(np.float64)
    if valid is None:
        valid = kinds != OpKind.NOP
    else:
        valid = np.asarray(valid) & (kinds != OpKind.NOP)
    search = kinds == OpKind.SEARCH
    insert = kinds == OpKind.INSERT
    update = kinds == OpKind.UPDATE
    delete = kinds == OpKind.DELETE
    scan = kinds == OpKind.SCAN
    rows = np.asarray(res.rows).astype(np.float64)
    if scan_counts is None:
        counts = np.where(scan, rows, 0.0)
    else:
        # clip to the engine's static probe bound: the model must bill the
        # leaves the engine actually traversed, not the requested length
        counts = np.where(scan, np.minimum(
            np.asarray(scan_counts, np.float64), float(cfg.scan_max)), 0.0)
    idx = float(cfg.index_read_iops)
    rtt, cnr = float(p.rtt), float(p.cn_rtt)

    # ---- critical-path chain: sequential MN RTTs + CN-msg hops (us) --------
    chain = np.full(kinds.shape, idx, np.float64)      # index resolve
    extra = np.zeros(kinds.shape, np.float64)          # CN<->CN hops (us)
    chain = np.where(search, idx + ok, chain)          # + value READ if found
    chain = np.where(insert, idx + 2.0, chain)         # heap WRITE + ptr CAS
    # optimistic writers: rank-r loses r CAS rounds (re-read + re-CAS each)
    opt_u = update & ~pess
    chain = np.where(opt_u & ~comb, idx + 2.0 + 2.0 * rank, chain)
    chain = np.where(opt_u & comb, idx + 2.0, chain)   # rides its executor
    if cfg.mode == SyncMode.OSYNC:
        chain = np.where(delete, idx + 1.0 + 2.0 * rank, chain)
    else:
        chain = np.where(delete, idx + 3.0, chain)     # lock CAS+CAS+FAA
    if cfg.mode == SyncMode.SPIN:
        # acquire CAS + r holders (WRITE + ptr CAS + unlock each) + own 3
        chain = np.where(update & pess, idx + 4.0 + 3.0 * rank, chain)
    elif cfg.mode == SyncMode.MCS:
        # enqueue CAS + own WRITE + ptr CAS + FAA; each predecessor serves
        # 3 RTTs then hands off with one CN msg
        chain = np.where(update & pess, idx + 4.0, chain)
        extra = np.where(update & pess, rank * (3.0 * rtt + cnr), extra)
    elif cfg.mode == SyncMode.CIDER:
        # the whole queue completes with its ONE executor: enqueue CAS +
        # coordinator tail READ (multi-writer queues) + combined WRITE +
        # ptr CAS + release FAA — rank does NOT appear (global WC, §4.2)
        chain = np.where(update & pess, idx + 4.0 + (m > 1), chain)
        extra = np.where(update & pess & (m > 1), 2.0 * cnr, extra)

    # SCAN (DESIGN.md §9): leaf-run READ round + a value round when any row
    # was found (doorbell-batched); readers wait behind `rank` exclusive
    # holders on the anchor leaf — except CIDER, whose cold scans skip the
    # queue and whose hot anchor waits for ONE combined executor
    found = (rows > 0).astype(np.float64)
    if cfg.mode == SyncMode.OSYNC:
        chain = np.where(scan, idx + 2.0 + found, chain)       # + re-read round
    elif cfg.mode == SyncMode.SPIN:
        chain = np.where(scan, idx + 2.0 + found + 3.0 * rank, chain)
    elif cfg.mode == SyncMode.MCS:
        chain = np.where(scan, idx + 2.0 + found, chain)
        extra = np.where(scan, rank * (3.0 * rtt + cnr), extra)
    else:
        chain = np.where(scan, idx + 1.0 + found
                         + np.where(rank > 0, 4.0, 0.0), chain)

    # ---- MN NIC queueing: wait behind earlier ops' verbs in the window ----
    verbs = np.full(kinds.shape, idx, np.float64)
    verbs = np.where(search, idx + ok, verbs)
    verbs = np.where(insert, idx + 2.0, verbs)
    verbs = np.where(opt_u & ~comb, idx + 2.0 + 2.0 * rank, verbs)
    if cfg.mode == SyncMode.OSYNC:
        verbs = np.where(delete, idx + 1.0 + 2.0 * rank, verbs)
    else:
        verbs = np.where(delete, idx + 3.0, verbs)
    if cfg.mode == SyncMode.SPIN:
        verbs = np.where(update & pess, idx + 4.0 + polls, verbs)
    elif cfg.mode == SyncMode.MCS:
        verbs = np.where(update & pess, idx + 4.0, verbs)
    elif cfg.mode == SyncMode.CIDER:
        verbs = np.where(update & pess & comb, idx + 2.0, verbs)   # CAS + FAA
        verbs = np.where(update & pess & ~comb, idx + 4.0 + (m > 1), verbs)
    # SCAN verb footprint is per-leaf even though its chain is batched:
    # leaf READs + found-value READs + the per-mode traversal verbs
    scan_base = idx + counts + rows
    if cfg.mode == SyncMode.OSYNC:
        verbs = np.where(scan, scan_base + counts, verbs)      # version re-reads
    elif cfg.mode in (SyncMode.SPIN, SyncMode.MCS):
        verbs = np.where(scan, scan_base + 2.0 * counts, verbs)
    else:  # CIDER: hot-anchor proxy for the credit-hot leaf subset
        verbs = np.where(scan, scan_base + 2.0 * (rank > 0), verbs)
    # SNAPSHOT replication (DESIGN.md §13): write-class verbs fan out to all
    # R replica MNs, so the backlog everyone queues behind scales on the
    # write portion of each op's footprint (reads — index resolves, SEARCH
    # payloads, SCAN probe READs — stay x1); the op itself additionally
    # waits one `replica_rtt` for the slowest replica's ack.  R=1 skips both
    # terms, keeping the pre-replication latencies bit-exact.
    rep = float(p.n_replicas)
    if rep > 1.0:
        ro = np.full(kinds.shape, idx, np.float64)
        ro = np.where(search, idx + ok, ro)
        ro = np.where(scan, scan_base, ro)
        if cfg.mode == SyncMode.OSYNC:
            ro = np.where(scan, scan_base + counts, ro)
        verbs = ro + rep * (verbs - ro)
        extra = np.where(insert | update | delete,
                         extra + float(p.replica_rtt), extra)
    verbs = np.where(valid, verbs, 0.0)
    backlog = np.cumsum(verbs, axis=-1) - verbs
    # orphaned-lock lease waits: each unit is one lease expiry + the
    # stale-epoch READ + repair CAS of the break (2 RTTs)
    orphan = np.asarray(res.orphan_wait).astype(np.float64)
    lat = (rtt * chain + extra + backlog / float(p.mn_cap)
           + orphan * (float(p.lease_us) + 2.0 * rtt))
    return np.where(valid, lat, np.nan)


def latency_stats(lat_us: np.ndarray) -> LatencyStats:
    """Reduce ``modeled_latency`` output to the paper's percentiles."""
    lat = np.asarray(lat_us, np.float64).ravel()
    lat = lat[~np.isnan(lat)]
    if lat.size == 0:
        return LatencyStats(0.0, 0.0, 0.0, 0.0, 0)
    return LatencyStats(
        p50_us=round(float(np.percentile(lat, 50)), 2),
        p99_us=round(float(np.percentile(lat, 99)), 2),
        mean_us=round(float(lat.mean()), 2),
        max_us=round(float(lat.max()), 2),
        n_ops=int(lat.size))
