"""Fused multi-window execution: W synchronization windows in ONE jitted scan.

The per-window Python loop (``for w: state, ... = apply_batch(...)``) pays one
jit dispatch plus a host round-trip per window, so host dispatch — not the
engine — dominates wall-clock at benchmark sizes and inverts the ordering the
paper measures.  ``run_windows`` replaces that loop with a single
``jax.lax.scan`` over a stacked ``WindowStream``: the store/credit carry never
leaves the device and the buffers are donated, so steady-state windows run
back-to-back at device speed.

Two throughput metrics (see DESIGN.md §6):

* **device wall-clock** — what ``time.time()`` around the fused scan measures;
  an artifact of the TPU/CPU adaptation, useful only as a regression signal.
* **MN-IOPS-modeled** — the paper's metric (§2.3, §5): on real disaggregated
  memory the bottleneck is memory-side NIC IOPS, which the engine meters
  *exactly* per window.  ``modeled_throughput`` converts the verb bill into
  ops/s under the testbed cost model (``SimParams``: ``mn_cap`` verbs/us,
  ``mn_bw`` bytes/us), the same accounting FUSEE/Outback evaluate with.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine
from repro.core.credits import CreditState
from repro.core.engine import Results, StoreState
from repro.core.simnet import SimParams
from repro.core.types import EngineConfig, IOMetrics, OpBatch, OpKind

__all__ = ["WindowStream", "make_stream", "run_windows", "io_window",
           "modeled_throughput"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class WindowStream:
    """W stacked synchronization windows: every ``OpBatch`` leaf plus the
    validity mask carries a leading window axis ``(W, B)``."""
    batch: OpBatch      # all leaves (W, B)
    valid: jax.Array    # (W, B) bool

    @property
    def shape(self) -> tuple[int, int]:
        return self.batch.kinds.shape


def make_stream(kinds, keys, values, n_cns: int = 1,
                lanes_per_cn: int | None = None,
                valid: jax.Array | None = None) -> WindowStream:
    """Stack ``(W, B)`` op arrays into a ``WindowStream``.

    Window ``w`` of the result is exactly ``OpBatch.make(kinds[w], keys[w],
    values[w], n_cns, lanes_per_cn)`` — same serialization priorities and CN
    assignment — so the fused scan sees the batches the per-window loop saw.
    """
    kinds = jnp.asarray(kinds, jnp.int32)
    keys = jnp.asarray(keys, jnp.int32)
    values = jnp.asarray(values, jnp.int32)
    w, b = kinds.shape
    pos = jnp.broadcast_to(jnp.arange(b, dtype=jnp.int32), (w, b))
    if lanes_per_cn is None:
        lanes_per_cn = max(b // max(n_cns, 1), 1)
    cn = (pos // lanes_per_cn) % max(n_cns, 1)
    if valid is None:
        valid = kinds != OpKind.NOP
    batch = OpBatch(kinds=kinds, keys=keys, values=values, pos=pos, cn=cn)
    return WindowStream(batch=batch, valid=jnp.asarray(valid, bool))


@functools.partial(jax.jit, static_argnames=("cfg", "io_per_window"),
                   donate_argnums=(1, 2))
def run_windows(cfg: EngineConfig, state: StoreState, credits: CreditState,
                stream: WindowStream, io_per_window: bool = False,
                ) -> tuple[StoreState, CreditState, Results, IOMetrics]:
    """Execute every window of ``stream`` in one fused ``lax.scan``.

    Bit-exact per-window semantics: window ``w``'s ``Results`` row, I/O bill,
    and credit-table transition are identical to calling ``apply_batch`` W
    times from Python (asserted in ``tests/test_runner.py``).  ``state`` and
    ``credits`` are donated — callers must use the returned buffers.

    Returns ``(state, credits, results, io)`` with ``results`` stacked over
    the window axis and ``io`` summed across windows (``io_per_window=True``
    keeps the per-window bill, leaves shaped ``(W,)``).
    """
    def step(carry, win):
        st, cr = carry
        batch, valid = win
        st, cr, res, io = engine.apply_batch(cfg, st, cr, batch, valid=valid)
        return (st, cr), (res, io)

    (state, credits), (results, ios) = jax.lax.scan(
        step, (state, credits), (stream.batch, stream.valid))
    if not io_per_window:
        ios = jax.tree.map(lambda x: jnp.sum(x, axis=0), ios)
    return state, credits, results, ios


def io_window(ios: IOMetrics, w: int) -> IOMetrics:
    """Window ``w``'s bill out of a stacked (``io_per_window=True``) bill."""
    return jax.tree.map(lambda x: x[w], ios)


def modeled_throughput(io: IOMetrics, p: SimParams, n_ops: int
                       ) -> dict[str, Any]:
    """MN-IOPS-bound throughput of ``n_ops`` ops with verb bill ``io``.

    The memory-pool NIC serves ``mn_cap`` verbs and ``mn_bw`` bytes per tick
    (1 tick == 1 us, ``repro.core.simnet``); CN<->CN messages ride client
    NICs and are free here — exactly ShiftLock's design point.  The modeled
    service time is the binding constraint, so throughput in Mops/s is
    ``n_ops / ticks`` directly.
    """
    mn_iops = int(np.asarray(io.mn_iops))
    mn_bytes = int(np.asarray(io.mn_bytes))
    iops_ticks = mn_iops / p.mn_cap
    bw_ticks = mn_bytes / p.mn_bw
    ticks = max(iops_ticks, bw_ticks)
    return {
        "modeled_ticks_us": round(ticks, 2),
        "modeled_mops": round(n_ops / ticks, 4) if ticks > 0 else float("inf"),
        "bound": "iops" if iops_ticks >= bw_ticks else "bandwidth",
        "mn_cap_per_us": p.mn_cap,
        "mn_bw_bytes_per_us": p.mn_bw,
    }
