"""The CIDER batched dataplane engine (§4, TPU adaptation).

Executes one *synchronization window* — a device batch of concurrent KV ops —
against a pointer store under one of four synchronization schemes
(``SyncMode``): OSYNC (optimistic CAS-retry), SPIN (CAS spinlock + backoff),
MCS (ShiftLock), CIDER (global write-combining + contention-aware credits).

Design invariants:

* **Semantic equivalence**: all four modes produce the *same* logical store
  state and per-op results — the canonical serialization is queue order ==
  batch position (``OpBatch.pos``), which is exactly what the MCS wait queue
  enforces and what last-writer-wins combining preserves (§4.5.1).  Tests
  assert equivalence against a sequential oracle.
* **Exact I/O metering**: modes differ in the RDMA-verb I/O they would issue
  on real DM; we meter those *exactly* (closed-form per wait queue, derived
  from the protocol workflows in Figs 9-10), because memory-side NIC IOPS is
  the paper's bottleneck resource.  The protocol *simulator*
  (``repro.core.sim``) additionally models queueing delay and reproduces the
  paper's throughput/latency figures; this engine is the jit/shard_map
  production path.

Per-queue I/O cost (m = effective concurrent UPDATE writers in the window),
derived from §2.2, §2.3, §4.2, Fig 9-10:

  OSYNC : m heap WRITEs + m(m+1)/2 CAS   (worst-case synchrony; §2.2)
  SPIN  : m WRITEs + m ptr-CAS + m lock-CAS + m unlock-CAS + backoff polls
  MCS   : m enqueue-CAS + m WRITEs + m ptr-CAS + m epoch-FAA + 2(m-1) CN msgs
  CIDER : m enqueue-CAS + 1 tail-READ + 1 WRITE + 1 ptr-CAS + m epoch-FAA
          + (m+1) CN msgs                 (m>1; m==1 falls back to MCS cost)

Local WC (applied to every scheme, §5.1) first collapses same-(key, CN)
writers to one effective writer; CIDER's global WC collapses same-key writers
across CNs to one executor (§4.2.1).

SCAN (range read over [key, key+count), DESIGN.md §9): when
``EngineConfig.scan_max > 0``, each SCAN expands into up to ``scan_max``
reader probes over its contiguous leaf-slot run (step 5c).  Probes join the
per-key wait queues *as readers* at the scanning op's batch position — they
observe exactly the per-slot state at that serialization point — and bill a
per-mode traversal: OSYNC re-reads each leaf's version, SPIN lock/unlock-CASes
every leaf (a CAS spinlock has no shared mode), MCS enqueues shared + releases
per leaf, and CIDER consults the CN-local credit table so cold leaves are
traversed lock-free (only credit-hot leaves pay shared-queue verbs).

The shard_map path (``repro.dist.store``) partitions the store over the
``data`` mesh axis and calls ``apply_batch`` per shard with ``owned``/
``slot_base``: the data plane then covers only the shard's keys while the
credit plane still sees the full window (see ``apply_batch``'s docstring and
DESIGN.md §3.3).

Replication (SNAPSHOT client-centric, FUSEE; DESIGN.md §13): with
``EngineConfig.n_replicas = R > 1`` every write-class verb — WRITEs, CASes
(lock words, pointer installs, retries, SCAN lock traversals, §4.6 repair
break-CASes) and FAAs — fans out from the client to all R replica MNs, while
reads (index READs, SEARCH payloads, the CIDER coordinator lock read, the
repair stale-epoch detection read, SCAN probes) bill to one replica.  The
scaling is a static end-of-metering block on the aggregate bill: read-only
bytes are tracked separately (``ro_bytes``) so
``mn_bytes = ro + R * wr`` exactly, and R=1 skips the block entirely — the
compiled program is byte-identical to the pre-replication engine.  Results
are replica-count-invariant: replicas hold identical logical state, so
per-op outcomes, combining, ranks and waits never see R.

Crash recovery (§4.6, DESIGN.md §8): ``apply_batch`` additionally accepts a
liveness plane (``alive``/``died`` CN masks).  Ops from dead CNs are dropped
at the window boundary; the pessimistic writes a newly-died CN had in flight
strand orphaned locks, which surviving waiters detect via the stale-epoch
read and break with a repair CAS — billed exactly (``IOMetrics.repair_cas``)
with the lease wait charged to the blocked queue (``Results.orphan_wait``).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core import combine as wc
from repro.core.credits import (CreditState, credit_decide, credit_feedback,
                                credit_slot)
from repro.core.types import (NULL_PTR, EngineConfig, IOMetrics, OpBatch,
                              OpKind, SyncMode)

__all__ = ["StoreState", "Results", "store_init", "store_view", "apply_batch",
           "populate", "pack_meta"]

_NONE = jnp.int32(-1)


_VER_MASK = jnp.int32(0xF)      # the 4-bit version field of Fig 8
_STRANDED_SHIFT = 4


def pack_meta(ver: jax.Array, stranded: jax.Array) -> jax.Array:
    """Pack the two small per-slot planes into one int32 word: the 4-bit
    DELETE version (bits 0-3, §4.2.2) and the orphaned-lock node count
    (bits 4-31, §4.6 — MCS chains bound it by the window size, far below
    2^28).  One word instead of two halves the slot-metadata footprint,
    which is what keeps the donated-buffer window scan resident at the
    multi-million-key sizes ``benchmarks/scale.py`` runs (DESIGN.md §12)."""
    return ver | (stranded << _STRANDED_SHIFT)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class StoreState:
    """The memory-pool resident state (all arrays shardable over slots)."""
    ptr: jax.Array       # (n_slots,) int32 heap index, NULL_PTR if empty
    meta: jax.Array      # (n_slots,) int32 packed per-slot metadata —
                         # see ``pack_meta``: 4-bit DELETE version (§4.2.2)
                         # + orphaned-lock node count (§4.6); read through
                         # the ``ver``/``stranded`` properties
    epoch: jax.Array     # (n_slots,) int32 lock epoch (fault tolerance, §4.6)
    heap: jax.Array      # (heap_slots,) int32 out-of-place value payloads
    heap_top: jax.Array  # () int32 bump cursor

    @property
    def ver(self) -> jax.Array:
        """(n_slots,) 4-bit DELETE version, unpacked from ``meta``."""
        return self.meta & _VER_MASK

    @property
    def stranded(self) -> jax.Array:
        """(n_slots,) orphaned lock nodes on each slot — a CN died holding/
        queued on the lock and no live waiter has broken it yet (§4.6)."""
        return self.meta >> _STRANDED_SHIFT


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Results:
    ok: jax.Array           # (B,) bool — success (IDU) / found (SEARCH)
    value: jax.Array        # (B,) int32 — SEARCH payload, _NONE if absent
    pessimistic: jax.Array  # (B,) bool — CIDER path decision (Fig 14)
    combined: jax.Array     # (B,) bool — write combined away by WC
    wc_batch: jax.Array     # (B,) int32 — wait-queue length at execution
    retries: jax.Array      # (B,) int32 — CAS retries (optimistic path ops)
    rank: jax.Array         # (B,) int32 — wait-queue rank at execution
                            # (0 = queue head / uncontended); feeds the
                            # modeled-latency derivation (runner.modeled_latency)
    orphan_wait: jax.Array  # (B,) int32 — orphaned (holder-dead) locks this op
                            # waited a lease expiry on before its queue could
                            # repair them (§4.6); modeled latency charges
                            # lease_us + the repair RTTs per unit
    rows: jax.Array         # (B,) int32 — SCAN rows found in [key, key+count)
                            # at the op's serialization position (DESIGN.md
                            # §9); 0 for point ops.  Sharded runs psum the
                            # per-shard sub-run counts.


def store_init(cfg: EngineConfig) -> StoreState:
    return StoreState(
        ptr=jnp.full((cfg.n_slots,), NULL_PTR, jnp.int32),
        meta=jnp.zeros((cfg.n_slots,), jnp.int32),
        epoch=jnp.zeros((cfg.n_slots,), jnp.int32),
        heap=jnp.full((cfg.heap_slots,), _NONE, jnp.int32),
        heap_top=jnp.zeros((), jnp.int32),
    )


def populate(cfg: EngineConfig, state: StoreState, keys, values) -> StoreState:
    """Bulk-load KV pairs (the paper pre-populates 60M items, §5.1)."""
    keys = jnp.asarray(keys, jnp.int32)
    values = jnp.asarray(values, jnp.int32)
    n = keys.shape[0]
    if n > cfg.heap_slots:
        raise ValueError(
            f"populate: {n} pairs exceed heap_slots={cfg.heap_slots}")
    loc = state.heap_top + jnp.arange(n, dtype=jnp.int32)
    heap = state.heap.at[loc].set(values)
    ptr = state.ptr.at[keys].set(loc)
    return dataclasses.replace(state, ptr=ptr, heap=heap, heap_top=state.heap_top + n)


def store_view(state: StoreState) -> tuple[jax.Array, jax.Array]:
    """Logical (exists, value) view — what tests compare across sync modes."""
    exists = state.ptr != NULL_PTR
    val = jnp.where(exists, state.heap[jnp.clip(state.ptr, 0)], _NONE)
    return exists, val


# ---------------------------------------------------------------------------
# Segmented linearization: per-slot sequential semantics, fully vectorized.
# Each op is a transfer function on (exists, value).  On a 2-point domain a
# composition chain collapses to "the last op that *set* the component": the
# last INSERT/DELETE before me decides existence, and from that the
# value-writing events (INSERT into empty, UPDATE/DELETE of occupied) are
# known lane-locally, so the last such event before me decides the value.
# Two prefix cummax sweeps + gathers replace the associative_scan of packed
# transfer matrices the engine used to run (~14x cheaper; DESIGN.md §10.2),
# bit-identically — every quantity is an exact int op.
# ---------------------------------------------------------------------------

def _last_before(marker, code):
    """Per lane: the ``code`` of the last marked lane STRICTLY before it
    (globally), or -1.  ``code`` must be monotone in lane index so a running
    max finds the latest marked lane; callers then check the decoded index
    against their run start to scope the result to the lane's own run."""
    enc = jnp.where(marker, code, -1)
    g = jax.lax.cummax(enc)
    return jnp.concatenate([jnp.full((1,), -1, jnp.int32), g[:-1]])


def _probe_sweep(keys_sorted, setcode, writer, e_init, backend):
    """Dispatch the fused probe pass (existence-before + reader waits over
    sorted lanes) to the Pallas ``scan_probe`` kernel or its jnp oracle
    (DESIGN.md §10.3)."""
    impl, interpret = wc.resolve_backend(backend)
    if impl == "pallas":
        from repro.kernels.scan_probe.ops import scan_probe_op
        return scan_probe_op(keys_sorted, setcode, writer, e_init,
                             interpret=interpret)
    from repro.kernels.scan_probe.ref import scan_probe_ref
    return scan_probe_ref(keys_sorted, setcode, writer, e_init)


# ---------------------------------------------------------------------------
# Mode-specific I/O metering helpers
# ---------------------------------------------------------------------------

def _backoff_polls(wait_rounds, cap):
    """Deterministic truncated-exponential-backoff poll count while waiting
    ``wait_rounds`` service rounds: probes at 1,2,4,...,2^cap,2^cap,... ."""
    w = wait_rounds.astype(jnp.float32)
    exp_phase = jnp.ceil(jnp.log2(jnp.maximum(w, 1.0) + 1.0))
    exp_phase = jnp.minimum(exp_phase, float(cap))
    linear = jnp.maximum(w - (2.0 ** cap - 1.0), 0.0) / (2.0 ** cap)
    return jnp.where(wait_rounds > 0, exp_phase + jnp.floor(linear), 0.0).astype(jnp.int32)


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("cfg",))
def apply_batch(cfg: EngineConfig, state: StoreState, credits: CreditState,
                batch: OpBatch, valid: jax.Array | None = None,
                owned: jax.Array | None = None,
                slot_base: jax.Array | None = None,
                alive: jax.Array | None = None,
                died: jax.Array | None = None,
                ) -> tuple[StoreState, CreditState, Results, IOMetrics]:
    """Execute one synchronization window. See module docstring.

    Sharded operation (``repro.dist.store``): ``owned`` masks the ops whose
    key's slot lives in this shard, and ``slot_base`` is the shard's first
    global slot (store arrays are indexed by ``keys - slot_base``).  The
    *data plane* (linearization, commits, results, I/O metering) runs on the
    owned subset only; the *credit plane* (contention-aware path decisions
    and AIMD feedback, §4.3) runs on the FULL batch with global keys on every
    shard, so the replicated credit table evolves identically everywhere and
    per-shard I/O sums to the single-device bill exactly.

    Liveness plane (crash recovery, §4.6 — ``repro.recovery``): ``alive`` is
    a ``(n_cns,)`` mask of compute nodes alive through this window and
    ``died`` marks CNs that crashed *at this window* (were alive at window
    start).  Ops from non-alive CNs are dropped at the window boundary —
    exactly as a real crash strands them — while the in-flight pessimistic
    writes of newly-died CNs strand orphaned locks that the next surviving
    waiter detects via the stale-epoch read and breaks with a repair CAS
    (billed into ``IOMetrics.repair_cas`` and the affected queue's
    ``Results.orphan_wait``); locks on slots with no surviving waiter stay
    recorded in ``StoreState.stranded`` until their next locker arrives.
    """
    b = batch.kinds.shape[0]
    kinds, keys, values, pos, cn = (batch.kinds, batch.keys, batch.values,
                                    batch.pos, batch.cn)
    if valid is None:
        valid = kinds != OpKind.NOP
    else:
        valid = valid & (kinds != OpKind.NOP)
    if cfg.scan_max == 0:
        # no probe capacity compiled in: SCAN lanes must not silently charge
        # point-op I/O and return 0 rows — they are dropped here, and the
        # point-op stores reject them loudly before ever reaching the engine
        valid = valid & (kinds != OpKind.SCAN)
    # present: ops issued into this window (including ones whose CN crashes
    # mid-window — the orphan candidates); valid: ops that complete.
    present = valid
    if alive is not None:
        a = jnp.asarray(alive, bool)
        valid = valid & a[jnp.clip(cn, 0, a.shape[0] - 1)]
    # valid: ops present in the window (credit plane); valid_o: ops whose
    # store state this shard owns (data plane).  Identical when not sharded.
    valid_o = valid if owned is None else valid & owned
    base = jnp.int32(0) if slot_base is None else jnp.asarray(slot_base, jnp.int32)
    is_search = (kinds == OpKind.SEARCH) & valid_o
    is_insert = (kinds == OpKind.INSERT) & valid_o
    is_update = (kinds == OpKind.UPDATE) & valid_o
    is_delete = (kinds == OpKind.DELETE) & valid_o
    upd_full = (kinds == OpKind.UPDATE) & valid

    # ---- 1. linearize: one sorted last-setter sweep serializes every
    # slot's queue (DESIGN.md §10.2) ----
    plan_all = wc.plan_combine(keys, pos, valid_o, backend=cfg.kernel_backend)
    perm = plan_all.perm
    idx = jnp.arange(b, dtype=jnp.int32)
    seg_start = idx - plan_all.rank
    seg_end = seg_start + plan_all.run_length - 1
    ks = kinds[perm]
    vals = values[perm]
    v_sorted = valid_o[perm]
    ins_s = v_sorted & (ks == OpKind.INSERT)
    upd_s = v_sorted & (ks == OpKind.UPDATE)
    del_s = v_sorted & (ks == OpKind.DELETE)
    # incoming (pre-window) state per sorted element's slot (shard-local)
    slot = jnp.clip(keys[perm] - base, 0, cfg.n_slots - 1)
    p = state.ptr[slot]
    e_init = p != NULL_PTR
    v_init = jnp.where(e_init, state.heap[jnp.clip(p, 0)], _NONE)
    # existence BEFORE each op: the last INSERT (sets present) or DELETE
    # (sets absent) strictly before it in its run; else the slot's
    # pre-window bit.  UPDATE/SEARCH never flip existence.
    setcode = jnp.where(ins_s, jnp.int32(1),
                        jnp.where(del_s, jnp.int32(0), jnp.int32(-1)))
    g_excl = _last_before(setcode >= 0, 2 * idx + setcode)
    has = (g_excl >= 0) & ((g_excl >> 1) >= seg_start)
    e_before = jnp.where(has, (g_excl & 1) == 1, e_init)
    # value BEFORE each op: the last value-writing event strictly before it
    # (INSERT into empty / UPDATE of occupied write the payload, successful
    # DELETE writes the tombstone); else the slot's pre-window value.
    w_ev = (ins_s & ~e_before) | ((upd_s | del_s) & e_before)
    val_w = jnp.where(del_s, _NONE, vals)
    gv_excl = _last_before(w_ev, idx)
    hasv = (gv_excl >= 0) & (gv_excl >= seg_start)
    v_before = jnp.where(hasv, val_w[jnp.clip(gv_excl, 0)], v_init)
    # per-op success / search results (sorted order)
    ok_s = jnp.where(ks == OpKind.SEARCH, e_before,
            jnp.where(ks == OpKind.INSERT, ~e_before,
             jnp.where((ks == OpKind.UPDATE) | (ks == OpKind.DELETE), e_before, False)))
    ok_s = ok_s & v_sorted
    val_s = jnp.where((ks == OpKind.SEARCH) & e_before & v_sorted,
                      v_before, _NONE)
    # state AFTER each op (at run tails: the new slot contents)
    e_fin = jnp.where(setcode >= 0, setcode == 1, e_before)
    v_fin = jnp.where(w_ev, val_w, v_before)
    seg_changed = ok_s & (ks != OpKind.SEARCH)          # any successful IDU
    # per-run reductions via prefix sums gathered at run bounds
    sc_i = seg_changed.astype(jnp.int32)
    cw = jnp.cumsum(sc_i)
    seg_any_write = (cw[seg_end] - (cw - sc_i)[seg_start]) > 0
    # ---- 2. commit final slot states (one out-of-place write per queue) ----
    # Out-of-bounds indices with mode="drop" mask out non-committing lanes.
    tail = plan_all.is_last & seg_any_write & v_sorted
    oob_h, oob_s = jnp.int32(cfg.heap_slots), jnp.int32(cfg.n_slots)
    n_commits = jnp.sum(tail.astype(jnp.int32))
    commit_rank = jnp.cumsum(tail.astype(jnp.int32)) - 1
    loc = (state.heap_top + commit_rank).astype(jnp.int32)
    heap = state.heap.at[jnp.where(tail, loc, oob_h)].set(v_fin, mode="drop")
    new_ptr_val = jnp.where(e_fin, loc, NULL_PTR)
    ptr = state.ptr.at[jnp.where(tail, slot, oob_s)].set(new_ptr_val, mode="drop")
    # version: +1 per successful DELETE (mod 16 — the 4-bit field of Fig 8)
    del_succ = del_s & ok_s
    ds_i = del_succ.astype(jnp.int32)
    cd = jnp.cumsum(ds_i)
    run_del = cd[seg_end] - (cd - ds_i)[seg_start]
    ver = (state.ver.at[jnp.where(plan_all.is_last, slot, oob_s)]
           .add(run_del, mode="drop")) % 16

    # ---- 3. synchronization-mode decision (CIDER credit split, §4.3) ----
    # Decided on the FULL window (upd_full, global keys): every shard's
    # replica of the credit table sees every op and stays bit-identical.
    if cfg.mode == SyncMode.CIDER:
        credits2, pess_full = credit_decide(credits, keys, upd_full,
                                            credits.credit.shape[0])
    elif cfg.mode in (SyncMode.MCS, SyncMode.SPIN):
        credits2, pess_full = credits, upd_full
    else:  # OSYNC
        credits2, pess_full = credits, jnp.zeros_like(upd_full)
    pess = pess_full & valid_o
    opt_upd = is_update & ~pess_full

    # ---- 4. effective writers after local WC (per (key, CN) group) --------
    # Local WC combines same-CN UPDATEs (applied to every baseline, §5.1);
    # combined ops never leave the CN.  CIDER's pessimistic path does NOT
    # pre-filter: every client enqueues in the *global* MCS queue (Fig 7),
    # and global WC subsumes local WC.
    # Two bit-identical groupings (DESIGN.md §10.2): with a static CN count
    # in scope (the liveness plane's shape — always true on the fused
    # runner/bench path) each masked subset costs one O(B) scatter-max over
    # (key, cn) cells; otherwise one (key, CN, pos) sort over the owned
    # window serves every subset — both executor masks are subsets of
    # valid_o, so group_last on the shared plan matches a dedicated
    # local_executors sort per mask.
    n_cns_static = None
    for liveness in (alive, died):
        if liveness is not None:
            n_cns_static = jnp.asarray(liveness).shape[0]
            break
    if n_cns_static is not None:
        def _group_last(mask):
            return wc.local_executors_scatter(keys, cn, pos, mask,
                                              cfg.n_slots, n_cns_static, base)
    else:
        gplan = wc.plan_groups(keys, cn, pos, valid_o) if cfg.local_wc else None

        def _group_last(mask):
            return wc.group_last(gplan, mask)
    loc_exec_opt = _group_last(opt_upd) if cfg.local_wc else opt_upd
    if cfg.mode in (SyncMode.CIDER, SyncMode.OSYNC) or not cfg.local_wc:
        # CIDER: global WC subsumes local; OSYNC: pess is statically empty
        loc_exec_pess = pess
    else:
        loc_exec_pess = _group_last(pess)

    # ---- 5. per-mode I/O metering ------------------------------------------
    i64 = jnp.int32
    def s(x):
        return jnp.sum(x.astype(i64))

    n_found_search = jnp.sum(((ks == OpKind.SEARCH) & ok_s).astype(jnp.int32))
    reads = s(valid_o) * cfg.index_read_iops + n_found_search
    mn_bytes = (s(valid_o) * cfg.index_read_bytes + n_found_search * cfg.value_bytes)
    # read-only bytes bill to ONE replica under replication (DESIGN.md §13);
    # every other mn_bytes contribution below is write-class and fans out xR
    ro_bytes = mn_bytes
    writes = jnp.zeros((), i64)
    cas = jnp.zeros((), i64)
    faa = jnp.zeros((), i64)
    cn_msgs = jnp.zeros((), i64)
    retries_total = jnp.zeros((), i64)
    combined_total = jnp.zeros((), i64)
    per_op_retries = jnp.zeros((b,), jnp.int32)
    per_op_combined = jnp.zeros((b,), bool)
    per_op_batch = jnp.ones((b,), jnp.int32)
    per_op_rank = jnp.zeros((b,), jnp.int32)
    per_op_rows = jnp.zeros((b,), jnp.int32)

    # INSERTs: optimistic CAS on the empty pointer in every mode (§4.2.2);
    # concurrent same-key INSERTs: exactly one wins, losers fail once.
    writes += s(is_insert)
    cas += s(is_insert)
    mn_bytes += s(is_insert) * (cfg.value_bytes + cfg.ptr_bytes)

    # DELETEs: pessimistic modes lock (enqueue-CAS + ptr-CAS + epoch-FAA);
    # under OSYNC they CAS-retry in the SAME per-key optimistic queue as
    # concurrent UPDATEs (both CAS the same pointer), metered jointly below.
    n_del = s(is_delete)
    if cfg.mode != SyncMode.OSYNC:
        cas += 2 * n_del
        faa += n_del
        mn_bytes += n_del * (2 * cfg.ptr_bytes + 8)

    # Optimistic CAS path -----------------------------------------------------
    # One joint queue per key: UPDATE executors after local WC, plus (OSYNC
    # only) DELETEs — cross-kind conflicts on a pointer retry against each
    # other, so metering them as two independent queues undercounts retries.
    if cfg.mode == SyncMode.OSYNC:
        opt_queue = loc_exec_opt | is_delete
    else:
        opt_queue = loc_exec_opt
    # opt_queue/loc_exec_pess ⊆ valid_o, so their queue statistics fall out
    # of plan_all's existing sort (stats_from_plan, DESIGN.md §10.2) —
    # bit-identical to per_key_stats, minus the extra lexsorts.
    plan_o = wc.stats_from_plan(plan_all, opt_queue)
    m_opt_writes = s(loc_exec_opt)                   # DELETEs write no heap
    writes += m_opt_writes
    cas += s(opt_queue) + plan_o.retry_sum
    retries_total += plan_o.retry_sum
    mn_bytes += (m_opt_writes * cfg.value_bytes
                 + (s(opt_queue) + plan_o.retry_sum) * cfg.ptr_bytes)
    combined_total += s(opt_upd) - m_opt_writes      # local-WC combined
    per_op_retries = jnp.where(opt_queue, plan_o.rank_of, per_op_retries)
    per_op_rank = jnp.where(opt_queue, plan_o.rank_of, per_op_rank)
    per_op_combined = per_op_combined | (opt_upd & ~loc_exec_opt)

    # pessimistic subset
    m_pe = s(loc_exec_pess)                          # effective queued writers
    if cfg.mode == SyncMode.SPIN:
        plan_p = wc.stats_from_plan(plan_all, loc_exec_pess)
        polls = _backoff_polls(plan_p.rank_of * 3, cfg.backoff_cap)
        polls_sum = s(jnp.where(loc_exec_pess, polls, 0))
        writes += m_pe
        cas += 3 * m_pe + polls_sum                  # lock + ptr + unlock + polls
        retries_total += polls_sum
        mn_bytes += m_pe * (cfg.value_bytes + 3 * cfg.ptr_bytes) + polls_sum * cfg.ptr_bytes
        per_op_retries = jnp.where(loc_exec_pess, polls, per_op_retries)
        per_op_rank = jnp.where(loc_exec_pess, plan_p.rank_of, per_op_rank)
    elif cfg.mode == SyncMode.MCS:
        writes += m_pe
        cas += 2 * m_pe                              # enqueue masked-CAS + ptr CAS
        faa += m_pe                                  # epoch release
        plan_p = wc.stats_from_plan(plan_all, loc_exec_pess)
        cn_msgs += 2 * s(jnp.where(loc_exec_pess, (plan_p.mult_of > 1), 0))
        mn_bytes += m_pe * (cfg.value_bytes + 2 * cfg.ptr_bytes + 8)
        per_op_batch = jnp.where(loc_exec_pess, 1, per_op_batch)
        per_op_rank = jnp.where(loc_exec_pess, plan_p.rank_of, per_op_rank)
    elif cfg.mode == SyncMode.CIDER:
        # global WC: all queued writers on a key collapse to ONE executed write
        plan_p = wc.stats_from_plan(plan_all, loc_exec_pess)
        is_exec = loc_exec_pess & plan_p.is_tail     # queue tail = executor
        n_q = s(is_exec)                             # number of wait queues
        multi = loc_exec_pess & (plan_p.mult_of > 1)
        n_multi_q = s(is_exec & (plan_p.mult_of > 1))
        cas += m_pe + n_q                            # m enqueues + 1 ptr CAS per queue
        writes += n_q                                # ONE combined write per queue
        faa += m_pe                                  # every client's release FAA
        reads += n_multi_q                           # coordinator tail lookup (step 1)
        cn_msgs += s(jnp.where(is_exec & (plan_p.mult_of > 1),
                               plan_p.mult_of + 1, 0))
        mn_bytes += (m_pe * cfg.ptr_bytes + n_q * (cfg.value_bytes + cfg.ptr_bytes)
                     + m_pe * 8 + n_multi_q * cfg.lock_bytes)
        ro_bytes += n_multi_q * cfg.lock_bytes       # coordinator tail READ
        combined_total += s(pess) - n_q
        per_op_combined = per_op_combined | (pess & ~is_exec)
        per_op_batch = jnp.where(loc_exec_pess, plan_p.mult_of, per_op_batch)
        per_op_rank = jnp.where(loc_exec_pess, plan_p.rank_of, per_op_rank)

    executed = writes

    # ---- 5b. lease/epoch orphaned-lock repair (crash recovery, §4.6) ------
    # A CN that dies mid-window strands the locks it held or was queued on.
    # The next surviving waiter notices the stale epoch (the holder stopped
    # FAA-ing), waits out the lease, and breaks the lock with a repair CAS.
    # Mode asymmetry — the recovery result: CIDER's combined queue has ONE
    # lock entry per queue and SPIN one lock word per key, while MCS strands
    # the whole chain of dead queue nodes, each repaired by its successor.
    per_op_orphan = jnp.zeros((b,), jnp.int32)
    repair_total = jnp.zeros((), i64)
    orphan_out = jnp.zeros((), i64)
    if cfg.mode == SyncMode.OSYNC:
        stranded = state.stranded          # lock-free: crashes strand nothing
    else:
        slot_u = jnp.clip(keys - base, 0, cfg.n_slots - 1)
        if died is None:
            add_slot = jnp.zeros((cfg.n_slots,), jnp.int32)
        else:
            d = jnp.asarray(died, bool)
            dead_w = (present & d[jnp.clip(cn, 0, d.shape[0] - 1)]
                      & ((kinds == OpKind.UPDATE) | (kinds == OpKind.DELETE)))
            if owned is not None:
                dead_w = dead_w & owned
            if cfg.mode == SyncMode.CIDER:
                # only writers the credit table routes pessimistically held a
                # lock when they crashed; a dead optimistic writer strands
                # nothing (its out-of-place CAS simply never lands) — the
                # lock-free-crash argument FUSEE makes.  DELETEs always lock.
                cslot = credit_slot(keys, credits.credit.shape[0])
                dead_node = dead_w & ((credits.credit[cslot] > 0)
                                      | (kinds == OpKind.DELETE))
            elif cfg.local_wc:
                # only the local executor of each (key, CN) UPDATE group had
                # left the crashed CN for the memory pool; DELETEs are never
                # locally combined (they lock independently on the live path
                # too), so each dead DELETE strands its own node.  `died`
                # is in scope here, so the static-CN scatter grouping always
                # applies — no extra sort on the recovery plane.
                dead_upd = dead_w & (kinds == OpKind.UPDATE)
                dead_node = (_group_last(dead_upd)
                             | (dead_w & (kinds == OpKind.DELETE)))
            else:
                dead_node = dead_w
            # stranded-node count per slot: dead nodes are keyed by slot
            # already, so the per-key multiplicity-at-tail reduction the
            # sort-based stats would compute IS this one scatter-add
            # (MCS strands the whole chain; SPIN/CIDER one word/entry)
            cnt = jnp.zeros((cfg.n_slots,), jnp.int32).at[slot_u].add(
                dead_node.astype(jnp.int32))
            add_slot = (cnt if cfg.mode == SyncMode.MCS
                        else jnp.minimum(cnt, 1))
        tot = state.stranded + add_slot
        if cfg.mode != SyncMode.MCS:
            tot = jnp.minimum(tot, 1)      # one lock word/entry per key
        # any surviving locker on the slot repairs all its stranded nodes
        # this window; untouched slots stay stranded for their next locker
        surv = loc_exec_pess | is_delete
        surv_slot = jnp.zeros((cfg.n_slots,), bool).at[
            jnp.where(surv, slot_u, cfg.n_slots)].set(True, mode="drop")
        repaired = surv_slot & (tot > 0)
        n_repair = jnp.sum(jnp.where(repaired, tot, 0).astype(i64))
        stranded = jnp.where(repaired, 0, tot)
        orphan_out = jnp.sum((stranded > 0).astype(i64))
        per_op_orphan = jnp.where(surv & repaired[slot_u], tot[slot_u], 0)
        # bill: one stale-epoch READ of the lock entry + one break CAS per
        # stranded node, charged to the blocked queue
        reads += n_repair
        cas += n_repair
        repair_total += n_repair
        mn_bytes += n_repair * (cfg.lock_bytes + 8)
        ro_bytes += n_repair * cfg.lock_bytes        # stale-epoch detection READ
        if cfg.mode == SyncMode.SPIN:
            # spinners keep re-CASing the orphaned word until the lease
            # expires — MN verbs MCS/CIDER waiters never issue (they wait
            # CN-locally); these polls ARE recovery overhead, so they are
            # folded into repair_cas as well
            pollc = _backoff_polls(jnp.asarray(cfg.lease_poll_rounds, jnp.int32),
                                   cfg.backoff_cap)
            lease_polls = per_op_orphan * pollc
            polls_lease = s(lease_polls)
            cas += polls_lease
            retries_total += polls_lease
            repair_total += polls_lease
            mn_bytes += polls_lease * cfg.ptr_bytes
            per_op_retries = per_op_retries + lease_polls

    # ---- 5c. SCAN reader probes (range reads, DESIGN.md §9, §10.3) --------
    # A SCAN(key, count) expands into `count` reader probes over the
    # contiguous leaf-slot run [key, key+count), each joining its slot's wait
    # queue *as a reader* at the scanning op's batch position.  ONE sort of
    # the combined writer+probe lanes feeds the fused scan_probe pass, which
    # yields both the existence each probe observes at its serialization
    # position AND its wait rank behind exclusive lock holders — the second
    # linearization sweep and the separate reader_waits sort this step used
    # to pay are gone (DESIGN.md §10.3).  Probes outside [slot_base,
    # slot_base + n_slots) belong to another shard (or fall off the keyspace
    # end): each shard counts its own sub-run and the dist psum reassembles
    # the rows.
    if cfg.scan_max > 0:
        ns = cfg.scan_max
        is_scan = (kinds == OpKind.SCAN) & valid
        count = jnp.clip(values, 0, ns)               # count rides `values`
        jj = jnp.arange(ns, dtype=jnp.int32)
        pk = keys[:, None] + jj[None, :]              # (B, ns) global slots
        p_loc = pk - base
        p_in = (is_scan[:, None] & (jj[None, :] < count[:, None])
                & (p_loc >= 0) & (p_loc < cfg.n_slots))
        keys_p = pk.reshape(b * ns)
        pos_p = jnp.broadcast_to(pos[:, None], (b, ns)).reshape(b * ns)
        pv = p_in.reshape(b * ns)
        keys_c = jnp.concatenate([keys, keys_p])
        pos_c = jnp.concatenate([pos, pos_p])
        tvalid = jnp.concatenate([valid_o, pv])
        plan_c = wc.plan_combine(keys_c, pos_c, tvalid,
                                 backend=cfg.kernel_backend)
        pc = plan_c.perm
        bc = b * (1 + ns)
        # existence transfers: only this window's INSERT/DELETE lanes set the
        # bit; probes (readers) and UPDATEs are identity — so the probe pass
        # needs no value plane at all
        setcode_c = jnp.concatenate(
            [jnp.where(is_insert, jnp.int32(1),
                       jnp.where(is_delete, jnp.int32(0), jnp.int32(-1))),
             jnp.full((b * ns,), -1, jnp.int32)])
        lockw = loc_exec_pess | is_delete             # exclusive lock holders
        writer_c = jnp.concatenate([lockw, jnp.zeros((b * ns,), bool)])
        slot_c = jnp.clip(keys_c[pc] - base, 0, cfg.n_slots - 1)
        e_init_c = state.ptr[slot_c] != NULL_PTR
        e_bc, waits_s = _probe_sweep(plan_c.keys_sorted, setcode_c[pc],
                                     writer_c[pc], e_init_c,
                                     cfg.kernel_backend)
        v_sc = tvalid[pc]
        e_probe = jnp.zeros((bc,), bool).at[pc].set(e_bc & v_sc)
        hit = e_probe[b:].reshape(b, ns) & p_in
        per_op_rows = jnp.sum(hit.astype(jnp.int32), axis=1)
        n_probes = s(pv)
        n_rows = s(hit)
        # base bill: one leaf-entry READ per probed slot + one value READ per
        # row found (every mode traverses the same run)
        reads += n_probes + n_rows
        mn_bytes += n_probes * cfg.ptr_bytes + n_rows * cfg.value_bytes
        ro_bytes += n_probes * cfg.ptr_bytes + n_rows * cfg.value_bytes
        if cfg.mode == SyncMode.OSYNC:
            # optimistic traversal must re-read each leaf's version to
            # validate against concurrent pointer swaps (§2.2's cost, paid
            # per leaf whether or not anyone wrote)
            reads += n_probes
            mn_bytes += n_probes * cfg.ptr_bytes
            ro_bytes += n_probes * cfg.ptr_bytes
        elif cfg.mode == SyncMode.SPIN:
            # a CAS spinlock has no shared mode: lock + unlock CAS per leaf
            cas += 2 * n_probes
            mn_bytes += 2 * n_probes * cfg.ptr_bytes
        elif cfg.mode == SyncMode.MCS:
            # lock-shared traversal: shared-mode enqueue CAS + release FAA
            # per leaf (the epoch heartbeat plane tracks exclusive holders
            # only — the reader FAA is billed, not recorded)
            cas += n_probes
            faa += n_probes
            mn_bytes += n_probes * (cfg.ptr_bytes + 8)
        else:  # CIDER: consult the CN-local credit table (free) — cold
               # leaves are traversed lock-free like OSYNC *without* the
               # re-read (the table certifies no concurrent pessimistic
               # writer), hot leaves join the queue in shared mode
            cslot_p = credit_slot(keys_p, credits.credit.shape[0])
            hot_p = pv & (credits.credit[cslot_p] > 0)
            n_hot = s(hot_p)
            cas += n_hot
            faa += n_hot
            mn_bytes += n_hot * (cfg.lock_bytes + 8)
        if cfg.mode != SyncMode.OSYNC:
            # wait rank of the anchor-leaf reader behind exclusive holders
            # (queue order == batch position now includes reader ranks) —
            # already computed by the fused pass above; just unsort it
            readers_s = jnp.concatenate([jnp.zeros((b,), bool), pv])[pc]
            waits = jnp.zeros((bc,), jnp.int32).at[pc].set(
                jnp.where(readers_s, waits_s, 0))
            per_op_rank = jnp.where(p_in[:, 0], waits[b:].reshape(b, ns)[:, 0],
                                    per_op_rank)

    # ---- 5d. SNAPSHOT replica fan-out (FUSEE; DESIGN.md §13) --------------
    # Client-centric replication: the client issues every write-class verb
    # to all R replica MNs itself (no MN CPU forwards anything), so the
    # aggregate bill scales exactly xR on WRITE/CAS/FAA — including retries
    # and repair break-CASes, which are failed/extra CASes on every replica's
    # word — while reads go to one replica.  Static branch: R=1 compiles to
    # the byte-identical pre-replication program (tests/test_replication.py).
    # Per-op Results are logical-op observables and never scale.
    if cfg.n_replicas > 1:
        rep = cfg.n_replicas
        writes = writes * rep
        cas = cas * rep
        faa = faa * rep
        retries_total = retries_total * rep
        repair_total = repair_total * rep
        mn_bytes = ro_bytes + rep * (mn_bytes - ro_bytes)

    # ---- 6. credit feedback (§4.3, Algorithm 1 lines 13-22) ---------------
    # Like the decision, feedback runs on the FULL window so replicated
    # credit tables stay identical across shards; when unsharded the full
    # masks ARE the owned masks and nothing is recomputed.
    if cfg.mode == SyncMode.CIDER:
        if owned is None:
            pess_fb, batch_fb = loc_exec_pess, per_op_batch
            opt_fb, retry_fb = loc_exec_opt | is_insert, per_op_retries
        else:
            # full-window masks are subsets of `valid` (not valid_o), so the
            # replicated credit plane pays one full-validity plan of each
            # kind and derives both feedback stats from it (DESIGN.md §10.2)
            opt_upd_full = upd_full & ~pess_full
            if cfg.local_wc:
                gplan_full = wc.plan_groups(keys, cn, pos, valid)
                loc_opt_full = wc.group_last(gplan_full, opt_upd_full)
            else:
                loc_opt_full = opt_upd_full
            plan_full = wc.plan_combine(keys, pos, valid,
                                        backend=cfg.kernel_backend)
            plan_p_fb = wc.stats_from_plan(plan_full, pess_full)
            plan_o_fb = wc.stats_from_plan(plan_full, loc_opt_full)
            pess_fb = pess_full
            batch_fb = jnp.where(pess_full, plan_p_fb.mult_of, 1)
            opt_fb = loc_opt_full | ((kinds == OpKind.INSERT) & valid)
            retry_fb = jnp.where(loc_opt_full, plan_o_fb.rank_of, 0)
        credits3 = credit_feedback(
            credits2, keys, credits.credit.shape[0],
            pess=pess_fb, wc_batch=batch_fb,
            opt=opt_fb, n_retry=retry_fb,
            initial_credit=cfg.initial_credit,
            hotness_threshold=cfg.hotness_threshold,
            aimd_factor=cfg.aimd_factor)
    else:
        credits3 = credits2

    # ---- 7. epoch FAA bookkeeping (fault-tolerance heartbeat, §4.6) -------
    if cfg.mode in (SyncMode.MCS, SyncMode.CIDER):
        rel = loc_exec_pess | is_delete
        epoch = state.epoch.at[jnp.where(rel, keys - base, cfg.n_slots)].add(
            rel.astype(jnp.int32), mode="drop")
    else:
        epoch = state.epoch

    new_state = StoreState(ptr=ptr, meta=pack_meta(ver, stranded),
                           epoch=epoch, heap=heap,
                           heap_top=state.heap_top + n_commits)
    # unsort results
    ok = jnp.zeros((b,), bool).at[perm].set(ok_s)
    # SCAN succeeds when it found any row; per-shard partial counts OR
    # together under the dist psum exactly as the totals add
    ok = ok | (per_op_rows > 0)
    value = jnp.full((b,), _NONE, jnp.int32).at[perm].set(val_s)
    res = Results(ok=ok, value=value, pessimistic=pess,
                  combined=per_op_combined, wc_batch=per_op_batch,
                  retries=per_op_retries, rank=per_op_rank,
                  orphan_wait=per_op_orphan, rows=per_op_rows)
    io = IOMetrics(reads=reads, writes=writes, cas=cas, faa=faa,
                   cn_msgs=cn_msgs, mn_bytes=mn_bytes, retries=retries_total,
                   combined=combined_total, executed=executed,
                   repair_cas=repair_total, orphan_windows=orphan_out)
    return new_state, credits3, res, io
