"""The CIDER batched dataplane engine (§4, TPU adaptation).

Executes one *synchronization window* — a device batch of concurrent KV ops —
against a pointer store under one of four synchronization schemes
(``SyncMode``): OSYNC (optimistic CAS-retry), SPIN (CAS spinlock + backoff),
MCS (ShiftLock), CIDER (global write-combining + contention-aware credits).

Design invariants:

* **Semantic equivalence**: all four modes produce the *same* logical store
  state and per-op results — the canonical serialization is queue order ==
  batch position (``OpBatch.pos``), which is exactly what the MCS wait queue
  enforces and what last-writer-wins combining preserves (§4.5.1).  Tests
  assert equivalence against a sequential oracle.
* **Exact I/O metering**: modes differ in the RDMA-verb I/O they would issue
  on real DM; we meter those *exactly* (closed-form per wait queue, derived
  from the protocol workflows in Figs 9-10), because memory-side NIC IOPS is
  the paper's bottleneck resource.  The protocol *simulator*
  (``repro.core.sim``) additionally models queueing delay and reproduces the
  paper's throughput/latency figures; this engine is the jit/shard_map
  production path.

Per-queue I/O cost (m = effective concurrent UPDATE writers in the window),
derived from §2.2, §2.3, §4.2, Fig 9-10:

  OSYNC : m heap WRITEs + m(m+1)/2 CAS   (worst-case synchrony; §2.2)
  SPIN  : m WRITEs + m ptr-CAS + m lock-CAS + m unlock-CAS + backoff polls
  MCS   : m enqueue-CAS + m WRITEs + m ptr-CAS + m epoch-FAA + 2(m-1) CN msgs
  CIDER : m enqueue-CAS + 1 tail-READ + 1 WRITE + 1 ptr-CAS + m epoch-FAA
          + (m+1) CN msgs                 (m>1; m==1 falls back to MCS cost)

Local WC (applied to every scheme, §5.1) first collapses same-(key, CN)
writers to one effective writer; CIDER's global WC collapses same-key writers
across CNs to one executor (§4.2.1).

SCAN (range read over [key, key+count), DESIGN.md §9): when
``EngineConfig.scan_max > 0``, each SCAN expands into up to ``scan_max``
reader probes over its contiguous leaf-slot run (step 5c).  Probes join the
per-key wait queues *as readers* at the scanning op's batch position — they
observe exactly the per-slot state at that serialization point — and bill a
per-mode traversal: OSYNC re-reads each leaf's version, SPIN lock/unlock-CASes
every leaf (a CAS spinlock has no shared mode), MCS enqueues shared + releases
per leaf, and CIDER consults the CN-local credit table so cold leaves are
traversed lock-free (only credit-hot leaves pay shared-queue verbs).

The shard_map path (``repro.dist.store``) partitions the store over the
``data`` mesh axis and calls ``apply_batch`` per shard with ``owned``/
``slot_base``: the data plane then covers only the shard's keys while the
credit plane still sees the full window (see ``apply_batch``'s docstring and
DESIGN.md §3.3).

Crash recovery (§4.6, DESIGN.md §8): ``apply_batch`` additionally accepts a
liveness plane (``alive``/``died`` CN masks).  Ops from dead CNs are dropped
at the window boundary; the pessimistic writes a newly-died CN had in flight
strand orphaned locks, which surviving waiters detect via the stale-epoch
read and break with a repair CAS — billed exactly (``IOMetrics.repair_cas``)
with the lease wait charged to the blocked queue (``Results.orphan_wait``).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core import combine as wc
from repro.core.credits import (CreditState, credit_decide, credit_feedback,
                                credit_slot)
from repro.core.types import (NULL_PTR, EngineConfig, IOMetrics, OpBatch,
                              OpKind, SyncMode)

__all__ = ["StoreState", "Results", "store_init", "store_view", "apply_batch",
           "populate"]

_KEEP = jnp.int32(-2)
_NONE = jnp.int32(-1)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class StoreState:
    """The memory-pool resident state (all arrays shardable over slots)."""
    ptr: jax.Array       # (n_slots,) int32 heap index, NULL_PTR if empty
    ver: jax.Array       # (n_slots,) int32 4-bit version (DELETE handling, §4.2.2)
    epoch: jax.Array     # (n_slots,) int32 lock epoch (fault tolerance, §4.6)
    heap: jax.Array      # (heap_slots,) int32 out-of-place value payloads
    heap_top: jax.Array  # () int32 bump cursor
    stranded: jax.Array  # (n_slots,) int32 orphaned lock nodes on this slot —
                         # a CN died holding/queued on the lock and no live
                         # waiter has broken it yet (crash recovery, §4.6)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Results:
    ok: jax.Array           # (B,) bool — success (IDU) / found (SEARCH)
    value: jax.Array        # (B,) int32 — SEARCH payload, _NONE if absent
    pessimistic: jax.Array  # (B,) bool — CIDER path decision (Fig 14)
    combined: jax.Array     # (B,) bool — write combined away by WC
    wc_batch: jax.Array     # (B,) int32 — wait-queue length at execution
    retries: jax.Array      # (B,) int32 — CAS retries (optimistic path ops)
    rank: jax.Array         # (B,) int32 — wait-queue rank at execution
                            # (0 = queue head / uncontended); feeds the
                            # modeled-latency derivation (runner.modeled_latency)
    orphan_wait: jax.Array  # (B,) int32 — orphaned (holder-dead) locks this op
                            # waited a lease expiry on before its queue could
                            # repair them (§4.6); modeled latency charges
                            # lease_us + the repair RTTs per unit
    rows: jax.Array         # (B,) int32 — SCAN rows found in [key, key+count)
                            # at the op's serialization position (DESIGN.md
                            # §9); 0 for point ops.  Sharded runs psum the
                            # per-shard sub-run counts.


def store_init(cfg: EngineConfig) -> StoreState:
    return StoreState(
        ptr=jnp.full((cfg.n_slots,), NULL_PTR, jnp.int32),
        ver=jnp.zeros((cfg.n_slots,), jnp.int32),
        epoch=jnp.zeros((cfg.n_slots,), jnp.int32),
        heap=jnp.full((cfg.heap_slots,), _NONE, jnp.int32),
        heap_top=jnp.zeros((), jnp.int32),
        stranded=jnp.zeros((cfg.n_slots,), jnp.int32),
    )


def populate(cfg: EngineConfig, state: StoreState, keys, values) -> StoreState:
    """Bulk-load KV pairs (the paper pre-populates 60M items, §5.1)."""
    keys = jnp.asarray(keys, jnp.int32)
    values = jnp.asarray(values, jnp.int32)
    n = keys.shape[0]
    loc = state.heap_top + jnp.arange(n, dtype=jnp.int32)
    heap = state.heap.at[loc].set(values)
    ptr = state.ptr.at[keys].set(loc)
    return dataclasses.replace(state, ptr=ptr, heap=heap, heap_top=state.heap_top + n)


def store_view(state: StoreState) -> tuple[jax.Array, jax.Array]:
    """Logical (exists, value) view — what tests compare across sync modes."""
    exists = state.ptr != NULL_PTR
    val = jnp.where(exists, state.heap[jnp.clip(state.ptr, 0)], _NONE)
    return exists, val


# ---------------------------------------------------------------------------
# Segmented linearization: per-slot sequential semantics, fully vectorized.
# Each op is a transfer function on (exists, value); functions on a 2-point
# domain compose associatively, so one segmented associative_scan linearizes
# every wait queue in the batch at once.
# ---------------------------------------------------------------------------

def _op_transfer(kinds, values):
    """Per-op transfer function: for e_in in {0,1} -> (e_out, c_out).
    c_out == _KEEP means "pass the incoming value through"."""
    k = kinds
    ins, upd, dele = (k == OpKind.INSERT), (k == OpKind.UPDATE), (k == OpKind.DELETE)
    e0 = jnp.where(ins, 1, 0).astype(jnp.int32)            # from empty
    e1 = jnp.where(dele, 0, 1).astype(jnp.int32)           # from occupied
    c0 = jnp.where(ins, values, _KEEP)
    c1 = jnp.where(upd, values, _KEEP)
    c1 = jnp.where(dele, _NONE, c1)
    return jnp.stack([e0, e1], -1), jnp.stack([c0, c1], -1)


def _compose(f, g):
    """(f then g) on the 2-point domain; both are (e[B,2], c[B,2])."""
    fe, fc = f
    ge, gc = g
    mid = fe                                   # (B,2) in {0,1}
    out_e = jnp.take_along_axis(ge, mid, axis=-1)
    g_at = jnp.take_along_axis(gc, mid, axis=-1)
    out_c = jnp.where(g_at == _KEEP, fc, g_at)
    return out_e, out_c


def _segmented_scan(e, c, first):
    """Inclusive segmented scan of transfer functions along axis 0."""
    def comb(a, b):
        ae, ac, af = a
        be, bc, bf = b
        ce, cc = _compose((ae, ac), (be, bc))
        e_out = jnp.where(bf[:, None], be, ce)
        c_out = jnp.where(bf[:, None], bc, cc)
        return e_out, c_out, af | bf
    return jax.lax.associative_scan(comb, (e, c, first), axis=0)


def _apply(e, c, e_in, v_in):
    """Apply transfer (e[B,2], c[B,2]) to incoming scalar state (e_in, v_in)."""
    idx = e_in.astype(jnp.int32)[:, None]
    e_out = jnp.take_along_axis(e, idx, axis=-1)[:, 0]
    c_out = jnp.take_along_axis(c, idx, axis=-1)[:, 0]
    v_out = jnp.where(c_out == _KEEP, v_in, c_out)
    return e_out.astype(bool), v_out


# ---------------------------------------------------------------------------
# Mode-specific I/O metering helpers
# ---------------------------------------------------------------------------

def _backoff_polls(wait_rounds, cap):
    """Deterministic truncated-exponential-backoff poll count while waiting
    ``wait_rounds`` service rounds: probes at 1,2,4,...,2^cap,2^cap,... ."""
    w = wait_rounds.astype(jnp.float32)
    exp_phase = jnp.ceil(jnp.log2(jnp.maximum(w, 1.0) + 1.0))
    exp_phase = jnp.minimum(exp_phase, float(cap))
    linear = jnp.maximum(w - (2.0 ** cap - 1.0), 0.0) / (2.0 ** cap)
    return jnp.where(wait_rounds > 0, exp_phase + jnp.floor(linear), 0.0).astype(jnp.int32)


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("cfg",))
def apply_batch(cfg: EngineConfig, state: StoreState, credits: CreditState,
                batch: OpBatch, valid: jax.Array | None = None,
                owned: jax.Array | None = None,
                slot_base: jax.Array | None = None,
                alive: jax.Array | None = None,
                died: jax.Array | None = None,
                ) -> tuple[StoreState, CreditState, Results, IOMetrics]:
    """Execute one synchronization window. See module docstring.

    Sharded operation (``repro.dist.store``): ``owned`` masks the ops whose
    key's slot lives in this shard, and ``slot_base`` is the shard's first
    global slot (store arrays are indexed by ``keys - slot_base``).  The
    *data plane* (linearization, commits, results, I/O metering) runs on the
    owned subset only; the *credit plane* (contention-aware path decisions
    and AIMD feedback, §4.3) runs on the FULL batch with global keys on every
    shard, so the replicated credit table evolves identically everywhere and
    per-shard I/O sums to the single-device bill exactly.

    Liveness plane (crash recovery, §4.6 — ``repro.recovery``): ``alive`` is
    a ``(n_cns,)`` mask of compute nodes alive through this window and
    ``died`` marks CNs that crashed *at this window* (were alive at window
    start).  Ops from non-alive CNs are dropped at the window boundary —
    exactly as a real crash strands them — while the in-flight pessimistic
    writes of newly-died CNs strand orphaned locks that the next surviving
    waiter detects via the stale-epoch read and breaks with a repair CAS
    (billed into ``IOMetrics.repair_cas`` and the affected queue's
    ``Results.orphan_wait``); locks on slots with no surviving waiter stay
    recorded in ``StoreState.stranded`` until their next locker arrives.
    """
    b = batch.kinds.shape[0]
    kinds, keys, values, pos, cn = (batch.kinds, batch.keys, batch.values,
                                    batch.pos, batch.cn)
    if valid is None:
        valid = kinds != OpKind.NOP
    else:
        valid = valid & (kinds != OpKind.NOP)
    if cfg.scan_max == 0:
        # no probe capacity compiled in: SCAN lanes must not silently charge
        # point-op I/O and return 0 rows — they are dropped here, and the
        # point-op stores reject them loudly before ever reaching the engine
        valid = valid & (kinds != OpKind.SCAN)
    # present: ops issued into this window (including ones whose CN crashes
    # mid-window — the orphan candidates); valid: ops that complete.
    present = valid
    if alive is not None:
        a = jnp.asarray(alive, bool)
        valid = valid & a[jnp.clip(cn, 0, a.shape[0] - 1)]
    # valid: ops present in the window (credit plane); valid_o: ops whose
    # store state this shard owns (data plane).  Identical when not sharded.
    valid_o = valid if owned is None else valid & owned
    base = jnp.int32(0) if slot_base is None else jnp.asarray(slot_base, jnp.int32)
    is_search = (kinds == OpKind.SEARCH) & valid_o
    is_insert = (kinds == OpKind.INSERT) & valid_o
    is_update = (kinds == OpKind.UPDATE) & valid_o
    is_delete = (kinds == OpKind.DELETE) & valid_o
    upd_full = (kinds == OpKind.UPDATE) & valid

    # ---- 1. linearize: one segmented scan serializes every slot's queue ----
    plan_all = wc.plan_combine(keys, pos, valid_o)
    perm = plan_all.perm
    e_t, c_t = _op_transfer(kinds[perm], values[perm])
    # invalid ops are identity transforms
    v_sorted = valid_o[perm]
    ident_e = jnp.broadcast_to(jnp.array([0, 1], jnp.int32), (b, 2))
    ident_c = jnp.full((b, 2), _KEEP, jnp.int32)
    e_t = jnp.where(v_sorted[:, None], e_t, ident_e)
    c_t = jnp.where(v_sorted[:, None], c_t, ident_c)
    incl_e, incl_c, _ = _segmented_scan(e_t, c_t, plan_all.is_first)
    # incoming (pre-window) state per sorted element's slot (shard-local)
    slot = jnp.clip(keys[perm] - base, 0, cfg.n_slots - 1)
    p = state.ptr[slot]
    e_init = p != NULL_PTR
    v_init = jnp.where(e_init, state.heap[jnp.clip(p, 0)], _NONE)
    # state BEFORE each op: exclusive scan = shifted inclusive, reset at heads
    prev_e = jnp.roll(incl_e, 1, axis=0)
    prev_c = jnp.roll(incl_c, 1, axis=0)
    e_before, v_before = _apply(prev_e, prev_c, e_init, v_init)
    e_before = jnp.where(plan_all.is_first, e_init, e_before)
    v_before = jnp.where(plan_all.is_first, v_init, v_before)
    # per-op success / search results (sorted order)
    ks = kinds[perm]
    ok_s = jnp.where(ks == OpKind.SEARCH, e_before,
            jnp.where(ks == OpKind.INSERT, ~e_before,
             jnp.where((ks == OpKind.UPDATE) | (ks == OpKind.DELETE), e_before, False)))
    ok_s = ok_s & v_sorted
    val_s = jnp.where((ks == OpKind.SEARCH) & e_before & v_sorted,
                      v_before, _NONE)
    # state AFTER the last op of each queue -> new slot contents
    e_fin, v_fin = _apply(incl_e, incl_c, e_init, v_init)
    seg_changed = ok_s & (ks != OpKind.SEARCH)          # any successful IDU
    # segment ids for reductions
    seg = jnp.cumsum(plan_all.is_first.astype(jnp.int32)) - 1
    seg_any_write = jax.ops.segment_max(seg_changed.astype(jnp.int32), seg,
                                        num_segments=b).astype(bool)
    # ---- 2. commit final slot states (one out-of-place write per queue) ----
    # Out-of-bounds indices with mode="drop" mask out non-committing lanes.
    tail = plan_all.is_last & seg_any_write[seg] & v_sorted
    oob_h, oob_s = jnp.int32(cfg.heap_slots), jnp.int32(cfg.n_slots)
    n_commits = jnp.sum(tail.astype(jnp.int32))
    commit_rank = jnp.cumsum(tail.astype(jnp.int32)) - 1
    loc = (state.heap_top + commit_rank).astype(jnp.int32)
    heap = state.heap.at[jnp.where(tail, loc, oob_h)].set(v_fin, mode="drop")
    new_ptr_val = jnp.where(e_fin, loc, NULL_PTR)
    ptr = state.ptr.at[jnp.where(tail, slot, oob_s)].set(new_ptr_val, mode="drop")
    # version: +1 per successful DELETE (mod 16 — the 4-bit field of Fig 8)
    del_succ = (ks == OpKind.DELETE) & ok_s
    dver = jax.ops.segment_sum(del_succ.astype(jnp.int32), seg, num_segments=b)
    ver = (state.ver.at[jnp.where(plan_all.is_last, slot, oob_s)]
           .add(dver[seg], mode="drop")) % 16

    # ---- 3. synchronization-mode decision (CIDER credit split, §4.3) ----
    # Decided on the FULL window (upd_full, global keys): every shard's
    # replica of the credit table sees every op and stays bit-identical.
    if cfg.mode == SyncMode.CIDER:
        credits2, pess_full = credit_decide(credits, keys, upd_full,
                                            credits.credit.shape[0])
    elif cfg.mode in (SyncMode.MCS, SyncMode.SPIN):
        credits2, pess_full = credits, upd_full
    else:  # OSYNC
        credits2, pess_full = credits, jnp.zeros_like(upd_full)
    pess = pess_full & valid_o
    opt_upd = is_update & ~pess_full

    # ---- 4. effective writers after local WC (per (key, CN) group) --------
    # Local WC combines same-CN UPDATEs (applied to every baseline, §5.1);
    # combined ops never leave the CN.  CIDER's pessimistic path does NOT
    # pre-filter: every client enqueues in the *global* MCS queue (Fig 7),
    # and global WC subsumes local WC.
    loc_exec_opt = wc.local_executors(keys, cn, pos, opt_upd) if cfg.local_wc else opt_upd
    if cfg.mode == SyncMode.CIDER or not cfg.local_wc:
        loc_exec_pess = pess
    else:
        loc_exec_pess = wc.local_executors(keys, cn, pos, pess)

    # ---- 5. per-mode I/O metering ------------------------------------------
    i64 = jnp.int32
    def s(x):
        return jnp.sum(x.astype(i64))

    n_found_search = jnp.sum(((ks == OpKind.SEARCH) & ok_s).astype(jnp.int32))
    reads = s(valid_o) * cfg.index_read_iops + n_found_search
    mn_bytes = (s(valid_o) * cfg.index_read_bytes + n_found_search * cfg.value_bytes)
    writes = jnp.zeros((), i64)
    cas = jnp.zeros((), i64)
    faa = jnp.zeros((), i64)
    cn_msgs = jnp.zeros((), i64)
    retries_total = jnp.zeros((), i64)
    combined_total = jnp.zeros((), i64)
    per_op_retries = jnp.zeros((b,), jnp.int32)
    per_op_combined = jnp.zeros((b,), bool)
    per_op_batch = jnp.ones((b,), jnp.int32)
    per_op_rank = jnp.zeros((b,), jnp.int32)
    per_op_rows = jnp.zeros((b,), jnp.int32)

    # INSERTs: optimistic CAS on the empty pointer in every mode (§4.2.2);
    # concurrent same-key INSERTs: exactly one wins, losers fail once.
    writes += s(is_insert)
    cas += s(is_insert)
    mn_bytes += s(is_insert) * (cfg.value_bytes + cfg.ptr_bytes)

    # DELETEs: pessimistic modes lock (enqueue-CAS + ptr-CAS + epoch-FAA);
    # under OSYNC they CAS-retry in the SAME per-key optimistic queue as
    # concurrent UPDATEs (both CAS the same pointer), metered jointly below.
    n_del = s(is_delete)
    if cfg.mode != SyncMode.OSYNC:
        cas += 2 * n_del
        faa += n_del
        mn_bytes += n_del * (2 * cfg.ptr_bytes + 8)

    # Optimistic CAS path -----------------------------------------------------
    # One joint queue per key: UPDATE executors after local WC, plus (OSYNC
    # only) DELETEs — cross-kind conflicts on a pointer retry against each
    # other, so metering them as two independent queues undercounts retries.
    if cfg.mode == SyncMode.OSYNC:
        opt_queue = loc_exec_opt | is_delete
    else:
        opt_queue = loc_exec_opt
    plan_o = wc.per_key_stats(keys, pos, opt_queue)
    m_opt_writes = s(loc_exec_opt)                   # DELETEs write no heap
    writes += m_opt_writes
    cas += s(opt_queue) + plan_o.retry_sum
    retries_total += plan_o.retry_sum
    mn_bytes += (m_opt_writes * cfg.value_bytes
                 + (s(opt_queue) + plan_o.retry_sum) * cfg.ptr_bytes)
    combined_total += s(opt_upd) - m_opt_writes      # local-WC combined
    per_op_retries = jnp.where(opt_queue, plan_o.rank_of, per_op_retries)
    per_op_rank = jnp.where(opt_queue, plan_o.rank_of, per_op_rank)
    per_op_combined = per_op_combined | (opt_upd & ~loc_exec_opt)

    # pessimistic subset
    m_pe = s(loc_exec_pess)                          # effective queued writers
    if cfg.mode == SyncMode.SPIN:
        plan_p = wc.per_key_stats(keys, pos, loc_exec_pess)
        polls = _backoff_polls(plan_p.rank_of * 3, cfg.backoff_cap)
        polls_sum = s(jnp.where(loc_exec_pess, polls, 0))
        writes += m_pe
        cas += 3 * m_pe + polls_sum                  # lock + ptr + unlock + polls
        retries_total += polls_sum
        mn_bytes += m_pe * (cfg.value_bytes + 3 * cfg.ptr_bytes) + polls_sum * cfg.ptr_bytes
        per_op_retries = jnp.where(loc_exec_pess, polls, per_op_retries)
        per_op_rank = jnp.where(loc_exec_pess, plan_p.rank_of, per_op_rank)
    elif cfg.mode == SyncMode.MCS:
        writes += m_pe
        cas += 2 * m_pe                              # enqueue masked-CAS + ptr CAS
        faa += m_pe                                  # epoch release
        plan_p = wc.per_key_stats(keys, pos, loc_exec_pess)
        cn_msgs += 2 * s(jnp.where(loc_exec_pess, (plan_p.mult_of > 1), 0))
        mn_bytes += m_pe * (cfg.value_bytes + 2 * cfg.ptr_bytes + 8)
        per_op_batch = jnp.where(loc_exec_pess, 1, per_op_batch)
        per_op_rank = jnp.where(loc_exec_pess, plan_p.rank_of, per_op_rank)
    elif cfg.mode == SyncMode.CIDER:
        # global WC: all queued writers on a key collapse to ONE executed write
        plan_p = wc.per_key_stats(keys, pos, loc_exec_pess)
        is_exec = loc_exec_pess & plan_p.is_tail     # queue tail = executor
        n_q = s(is_exec)                             # number of wait queues
        multi = loc_exec_pess & (plan_p.mult_of > 1)
        n_multi_q = s(is_exec & (plan_p.mult_of > 1))
        cas += m_pe + n_q                            # m enqueues + 1 ptr CAS per queue
        writes += n_q                                # ONE combined write per queue
        faa += m_pe                                  # every client's release FAA
        reads += n_multi_q                           # coordinator tail lookup (step 1)
        cn_msgs += s(jnp.where(is_exec & (plan_p.mult_of > 1),
                               plan_p.mult_of + 1, 0))
        mn_bytes += (m_pe * cfg.ptr_bytes + n_q * (cfg.value_bytes + cfg.ptr_bytes)
                     + m_pe * 8 + n_multi_q * cfg.lock_bytes)
        combined_total += s(pess) - n_q
        per_op_combined = per_op_combined | (pess & ~is_exec)
        per_op_batch = jnp.where(loc_exec_pess, plan_p.mult_of, per_op_batch)
        per_op_rank = jnp.where(loc_exec_pess, plan_p.rank_of, per_op_rank)

    executed = writes

    # ---- 5b. lease/epoch orphaned-lock repair (crash recovery, §4.6) ------
    # A CN that dies mid-window strands the locks it held or was queued on.
    # The next surviving waiter notices the stale epoch (the holder stopped
    # FAA-ing), waits out the lease, and breaks the lock with a repair CAS.
    # Mode asymmetry — the recovery result: CIDER's combined queue has ONE
    # lock entry per queue and SPIN one lock word per key, while MCS strands
    # the whole chain of dead queue nodes, each repaired by its successor.
    per_op_orphan = jnp.zeros((b,), jnp.int32)
    repair_total = jnp.zeros((), i64)
    orphan_out = jnp.zeros((), i64)
    if cfg.mode == SyncMode.OSYNC:
        stranded = state.stranded          # lock-free: crashes strand nothing
    else:
        slot_u = jnp.clip(keys - base, 0, cfg.n_slots - 1)
        if died is None:
            add_slot = jnp.zeros((cfg.n_slots,), jnp.int32)
        else:
            d = jnp.asarray(died, bool)
            dead_w = (present & d[jnp.clip(cn, 0, d.shape[0] - 1)]
                      & ((kinds == OpKind.UPDATE) | (kinds == OpKind.DELETE)))
            if owned is not None:
                dead_w = dead_w & owned
            if cfg.mode == SyncMode.CIDER:
                # only writers the credit table routes pessimistically held a
                # lock when they crashed; a dead optimistic writer strands
                # nothing (its out-of-place CAS simply never lands) — the
                # lock-free-crash argument FUSEE makes.  DELETEs always lock.
                cslot = credit_slot(keys, credits.credit.shape[0])
                dead_node = dead_w & ((credits.credit[cslot] > 0)
                                      | (kinds == OpKind.DELETE))
            elif cfg.local_wc:
                # only the local executor of each (key, CN) UPDATE group had
                # left the crashed CN for the memory pool; DELETEs are never
                # locally combined (they lock independently on the live path
                # too), so each dead DELETE strands its own node
                dead_upd = dead_w & (kinds == OpKind.UPDATE)
                dead_node = (wc.local_executors(keys, cn, pos, dead_upd)
                             | (dead_w & (kinds == OpKind.DELETE)))
            else:
                dead_node = dead_w
            stats_dead = wc.per_key_stats(keys, pos, dead_node)
            per_key_add = (stats_dead.mult_of if cfg.mode == SyncMode.MCS
                           else jnp.minimum(stats_dead.mult_of, 1))
            add_slot = jnp.zeros((cfg.n_slots,), jnp.int32).at[
                jnp.where(stats_dead.is_tail, slot_u, cfg.n_slots)
            ].add(jnp.where(stats_dead.is_tail, per_key_add, 0), mode="drop")
        tot = state.stranded + add_slot
        if cfg.mode != SyncMode.MCS:
            tot = jnp.minimum(tot, 1)      # one lock word/entry per key
        # any surviving locker on the slot repairs all its stranded nodes
        # this window; untouched slots stay stranded for their next locker
        surv = loc_exec_pess | is_delete
        surv_slot = jnp.zeros((cfg.n_slots,), bool).at[
            jnp.where(surv, slot_u, cfg.n_slots)].set(True, mode="drop")
        repaired = surv_slot & (tot > 0)
        n_repair = jnp.sum(jnp.where(repaired, tot, 0).astype(i64))
        stranded = jnp.where(repaired, 0, tot)
        orphan_out = jnp.sum((stranded > 0).astype(i64))
        per_op_orphan = jnp.where(surv & repaired[slot_u], tot[slot_u], 0)
        # bill: one stale-epoch READ of the lock entry + one break CAS per
        # stranded node, charged to the blocked queue
        reads += n_repair
        cas += n_repair
        repair_total += n_repair
        mn_bytes += n_repair * (cfg.lock_bytes + 8)
        if cfg.mode == SyncMode.SPIN:
            # spinners keep re-CASing the orphaned word until the lease
            # expires — MN verbs MCS/CIDER waiters never issue (they wait
            # CN-locally); these polls ARE recovery overhead, so they are
            # folded into repair_cas as well
            pollc = _backoff_polls(jnp.asarray(cfg.lease_poll_rounds, jnp.int32),
                                   cfg.backoff_cap)
            lease_polls = per_op_orphan * pollc
            polls_lease = s(lease_polls)
            cas += polls_lease
            retries_total += polls_lease
            repair_total += polls_lease
            mn_bytes += polls_lease * cfg.ptr_bytes
            per_op_retries = per_op_retries + lease_polls

    # ---- 5c. SCAN reader probes (range reads, DESIGN.md §9) ---------------
    # A SCAN(key, count) expands into `count` reader probes over the
    # contiguous leaf-slot run [key, key+count), each joining its slot's wait
    # queue *as a reader* at the scanning op's batch position.  The probes
    # run in a second linearization pass alongside the window's writers —
    # readers are identity transfer functions, so the pass observes exactly
    # the per-slot state at the probe's serialization position and the main
    # pass above is untouched.  Probes outside [slot_base, slot_base +
    # n_slots) belong to another shard (or fall off the keyspace end): each
    # shard counts its own sub-run and the dist psum reassembles the rows.
    if cfg.scan_max > 0:
        ns = cfg.scan_max
        is_scan = (kinds == OpKind.SCAN) & valid
        count = jnp.clip(values, 0, ns)               # count rides `values`
        jj = jnp.arange(ns, dtype=jnp.int32)
        pk = keys[:, None] + jj[None, :]              # (B, ns) global slots
        p_loc = pk - base
        p_in = (is_scan[:, None] & (jj[None, :] < count[:, None])
                & (p_loc >= 0) & (p_loc < cfg.n_slots))
        keys_p = pk.reshape(b * ns)
        pos_p = jnp.broadcast_to(pos[:, None], (b, ns)).reshape(b * ns)
        pv = p_in.reshape(b * ns)
        keys_c = jnp.concatenate([keys, keys_p])
        pos_c = jnp.concatenate([pos, pos_p])
        kinds_c = jnp.concatenate(
            [kinds, jnp.full((b * ns,), OpKind.SEARCH, jnp.int32)])
        values_c = jnp.concatenate([values, jnp.zeros((b * ns,), jnp.int32)])
        tvalid = jnp.concatenate([valid_o, pv])
        plan_c = wc.plan_combine(keys_c, pos_c, tvalid)
        pc = plan_c.perm
        bc = b * (1 + ns)
        e_tc, c_tc = _op_transfer(kinds_c[pc], values_c[pc])
        v_sc = tvalid[pc]
        e_tc = jnp.where(v_sc[:, None], e_tc,
                         jnp.broadcast_to(jnp.array([0, 1], jnp.int32), (bc, 2)))
        c_tc = jnp.where(v_sc[:, None], c_tc, jnp.full((bc, 2), _KEEP, jnp.int32))
        incl_ec, incl_cc, _ = _segmented_scan(e_tc, c_tc, plan_c.is_first)
        slot_c = jnp.clip(keys_c[pc] - base, 0, cfg.n_slots - 1)
        ptr_c = state.ptr[slot_c]
        e_init_c = ptr_c != NULL_PTR
        v_init_c = jnp.where(e_init_c, state.heap[jnp.clip(ptr_c, 0)], _NONE)
        prev_ec = jnp.roll(incl_ec, 1, axis=0)
        prev_cc = jnp.roll(incl_cc, 1, axis=0)
        e_bc, _ = _apply(prev_ec, prev_cc, e_init_c, v_init_c)
        e_bc = jnp.where(plan_c.is_first, e_init_c, e_bc)
        e_probe = jnp.zeros((bc,), bool).at[pc].set(e_bc & v_sc)
        hit = e_probe[b:].reshape(b, ns) & p_in
        per_op_rows = jnp.sum(hit.astype(jnp.int32), axis=1)
        n_probes = s(pv)
        n_rows = s(hit)
        # base bill: one leaf-entry READ per probed slot + one value READ per
        # row found (every mode traverses the same run)
        reads += n_probes + n_rows
        mn_bytes += n_probes * cfg.ptr_bytes + n_rows * cfg.value_bytes
        if cfg.mode == SyncMode.OSYNC:
            # optimistic traversal must re-read each leaf's version to
            # validate against concurrent pointer swaps (§2.2's cost, paid
            # per leaf whether or not anyone wrote)
            reads += n_probes
            mn_bytes += n_probes * cfg.ptr_bytes
        elif cfg.mode == SyncMode.SPIN:
            # a CAS spinlock has no shared mode: lock + unlock CAS per leaf
            cas += 2 * n_probes
            mn_bytes += 2 * n_probes * cfg.ptr_bytes
        elif cfg.mode == SyncMode.MCS:
            # lock-shared traversal: shared-mode enqueue CAS + release FAA
            # per leaf (the epoch heartbeat plane tracks exclusive holders
            # only — the reader FAA is billed, not recorded)
            cas += n_probes
            faa += n_probes
            mn_bytes += n_probes * (cfg.ptr_bytes + 8)
        else:  # CIDER: consult the CN-local credit table (free) — cold
               # leaves are traversed lock-free like OSYNC *without* the
               # re-read (the table certifies no concurrent pessimistic
               # writer), hot leaves join the queue in shared mode
            cslot_p = credit_slot(keys_p, credits.credit.shape[0])
            hot_p = pv & (credits.credit[cslot_p] > 0)
            n_hot = s(hot_p)
            cas += n_hot
            faa += n_hot
            mn_bytes += n_hot * (cfg.lock_bytes + 8)
        if cfg.mode != SyncMode.OSYNC:
            # wait rank of the anchor-leaf reader behind exclusive holders
            # (queue order == batch position now includes reader ranks)
            lockw = loc_exec_pess | is_delete
            waits = wc.reader_waits(
                keys_c, pos_c,
                jnp.concatenate([jnp.zeros((b,), bool), pv]),
                jnp.concatenate([lockw, jnp.zeros((b * ns,), bool)]))
            per_op_rank = jnp.where(p_in[:, 0], waits[b:].reshape(b, ns)[:, 0],
                                    per_op_rank)

    # ---- 6. credit feedback (§4.3, Algorithm 1 lines 13-22) ---------------
    # Like the decision, feedback runs on the FULL window so replicated
    # credit tables stay identical across shards; when unsharded the full
    # masks ARE the owned masks and nothing is recomputed.
    if cfg.mode == SyncMode.CIDER:
        if owned is None:
            pess_fb, batch_fb = loc_exec_pess, per_op_batch
            opt_fb, retry_fb = loc_exec_opt | is_insert, per_op_retries
        else:
            opt_upd_full = upd_full & ~pess_full
            loc_opt_full = (wc.local_executors(keys, cn, pos, opt_upd_full)
                            if cfg.local_wc else opt_upd_full)
            plan_p_fb = wc.per_key_stats(keys, pos, pess_full)
            plan_o_fb = wc.per_key_stats(keys, pos, loc_opt_full)
            pess_fb = pess_full
            batch_fb = jnp.where(pess_full, plan_p_fb.mult_of, 1)
            opt_fb = loc_opt_full | ((kinds == OpKind.INSERT) & valid)
            retry_fb = jnp.where(loc_opt_full, plan_o_fb.rank_of, 0)
        credits3 = credit_feedback(
            credits2, keys, credits.credit.shape[0],
            pess=pess_fb, wc_batch=batch_fb,
            opt=opt_fb, n_retry=retry_fb,
            initial_credit=cfg.initial_credit,
            hotness_threshold=cfg.hotness_threshold,
            aimd_factor=cfg.aimd_factor)
    else:
        credits3 = credits2

    # ---- 7. epoch FAA bookkeeping (fault-tolerance heartbeat, §4.6) -------
    if cfg.mode in (SyncMode.MCS, SyncMode.CIDER):
        rel = loc_exec_pess | is_delete
        epoch = state.epoch.at[jnp.where(rel, keys - base, cfg.n_slots)].add(
            rel.astype(jnp.int32), mode="drop")
    else:
        epoch = state.epoch

    new_state = StoreState(ptr=ptr, ver=ver, epoch=epoch, heap=heap,
                           heap_top=state.heap_top + n_commits,
                           stranded=stranded)
    # unsort results
    ok = jnp.zeros((b,), bool).at[perm].set(ok_s)
    # SCAN succeeds when it found any row; per-shard partial counts OR
    # together under the dist psum exactly as the totals add
    ok = ok | (per_op_rows > 0)
    value = jnp.full((b,), _NONE, jnp.int32).at[perm].set(val_s)
    res = Results(ok=ok, value=value, pessimistic=pess,
                  combined=per_op_combined, wc_batch=per_op_batch,
                  retries=per_op_retries, rank=per_op_rank,
                  orphan_wait=per_op_orphan, rows=per_op_rows)
    io = IOMetrics(reads=reads, writes=writes, cas=cas, faa=faa,
                   cn_msgs=cn_msgs, mn_bytes=mn_bytes, retries=retries_total,
                   combined=combined_total, executed=executed,
                   repair_cas=repair_total, orphan_windows=orphan_out)
    return new_state, credits3, res, io
