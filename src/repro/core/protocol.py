"""Per-client protocol state machines for the four synchronization schemes,
vectorized over client lanes (§2.2, §2.3, §4.2-4.4, Figs 9-10).

Each lane executes a closed-loop YCSB client.  One ``tick`` advances every
lane by at most one protocol event; lock/queue state lives in hashed tables
(ticket-FIFO == MCS queue order; documented approximation: hash collisions
between two concurrently-hot keys falsely serialize them — negligible at
<=1024 lanes vs 2^14 slots).

Phase map (see DESIGN.md):
  THINK -> IDX -> { KV                         (SEARCH)
                  | OW -> OCAS*                (optimistic write; CAS retry loop)
                  | SLOCK* -> SW -> SCAS -> SUNL        (CAS spinlock + backoff)
                  | ENQ -> [MNOTIFY -> MWAIT] -> MW -> MCAS -> MFAA   (MCS)
                  | ENQ -> ... -> CREAD -> CMSG -> MW -> MCAS -> MFAA (CIDER
                      coordinator: combined write for the whole wait queue)
                  | MWAIT -> PWAIT -> PFAA     (CIDER participant: combined) }

The CIDER delegation detail (§4.2.1): on acquiring a non-empty queue, the
head becomes *coordinator*, reads the lock entry to identify the tail
(executor) and transfers ownership.  Timing-wise the verb chain
READ -> CN_MSG -> WRITE -> CAS -> FAA is identical whichever client runs it,
so the simulator lets the coordinator lane run the combined write and
completes participants via the relay chain (one cn_rtt per hop), exactly the
verb count and serialization of Fig 7.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.simnet import NetState, SimParams, issue_mn, net_init
from repro.core.types import OpKind, SyncMode

__all__ = ["SimState", "sim_init", "tick", "PHASES"]

(THINK, IDX, KV, OW, OCAS, ORD, SLOCK, SBACK, SW, SCAS, SUNL, ENQ, MNOTIFY,
 MWAIT, MW, MCAS, MFAA, CREAD, CMSG, PWAIT, PFAA, LWAIT, DEAD) = range(23)
PHASES = dict(THINK=THINK, IDX=IDX, KV=KV, OW=OW, OCAS=OCAS, ORD=ORD,
              SLOCK=SLOCK, SBACK=SBACK, SW=SW, SCAS=SCAS, SUNL=SUNL, ENQ=ENQ,
              MNOTIFY=MNOTIFY, MWAIT=MWAIT, MW=MW, MCAS=MCAS, MFAA=MFAA,
              CREAD=CREAD, CMSG=CMSG, PWAIT=PWAIT, PFAA=PFAA, LWAIT=LWAIT,
              DEAD=DEAD)

V_READ, V_WRITE, V_CAS, V_FAA, V_CN = range(5)
_BIG = jnp.int32(2**30)


def _fail_lanes(p: SimParams) -> tuple[int, ...]:
    """All lanes scheduled to die at ``fail_tick``: the legacy single
    ``fail_lane`` plus the ``fail_lanes`` set — multi-CN crash scenarios
    run on the sim path with the same deadlock-repair machinery."""
    lanes = tuple(int(x) for x in p.fail_lanes)
    if p.fail_lane >= 0:
        lanes += (p.fail_lane,)
    return tuple(sorted(set(lanes)))


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SimState:
    # ---- per-lane ----
    phase: jax.Array       # (N,)
    ready: jax.Array       # (N,) next event tick
    kind: jax.Array        # (N,) current OpKind
    hkey: jax.Array        # (N,) hashed key -> lock/ticket tables
    hc: jax.Array          # (N,) credit-table slot
    hl: jax.Array          # (N,) local-WC slot
    ticket: jax.Array      # (N,) MCS ticket
    att: jax.Array         # (N,) CAS/lock attempt count for current op
    kver_seen: jax.Array   # (N,) pointer version read before CAS
    comb_tail: jax.Array   # (N,) coordinator: executor's ticket
    comb_pend: jax.Array   # (N,) coordinator: combined write in flight
    own_local: jax.Array   # (N,) I hold the local-WC flag
    idx_left: jax.Array    # (N,) remaining index reads for current op
    op_start: jax.Array    # (N,) issue tick of current op
    op_idx: jax.Array      # (N,) position in my op stream
    is_pess: jax.Array     # (N,) current write takes the pessimistic path
    wait_start: jax.Array  # (N,) MWAIT entry tick (deadlock detection §4.6)
    # ---- hashed key tables ----
    next_ticket: jax.Array  # (H,)
    now_serving: jax.Array  # (H,)
    kver: jax.Array         # (H,) pointer version (optimistic CAS conflicts)
    lockw: jax.Array        # (H,) spinlock word
    comb_time: jax.Array    # (H,) last combined batch: release tick
    comb_base: jax.Array    # (H,)   "  : coordinator ticket
    comb_upto: jax.Array    # (H,)   "  : executor ticket
    epoch: jax.Array        # (H,) lock epoch (FAA'd on release, §4.6)
    del_q: jax.Array        # (H,) ticket-assigned, unreleased DELETEs queued
                            # on the key — gates write combining: a combined
                            # batch completes its members WITHOUT their own
                            # pointer CAS, which would silently swallow a
                            # queued DELETE (found by repro.analysis.race_check)
    # ---- per-CN tables (flattened G x 2^bits) ----
    lflag: jax.Array        # local WC busy flags
    credit: jax.Array       # contention credits (§4.3)
    rrec: jax.Array         # retryRecord (§4.3)
    # ---- network + counters ----
    net: NetState
    verbs: jax.Array        # (5,) per-class verb counts
    done: jax.Array         # () completed ops
    done_w: jax.Array       # () completed writes
    retries: jax.Array      # () redundant CAS/poll attempts
    comb_g: jax.Array       # () globally combined writes
    comb_l: jax.Array       # () locally combined writes
    pess_w: jax.Array       # () writes that took the pessimistic path
    exec_w: jax.Array       # () executed (non-combined) writes
    batch_sum: jax.Array    # () sum of WC batch sizes
    batch_cnt: jax.Array    # () number of combined batches
    hot_ideal: jax.Array    # () ops finishing with att >= HOTNESS_THRESHOLD
    deadlocks: jax.Array    # () deadlock repairs performed
    hist: jax.Array         # (HB,) latency histogram (1-tick buckets)


def sim_init(p: SimParams, streams) -> SimState:
    n = p.n_lanes
    h = 1 << p.h_bits
    g = (n + p.lanes_per_cn - 1) // p.lanes_per_cn
    zN = jnp.zeros((n,), jnp.int32)
    zH = jnp.zeros((h,), jnp.int32)
    kinds0 = streams["kinds"][:, 0]
    return SimState(
        phase=jnp.full((n,), THINK, jnp.int32),
        ready=(jnp.arange(n, dtype=jnp.int32) % 7),   # staggered start
        kind=kinds0.astype(jnp.int32),
        hkey=streams["hkey"][:, 0].astype(jnp.int32),
        hc=streams["hc"][:, 0].astype(jnp.int32),
        hl=streams["hl"][:, 0].astype(jnp.int32),
        ticket=zN, att=zN, kver_seen=zN, comb_tail=zN, comb_pend=zN,
        own_local=zN, idx_left=zN, op_start=zN, op_idx=zN, is_pess=zN,
        wait_start=zN,
        next_ticket=zH, now_serving=zH, kver=zH, lockw=zH,
        comb_time=zH, comb_base=jnp.full((h,), -1, jnp.int32),
        comb_upto=jnp.full((h,), -1, jnp.int32), epoch=zH, del_q=zH,
        lflag=jnp.zeros((g << p.hl_bits,), jnp.int32),
        credit=jnp.zeros((g << p.hc_bits,), jnp.int32),
        rrec=jnp.zeros((g << p.hc_bits,), jnp.int32),
        net=net_init(2 * h),
        verbs=jnp.zeros((5,), jnp.int32),
        done=jnp.zeros((), jnp.int32), done_w=jnp.zeros((), jnp.int32),
        retries=jnp.zeros((), jnp.int32), comb_g=jnp.zeros((), jnp.int32),
        comb_l=jnp.zeros((), jnp.int32), pess_w=jnp.zeros((), jnp.int32),
        exec_w=jnp.zeros((), jnp.int32), batch_sum=jnp.zeros((), jnp.int32),
        batch_cnt=jnp.zeros((), jnp.int32), hot_ideal=jnp.zeros((), jnp.int32),
        deadlocks=jnp.zeros((), jnp.int32),
        hist=jnp.zeros((p.hist_buckets,), jnp.int32),
    )


def _scatter_min_id(h_idx, mask, h_size, n, prio=None):
    """One winner per hashed key among masked lanes.  ``prio`` (a permutation
    of lane ids) models RNIC timing jitter: without it, fixed min-id
    arbitration starves high-id lanes *completely*, which is stronger than
    the real unfairness the paper describes (§4.6 Fairness)."""
    ids = jnp.arange(n, dtype=jnp.int32)
    if prio is None:
        prio = ids
    tmp = jnp.full((h_size,), _BIG, jnp.int32)
    tmp = tmp.at[jnp.where(mask, h_idx, h_size)].min(prio, mode="drop")
    return mask & (tmp[h_idx] == prio)


def _group_rank(h_idx, mask, h_size, n):
    """0-based rank by lane id within each hashed-key group of masked lanes."""
    ids = jnp.arange(n, dtype=jnp.int32)
    order = jnp.lexsort((ids, jnp.where(mask, h_idx, h_size)))
    hs = jnp.where(mask, h_idx, h_size)[order]
    is_first = jnp.concatenate([jnp.ones((1,), bool), hs[1:] != hs[:-1]])
    pos = jnp.arange(n, dtype=jnp.int32)
    seg_start = jax.lax.cummax(jnp.where(is_first, pos, 0))
    rank_sorted = pos - seg_start
    return jnp.zeros((n,), jnp.int32).at[order].set(rank_sorted)


def tick(p: SimParams, mode: SyncMode, streams, state: SimState, t
         ) -> SimState:
    """Advance every lane by one event.  ``t``: current tick (i32 scalar)."""
    n, H = p.n_lanes, 1 << p.h_bits
    s = state
    active = s.phase != DEAD
    ev = (s.ready == t) & active
    ids = jnp.arange(n, dtype=jnp.int32)
    li = (ids // p.lanes_per_cn) * (1 << p.hl_bits) + s.hl   # local-WC slot
    ci = (ids // p.lanes_per_cn) * (1 << p.hc_bits) + s.hc   # credit slot

    is_search = s.kind == OpKind.SEARCH
    is_insert = s.kind == OpKind.INSERT
    is_delete = s.kind == OpKind.DELETE

    # accumulators for this tick
    issue_mask = jnp.zeros((n,), bool)
    issue_bytes = jnp.zeros((n,), jnp.int32)
    issue_cost = jnp.zeros((n,), jnp.int32)
    issue_atomic = jnp.zeros((n,), bool)
    issue_repl = jnp.zeros((n,), bool)
    new_phase = s.phase
    new_ready = s.ready
    verbs = s.verbs
    complete = jnp.zeros((n,), bool)
    combined_g_fin = jnp.zeros((n,), bool)
    combined_l_fin = jnp.zeros((n,), bool)

    issue_addr = s.hkey

    def issue(m, phase_id, verb, nbytes, lock_addr=False):
        """``lock_addr``: the verb targets the key's LOCK ENTRY, a different
        memory word than the data pointer — atomics on the two serialize
        independently at the RNIC.

        SNAPSHOT replication (DESIGN.md §13): write-class verbs fan out from
        the client to all ``p.n_replicas`` replica MNs — xR capacity tokens,
        bytes, and verb counts on the shared MN fleet — and the lane waits
        ``p.replica_rtt`` extra ticks for the slowest replica's ack (applied
        after ``issue_mn``).  Each replica's copy of a hot word serializes at
        its own RNIC in parallel, so per-address arrivals stay x1.  Reads go
        to one replica.  Static: R=1 builds the pre-replication program.
        """
        nonlocal issue_mask, issue_bytes, issue_cost, issue_atomic, issue_addr
        nonlocal issue_repl, new_phase, verbs
        atomic = verb in (V_CAS, V_FAA)
        rep = p.n_replicas if (p.n_replicas > 1
                               and verb in (V_WRITE, V_CAS, V_FAA)) else 1
        issue_mask = issue_mask | m
        issue_bytes = jnp.where(m, nbytes * rep, issue_bytes)
        issue_cost = jnp.where(m, (p.atomic_cost if atomic else 1) * rep,
                               issue_cost)
        if atomic:
            issue_atomic = issue_atomic | m
        if rep > 1:
            issue_repl = issue_repl | m
        if lock_addr:
            issue_addr = jnp.where(m, s.hkey + H, issue_addr)
        new_phase = jnp.where(m, phase_id, new_phase)
        count = jnp.sum(m.astype(jnp.int32))
        verbs = verbs.at[verb].add(count if rep == 1 else rep * count)

    def cn_hop(m, phase_id):
        nonlocal new_phase, new_ready, verbs
        new_phase = jnp.where(m, phase_id, new_phase)
        new_ready = jnp.where(m, t + p.cn_rtt, new_ready)
        verbs = verbs.at[V_CN].add(jnp.sum(m.astype(jnp.int32)))

    # ============ THINK -> first index read =================================
    m = ev & (s.phase == THINK)
    idx_left = jnp.where(m, p.index_reads - 1, s.idx_left)
    issue(m, IDX, V_READ, p.index_bytes)

    # ============ IDX completion =============================================
    m = ev & (s.phase == IDX)
    more = m & (idx_left > 0)
    idx_left = jnp.where(more, idx_left - 1, idx_left)
    issue(more, IDX, V_READ, p.index_bytes)
    disp = m & ~more
    # SEARCH -> KV read
    issue(disp & is_search, KV, V_READ, p.value_bytes)
    w_disp = disp & ~is_search
    # ---- synchronization-mode decision (§4.3) ----
    if mode == SyncMode.CIDER:
        have_credit = (s.credit[ci] > 0) | p.cas_off
        pess = w_disp & ((~is_insert & have_credit) | is_delete)
        credit = s.credit.at[jnp.where(pess & ~is_delete, ci, s.credit.shape[0])
                             ].add(-1, mode="drop")
        credit = jnp.maximum(credit, 0)
    elif mode in (SyncMode.SPIN, SyncMode.MCS):
        pess = w_disp & ~is_insert      # INSERTs bypass locks in ALL schemes
        credit = s.credit
    else:
        pess = jnp.zeros((n,), bool)
        credit = s.credit
    opt = w_disp & ~pess
    is_pess = jnp.where(w_disp, pess, s.is_pess.astype(bool))
    # ---- local write combining (baselines only; global WC subsumes it) ----
    lflag = s.lflag
    own_local = s.own_local
    if p.local_wc and mode != SyncMode.CIDER:
        wc_cand = w_disp & ~is_insert & ~is_delete
        busy = lflag[li] > 0
        join = wc_cand & busy
        claim_c = wc_cand & ~busy
        claim_w = _scatter_min_id(li, claim_c, lflag.shape[0], n)
        join = join | (claim_c & ~claim_w)
        lflag = lflag.at[jnp.where(claim_w, li, lflag.shape[0])].set(1, mode="drop")
        own_local = jnp.where(claim_w, 1, own_local)
        new_phase = jnp.where(join, LWAIT, new_phase)
        new_ready = jnp.where(join, t + 1, new_ready)
        go = ~join
    else:
        go = jnp.ones((n,), bool)
    # ---- dispatch the write ----
    o = opt & go
    kver_seen = s.kver_seen
    kver_seen = jnp.where(o & is_delete, s.kver[s.hkey], kver_seen)
    issue(o & is_delete, OCAS, V_CAS, 8)          # DELETE: no heap write
    issue(o & ~is_delete, OW, V_WRITE, p.value_bytes)
    if mode == SyncMode.SPIN:
        issue(pess & go, SLOCK, V_CAS, 8, lock_addr=True)
    elif mode in (SyncMode.MCS, SyncMode.CIDER):
        issue(pess & go, ENQ, V_CAS, 16, lock_addr=True)  # masked-CAS on lock entry

    # ============ KV read completion -> op done ==============================
    m = ev & (s.phase == KV)
    complete = complete | m

    # ============ optimistic path ============================================
    m = ev & (s.phase == OW)
    kver_seen = jnp.where(m, s.kver[s.hkey], kver_seen)
    issue(m, OCAS, V_CAS, 8)

    m = ev & (s.phase == OCAS)
    elig = m & (kver_seen == s.kver[s.hkey])
    prio = (ids + t * 40503) % n          # rotating arbitration (NIC jitter)
    win = _scatter_min_id(s.hkey, elig, H, n, prio)
    kver = s.kver.at[jnp.where(win, s.hkey, H)].add(1, mode="drop")
    lose = m & ~win
    att = jnp.where(lose, s.att + 1, s.att)
    # a failed CAS returns the current value, so the client re-CASes
    # immediately with the returned (version, pointer) — the staleness
    # window of each retry is exactly one CAS RTT (§2.2)
    kver_seen = jnp.where(lose, kver[s.hkey], kver_seen)
    retries = s.retries + jnp.sum(lose.astype(jnp.int32))
    complete = complete | win
    if mode == SyncMode.CIDER:
        # Retry-budget escape (implementation choice, see DESIGN.md): an
        # optimistic UPDATE that keeps losing re-runs Algorithm 1's decision
        # mid-op — `escape_retries` straight failures are self-evident
        # contention, so the client self-promotes the key and enqueues.
        # Without a bound, a cold-start burst can park enough clients in the
        # CAS loop to saturate the pointer's address and strangle the
        # pessimistic path too (two-equilibria death spiral).
        escape = lose & (att >= p.escape_retries) & ~is_insert
        credit = credit.at[jnp.where(escape, ci, credit.shape[0])].add(
            p.initial_credit, mode="drop")
        is_pess = is_pess | escape
        issue(escape, ENQ, V_CAS, 16, lock_addr=True)
        lose = lose & ~escape
    issue(lose, OCAS, V_CAS, 8)

    # ============ spinlock path ==============================================
    m = ev & (s.phase == SLOCK)
    free = m & (s.lockw[s.hkey] == 0)
    swin = _scatter_min_id(s.hkey, free, H, n)
    lockw = s.lockw.at[jnp.where(swin, s.hkey, H)].set(1, mode="drop")
    slose = m & ~swin
    att = jnp.where(slose, att + 1, att)
    retries = retries + jnp.sum(slose.astype(jnp.int32))
    boff = jnp.minimum(att, p.backoff_cap)
    new_phase = jnp.where(slose, SBACK, new_phase)
    new_ready = jnp.where(slose, t + (1 << boff), new_ready)
    issue(swin & is_delete, SCAS, V_CAS, 8)
    issue(swin & ~is_delete, SW, V_WRITE, p.value_bytes)

    m = ev & (s.phase == SBACK)
    issue(m, SLOCK, V_CAS, 8, lock_addr=True)

    m = ev & (s.phase == SW)
    issue(m, SCAS, V_CAS, 8)

    m = ev & (s.phase == SCAS)
    kver = kver.at[jnp.where(m, s.hkey, H)].add(1, mode="drop")
    issue(m, SUNL, V_CAS, 8, lock_addr=True)

    m = ev & (s.phase == SUNL)
    lockw = lockw.at[jnp.where(m, s.hkey, H)].set(0, mode="drop")
    complete = complete | m

    # ============ MCS / CIDER pessimistic path ===============================
    # ENQ completion: assign FIFO tickets (get-and-set on the lock entry tail)
    m = ev & (s.phase == ENQ)
    rank = _group_rank(s.hkey, m, H, n)
    base = s.next_ticket[s.hkey]
    ticket = jnp.where(m, base + rank, s.ticket)
    next_ticket = s.next_ticket.at[jnp.where(m, s.hkey, H)].add(1, mode="drop")
    del_q = s.del_q.at[jnp.where(m & is_delete, s.hkey, H)].add(1, mode="drop")

    def acquire(acq, ticket, next_ticket, comb_tail_in):
        """Dispatch lanes that just acquired the lock (head of queue)."""
        tail = next_ticket[s.hkey] - 1
        if mode == SyncMode.CIDER and not p.wc_off:
            # Never combine while a DELETE holds an unreleased ticket on the
            # key: a combined release completes every covered ticket WITHOUT
            # its own pointer MCAS, so a covered DELETE would "complete"
            # while the key stays live — a lost delete (surfaced by
            # repro.analysis.race_check).  Conservative: a crashed ticketed
            # DELETE keeps combining off for its key, which only costs
            # throughput, never safety.
            coord = acq & (tail > ticket) & ~is_delete & (del_q[s.hkey] == 0)
        else:
            coord = jnp.zeros((n,), bool)
        plain = acq & ~coord
        return coord, plain, jnp.where(coord, tail, comb_tail_in)

    acq = m & (ticket == s.now_serving[s.hkey])
    coord, plain, comb_tail = acquire(acq, ticket, next_ticket, s.comb_tail)
    issue(coord, CREAD, V_READ, 16)               # read lock entry -> find tail
    issue(plain & is_delete, MCAS, V_CAS, 8)
    issue(plain & ~is_delete, MW, V_WRITE, p.value_bytes)
    waitq = m & ~acq
    cn_hop(waitq, MNOTIFY)                        # notify predecessor
    wait_start = jnp.where(waitq, t, s.wait_start)

    m = ev & (s.phase == MNOTIFY)
    new_phase = jnp.where(m, MWAIT, new_phase)
    new_ready = jnp.where(m, t + 1, new_ready)

    # MWAIT polling (local; no MN traffic — ShiftLock's design point)
    m = ev & (s.phase == MWAIT)
    if mode == SyncMode.CIDER:
        # stale-batch safety: tickets are monotone, so an old comb_upto can
        # never cover a ticket issued after that batch was released.
        combed = m & (s.comb_upto[s.hkey] >= ticket) & (s.comb_base[s.hkey] < ticket)
        relay = s.comb_time[s.hkey] + (ticket - s.comb_base[s.hkey]) * p.cn_rtt
        new_phase = jnp.where(combed, PWAIT, new_phase)
        new_ready = jnp.where(combed, jnp.maximum(relay, t + 1), new_ready)
    else:
        combed = jnp.zeros((n,), bool)
    acq2 = m & ~combed & (s.now_serving[s.hkey] == ticket)
    coord2, plain2, comb_tail = acquire(acq2, ticket, next_ticket, comb_tail)
    issue(coord2, CREAD, V_READ, 16)
    issue(plain2 & is_delete, MCAS, V_CAS, 8)
    issue(plain2 & ~is_delete, MW, V_WRITE, p.value_bytes)
    # deadlock detection & repair (§4.6): epoch stagnant for max_wait
    still = m & ~combed & ~acq2
    if _fail_lanes(p):
        stuck = still & (t - s.wait_start > p.max_wait)
        repair = _scatter_min_id(s.hkey, stuck, H, n)
        now_serving = s.now_serving.at[jnp.where(repair, s.hkey, H)].add(1, mode="drop")
        deadlocks = s.deadlocks + jnp.sum(repair.astype(jnp.int32))
        wait_start = jnp.where(stuck, t, wait_start)
    else:
        now_serving = s.now_serving
        deadlocks = s.deadlocks
    new_ready = jnp.where(still, t + 1, new_ready)

    # coordinator: READ done -> CN msg to executor -> combined write
    m = ev & (s.phase == CREAD)
    cn_hop(m, CMSG)
    m = ev & (s.phase == CMSG)
    comb_pend = jnp.where(m, 1, s.comb_pend)
    issue(m, MW, V_WRITE, p.value_bytes)

    m = ev & (s.phase == MW)
    issue(m, MCAS, V_CAS, 8)

    m = ev & (s.phase == MCAS)
    kver = kver.at[jnp.where(m, s.hkey, H)].add(1, mode="drop")
    issue(m, MFAA, V_FAA, 8, lock_addr=True)

    # release (epoch FAA done)
    m = ev & (s.phase == MFAA)
    epoch = s.epoch.at[jnp.where(m, s.hkey, H)].add(1, mode="drop")
    del_q = del_q.at[jnp.where(m & is_delete, s.hkey, H)].add(-1, mode="drop")
    comb_rel = m & (comb_pend > 0)
    batch = jnp.where(comb_rel, comb_tail - ticket + 1, 1)
    now_serving = now_serving.at[jnp.where(comb_rel, s.hkey, H)].set(
        comb_tail + 1, mode="drop")
    plain_rel = m & ~comb_rel
    now_serving = now_serving.at[jnp.where(plain_rel, s.hkey, H)].set(
        ticket + 1, mode="drop")
    comb_time = s.comb_time.at[jnp.where(comb_rel, s.hkey, H)].set(t, mode="drop")
    comb_base = s.comb_base.at[jnp.where(comb_rel, s.hkey, H)].set(ticket, mode="drop")
    comb_upto = s.comb_upto.at[jnp.where(comb_rel, s.hkey, H)].set(comb_tail, mode="drop")
    # handoff message if someone is queued behind (counted, client-side)
    handoff = plain_rel & (next_ticket[s.hkey] > ticket + 1)
    verbs = verbs.at[V_CN].add(jnp.sum(handoff.astype(jnp.int32)))
    comb_pend = jnp.where(m, 0, comb_pend)
    complete = complete | m

    # participants: relay arrives -> FAA -> done
    m = ev & (s.phase == PWAIT)
    issue(m, PFAA, V_FAA, 8, lock_addr=True)
    m = ev & (s.phase == PFAA)
    complete = complete | m
    combined_g_fin = combined_g_fin | m
    epoch = epoch.at[jnp.where(m, s.hkey, H)].add(1, mode="drop")

    # local-WC joiners: owner cleared the flag -> done (result = combiner's)
    m = ev & (s.phase == LWAIT)
    freed = m & (lflag[li] == 0)
    complete = complete | freed
    combined_l_fin = combined_l_fin | freed
    stay = m & ~freed
    new_ready = jnp.where(stay, t + 1, new_ready)

    # ============ op completion ==============================================
    fin = complete
    # release local-WC ownership
    lflag = lflag.at[jnp.where(fin & (own_local > 0), li, lflag.shape[0])
                     ].set(0, mode="drop")
    own_local = jnp.where(fin, 0, own_local)
    # latency histogram
    lat = jnp.clip(t - s.op_start, 0, p.hist_buckets - 1)
    hist = s.hist.at[jnp.where(fin, lat, p.hist_buckets)].add(1, mode="drop")
    # contention-aware feedback (§4.3, Algorithm 1)
    fin_w = fin & ~is_search
    if mode == SyncMode.CIDER:
        fin_opt = fin_w & ~is_pess
        promote = fin_opt & (att >= p.hotness_threshold) \
                          & (s.rrec[ci] >= p.hotness_threshold)
        credit = credit.at[jnp.where(promote, ci, credit.shape[0])].add(
            p.initial_credit, mode="drop")
        rrec = s.rrec.at[jnp.where(fin_opt, ci, s.rrec.shape[0])].set(
            att, mode="drop")
        # Algorithm 1 lines 13-16 run on EVERY pessimisticUpdate call:
        # coordinators AND participants of a multi-element batch see
        # WCBatchSize > 1 and add +2 on their own CN's credit table.
        grow = (fin & comb_rel & (batch > 1)) | (fin & combined_g_fin)
        # executor found no peers to combine -> multiplicative decrease
        shrink = fin & plain_rel & is_pess & ~is_delete
        credit = credit.at[jnp.where(grow, ci, credit.shape[0])].add(2, mode="drop")
        newc = credit[ci] // p.aimd_factor
        credit = credit.at[jnp.where(shrink, ci, credit.shape[0])].set(
            newc, mode="drop")
    else:
        rrec = s.rrec

    # counters
    done = s.done + jnp.sum(fin.astype(jnp.int32))
    done_w = s.done_w + jnp.sum(fin_w.astype(jnp.int32))
    comb_g = s.comb_g + jnp.sum((combined_g_fin & fin).astype(jnp.int32))
    comb_l = s.comb_l + jnp.sum((combined_l_fin & fin).astype(jnp.int32))
    pess_w = s.pess_w + jnp.sum((fin_w & is_pess).astype(jnp.int32))
    exec_w = s.exec_w + jnp.sum((fin_w & ~combined_g_fin & ~combined_l_fin)
                                .astype(jnp.int32))
    batch_sum = s.batch_sum + jnp.sum(jnp.where(fin & comb_rel, batch, 0))
    batch_cnt = s.batch_cnt + jnp.sum((fin & comb_rel).astype(jnp.int32))
    hot_ideal = s.hot_ideal + jnp.sum((fin_w & (att >= p.hotness_threshold))
                                      .astype(jnp.int32))

    # load next op
    op_idx = jnp.where(fin, s.op_idx + 1, s.op_idx)
    col = op_idx % p.max_ops
    gk = streams["kinds"][ids, col].astype(jnp.int32)
    ghk = streams["hkey"][ids, col].astype(jnp.int32)
    ghc = streams["hc"][ids, col].astype(jnp.int32)
    ghl = streams["hl"][ids, col].astype(jnp.int32)
    kind = jnp.where(fin, gk, s.kind)
    hkey = jnp.where(fin, ghk, s.hkey)
    hc = jnp.where(fin, ghc, s.hc)
    hl = jnp.where(fin, ghl, s.hl)
    att = jnp.where(fin, 0, att)
    is_pess_i = jnp.where(fin, 0, is_pess.astype(jnp.int32))
    new_phase = jnp.where(fin, THINK, new_phase)
    new_ready = jnp.where(fin, t + p.think, new_ready)
    op_start = jnp.where(fin, t + p.think, s.op_start)

    # ============ inject failure (§4.6) ======================================
    fl = _fail_lanes(p)
    if fl:
        kill = jnp.isin(ids, jnp.asarray(fl, jnp.int32)) & (t >= p.fail_tick)
        new_phase = jnp.where(kill, DEAD, new_phase)

    # ============ network: issue all MN verbs of this tick ===================
    net2, done_at = issue_mn(s.net, t, issue_mask, issue_bytes, issue_cost,
                             issue_atomic, issue_addr, p)
    if p.n_replicas > 1:
        # replicated write-class verbs complete at the SLOWEST replica: one
        # extra replica_rtt on top of the primary's completion tick
        done_at = done_at + jnp.where(issue_repl, p.replica_rtt, 0)
    new_ready = jnp.where(issue_mask, done_at, new_ready)

    return SimState(
        phase=new_phase, ready=new_ready, kind=kind, hkey=hkey, hc=hc, hl=hl,
        ticket=ticket, att=att, kver_seen=kver_seen, comb_tail=comb_tail,
        comb_pend=comb_pend, own_local=own_local, idx_left=idx_left,
        op_start=op_start, op_idx=op_idx, is_pess=is_pess_i,
        wait_start=wait_start,
        next_ticket=next_ticket, now_serving=now_serving, kver=kver,
        lockw=lockw, comb_time=comb_time, comb_base=comb_base,
        comb_upto=comb_upto, epoch=epoch, del_q=del_q,
        lflag=lflag, credit=credit, rrec=rrec,
        net=net2, verbs=verbs, done=done, done_w=done_w, retries=retries,
        comb_g=comb_g, comb_l=comb_l, pess_w=pess_w, exec_w=exec_w,
        batch_sum=batch_sum, batch_cnt=batch_cnt, hot_ideal=hot_ideal,
        deadlocks=deadlocks, hist=hist,
    )
