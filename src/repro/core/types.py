"""Core types shared by the CIDER dataplane engine and the protocol simulator.

The paper's op vocabulary (§2.2): SEARCH / INSERT / UPDATE / DELETE over a
store of data pointers; IDU = {INSERT, UPDATE, DELETE}.  SCAN (key + count)
extends it with the range read YCSB E is built from (DESIGN.md §9) — a
reader over a contiguous leaf-slot run, representable only on a
range-capable index.  One-sided RDMA verbs
(§2.1): READ / WRITE / CAS / FAA / masked-CAS (get-and-set).  We meter each
verb class separately because the paper's bottleneck argument is on
memory-node (MN) NIC *IOPS*, with client-to-client (CN<->CN) messages
explicitly off the MN NIC (the whole point of ShiftLock's handoff design).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "OpKind", "Verb", "SyncMode", "IOMetrics", "LatencyStats", "EngineConfig",
    "OpBatch", "NULL_PTR", "UnsupportedOpError", "io_zeros", "io_add",
    "per_replica_bill",
]

# A null data pointer (empty slot). Pointers are int32 heap indices >= 0.
NULL_PTR = jnp.int32(-1)


class UnsupportedOpError(NotImplementedError):
    """An op kind the target index structure cannot serve *by design* —
    e.g. SCAN on a hash index, whose buckets scatter adjacent keys so a key
    range has no contiguous slot run (DESIGN.md §9).

    Every store raises this one type for capability rejections (enforced by
    the bill lint, ``repro.analysis.bill_lint``) so callers can catch
    "wrong index for this workload" distinctly from genuine bugs; it
    subclasses ``NotImplementedError`` for backward compatibility.
    """


class OpKind(enum.IntEnum):
    SEARCH = 0
    INSERT = 1
    UPDATE = 2
    DELETE = 3
    NOP = 4      # padding
    SCAN = 5     # range read: (key, count) — count rides OpBatch.values.
                 # Resolvable only by a range-capable (radix) index: the key
                 # run [key, key+count) must be a contiguous leaf-slot run
                 # (stores/smart_art.py); hash indexes reject it.  YCSB E's
                 # op; rows found come back in Results.rows.


class Verb(enum.IntEnum):
    """RDMA verb classes, for I/O metering."""
    READ = 0
    WRITE = 1
    CAS = 2          # includes masked-CAS (get-and-set) — same NIC cost
    FAA = 3
    CN_MSG = 4       # client<->client message: does NOT consume MN NIC IOPS


class SyncMode(enum.IntEnum):
    """The four synchronization schemes compared in the paper (§5.1)."""
    OSYNC = 0     # optimistic: out-of-place write + CAS-retry     (RACE/SMART default)
    SPIN = 1      # CAS spinlock w/ truncated exponential backoff  (SMART-framework lock)
    MCS = 2       # ShiftLock distributed MCS lock, no combining   (FAST'25)
    CIDER = 3     # MCS + global write-combining + contention-aware sync (this paper)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class IOMetrics:
    """Per-verb I/O counters. ``mn_iops``/``mn_bytes`` are the bottleneck
    quantities (memory-pool NIC); ``cn_msgs`` ride client NICs."""
    reads: jax.Array      # () i64
    writes: jax.Array
    cas: jax.Array
    faa: jax.Array
    cn_msgs: jax.Array
    mn_bytes: jax.Array   # bytes moved through MN NICs
    retries: jax.Array    # redundant (failed) CAS attempts — paper Fig 1 metric
    combined: jax.Array   # ops whose write was combined away (WC rate numerator)
    executed: jax.Array   # ops that reached the store
    repair_cas: jax.Array     # orphan-repair verbs (§4.6): epoch-stale lock
                              # break CAS + (SPIN) lease-expiry polls — the
                              # recovery I/O bill; also folded into reads/cas
    orphan_windows: jax.Array  # slot-windows spent with a stranded (orphaned)
                               # lock still outstanding at window end

    @property
    def mn_iops(self) -> jax.Array:
        return self.reads + self.writes + self.cas + self.faa

    def as_dict(self) -> dict[str, Any]:
        d = {f.name: np.asarray(getattr(self, f.name)).item()
             for f in dataclasses.fields(self)}
        d["mn_iops"] = d["reads"] + d["writes"] + d["cas"] + d["faa"]
        return d


@dataclasses.dataclass(frozen=True)
class LatencyStats:
    """Modeled per-op latency percentiles (microseconds) — the paper's second
    evaluation axis next to throughput (Figs 11-12, 16-19).  Produced by
    ``repro.core.runner.modeled_latency`` / ``latency_stats`` from each op's
    exact verb bill and wait-queue rank under the ``SimParams`` cost model."""
    p50_us: float
    p99_us: float
    mean_us: float
    max_us: float
    n_ops: int

    def as_dict(self) -> dict[str, Any]:
        return dataclasses.asdict(self)


def io_zeros() -> IOMetrics:
    z = jnp.zeros((), jnp.int64) if jax.config.jax_enable_x64 else jnp.zeros((), jnp.int32)
    return IOMetrics(*([z] * len(dataclasses.fields(IOMetrics))))


def io_add(a: IOMetrics, b: IOMetrics) -> IOMetrics:
    return jax.tree.map(lambda x, y: x + y, a, b)


def per_replica_bill(io_one: IOMetrics, io_r: IOMetrics,
                     n_replicas: int) -> list[dict[str, int]]:
    """Decompose a replicated bill into per-replica-MN bills (host-side).

    ``io_one`` is the ``n_replicas=1`` bill of a run and ``io_r`` the
    ``n_replicas=R`` bill of the *same* run.  Under SNAPSHOT client-centric
    replication (DESIGN.md §13) the engine fans every write-class verb
    (WRITE/CAS/FAA, their retries, and §4.6 repair break-CASes) out to all R
    replica MNs while reads — index READs, coordinator lock reads, repair
    stale-epoch detection reads, SCAN probes — go to the primary only, so
    the totals determine the split exactly:

    * every replica carries the R=1 write-class verbs and write bytes
      (``wr = (io_r.mn_bytes - io_one.mn_bytes) / (R - 1)``),
    * the primary (replica 0) additionally carries all reads, read bytes,
      and the observable-only counters (cn_msgs/combined/executed/
      orphan_windows), which are logical-op properties, not fan-out.

    Raises ``ValueError`` if the two bills are not consistent with the ×R
    contract — this is the conservation law the property tests enforce:
    summing the returned dicts field-by-field reproduces ``io_r``.
    """
    one, tot = io_one.as_dict(), io_r.as_dict()
    r = int(n_replicas)
    if r < 1:
        raise ValueError(f"n_replicas must be >= 1, got {r}")
    if r == 1:
        if one != tot:
            raise ValueError("R=1 bills differ; same run required")
        return [{k: v for k, v in one.items() if k != "mn_iops"}]
    for f in ("writes", "cas", "faa", "retries", "repair_cas"):
        if tot[f] != r * one[f]:
            raise ValueError(
                f"replicated bill violates x{r} write fan-out on '{f}': "
                f"{tot[f]} != {r} * {one[f]}")
    for f in ("reads", "cn_msgs", "combined", "executed", "orphan_windows"):
        if tot[f] != one[f]:
            raise ValueError(
                f"replicated bill changes read/observable field '{f}': "
                f"{tot[f]} != {one[f]} (reads bill to one replica)")
    wr_bytes, rem = divmod(tot["mn_bytes"] - one["mn_bytes"], r - 1)
    if rem or wr_bytes < 0 or wr_bytes > one["mn_bytes"]:
        raise ValueError(
            f"replicated byte bill inconsistent: mn_bytes {one['mn_bytes']} "
            f"-> {tot['mn_bytes']} is not ro + {r}*wr")
    secondary = {
        "reads": 0, "writes": one["writes"], "cas": one["cas"],
        "faa": one["faa"], "cn_msgs": 0, "mn_bytes": wr_bytes,
        "retries": one["retries"], "combined": 0, "executed": 0,
        "repair_cas": one["repair_cas"], "orphan_windows": 0,
    }
    primary = {k: v for k, v in one.items() if k != "mn_iops"}
    primary["cn_msgs"] = tot["cn_msgs"]
    primary["combined"] = tot["combined"]
    primary["executed"] = tot["executed"]
    primary["orphan_windows"] = tot["orphan_windows"]
    return [primary] + [dict(secondary) for _ in range(r - 1)]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class OpBatch:
    """A device batch of concurrent KV ops (one synchronization window).

    ``keys`` here are *slot indices* into the pointer store — index structures
    (hash / radix tree) resolve string keys to slots first and account their
    own I/O.  ``pos`` is the canonical serialization priority inside the batch
    (queue order == batch position, so all four modes agree on the final
    state: last-writer-wins by ``pos``).  ``cn`` is the compute-node id of the
    issuing client (local-WC combines within a CN; global WC across CNs).
    """
    kinds: jax.Array    # (B,) int32 OpKind
    keys: jax.Array     # (B,) int32 slot index
    values: jax.Array   # (B,) int32 value payload id
    pos: jax.Array      # (B,) int32 serialization priority (0..B-1)
    cn: jax.Array       # (B,) int32 compute-node id

    @staticmethod
    def make(kinds, keys, values, n_cns: int = 1, lanes_per_cn: int | None = None):
        kinds = jnp.asarray(kinds, jnp.int32)
        keys = jnp.asarray(keys, jnp.int32)
        values = jnp.asarray(values, jnp.int32)
        b = kinds.shape[0]
        pos = jnp.arange(b, dtype=jnp.int32)
        if lanes_per_cn is None:
            lanes_per_cn = max(b // max(n_cns, 1), 1)
        cn = (pos // lanes_per_cn) % max(n_cns, 1)
        return OpBatch(kinds=kinds, keys=keys, values=values, pos=pos, cn=cn)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Static configuration for the dataplane engine."""
    n_slots: int                      # pointer-array length
    heap_slots: int                   # out-of-place heap capacity (values)
    mode: SyncMode = SyncMode.CIDER
    local_wc: bool = True             # local write combining (baselines get it too, §5.1)
    value_bytes: int = 8              # payload size (paper: 8B values)
    ptr_bytes: int = 8                # data pointer (60-bit ptr + 4-bit version)
    lock_bytes: int = 16              # lock entry: 60b tail + 64b epoch + 4b version
    index_read_iops: int = 1          # per-op index I/O (pointer array: 1 READ)
    index_read_bytes: int = 8
    # SNAPSHOT client-centric replication degree (FUSEE; DESIGN.md §13).
    # Every write-class verb (WRITE/CAS/FAA, retries, §4.6 repair break-CAS)
    # fans out to all R replica MNs from the client; reads bill to one
    # replica.  R=1 compiles to the byte-identical pre-replication program
    # (the scaling block is a static Python branch), so the replica axis is
    # provably zero-cost when off (tests/test_replication.py).
    n_replicas: int = 1
    # CIDER contention-aware parameters (§4.3, Fig 15)
    initial_credit: int = 36
    hotness_threshold: int = 2
    aimd_factor: int = 2
    # SPIN backoff cap (truncated exponential), in poll-interval rounds
    backoff_cap: int = 6
    # Crash recovery (§4.6): how many poll-interval rounds a SPIN waiter
    # spends re-CASing an orphaned lock before the lease expires and the
    # repair CAS succeeds (MCS/CIDER waiters wait locally — ShiftLock's
    # design point — so only SPIN pays MN verbs for the lease).
    lease_poll_rounds: int = 16
    # SCAN support (DESIGN.md §9): static per-op leaf-run bound.  0 disables
    # the reader-probe pass entirely — the engine then compiles to exactly
    # the pre-SCAN program, so point-op-only stores pay nothing.  A SCAN's
    # count is clipped to this bound by the stores/workloads; the engine
    # expands each SCAN into `scan_max` reader probes that join the per-key
    # wait queues at the scanning op's batch position.
    scan_max: int = 0
    # Kernel dispatch seam (DESIGN.md §10): which implementation of the
    # sorted-run sweeps (wc_combine, scan_probe) the engine's consumers use.
    # "auto" = the compiled Pallas kernels on TPU, the jnp reference
    # elsewhere; "pallas" = force the kernels (interpret mode off-TPU — CI
    # exercises the exact kernel dataflow); "jnp" = force the reference.
    # All three are bit-identical by contract and by test (tests/
    # test_backend.py).  The config is hashable/static, so the choice flows
    # through jit, the fused runner scan, and dist's per-shard config cache.
    kernel_backend: str = "auto"
