"""Global write-combining as a batched primitive (§4.2, TPU adaptation).

The paper's mechanism: the MCS wait queue *is* a registry of concurrent
conflicting UPDATEs, so the whole queue is served by ONE combined write by
the queue-tail client ("executor"), with last-writer-wins resolution.

The batch analogue on an SPMD dataplane: the ops that would have formed a
wait queue are exactly the ops in the current batch that share a key.  A
stable sort by (key, queue-position) materializes every wait queue at once;
the *last* element of each run is the executor; everyone else is combined.

This module is the kernel-dispatch seam (DESIGN.md §10): ``plan_combine``
and ``per_key_stats`` accept ``backend`` ∈ {"auto", "pallas", "jnp"} and
route the sorted-run sweep through either the fused Pallas kernel
(``repro.kernels.wc_combine``, interpret mode off-TPU) or the pure-jnp
path below — bit-identical by contract and by test.

DESIGN.md §2.1 (the combine primitive): one lexsort materializes every wait
queue; reader ranks extend it to SCAN (§9.2); §10 covers backend dispatch
and the shared-sort derived plans (``stats_from_plan``, ``plan_groups``).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["CombinePlan", "plan_combine", "segment_last", "segment_counts",
           "OpStats", "per_key_stats", "stats_from_plan", "GroupPlan",
           "plan_groups", "group_last", "local_executors", "reader_waits",
           "resolve_backend"]

_BIG = 2**31 - 1   # python int, weak-typed to int32 at use sites


def resolve_backend(backend: str) -> tuple[str, bool]:
    """Resolve a ``kernel_backend`` config value to ``(impl, interpret)``.

    ``auto`` picks the Pallas kernel only where it is compiled (TPU) and the
    jnp reference elsewhere — on CPU the interpreted kernel is strictly
    slower, so "auto" never selects it.  ``pallas`` forces the kernel
    (interpret mode off-TPU: CI exercises the exact kernel dataflow).
    ``jnp`` forces the reference.  DESIGN.md §10.
    """
    if backend == "auto":
        if jax.default_backend() == "tpu":
            return "pallas", False
        return "jnp", False
    if backend == "pallas":
        return "pallas", jax.default_backend() != "tpu"
    if backend == "jnp":
        return "jnp", False
    raise ValueError(f"unknown kernel backend {backend!r} "
                     "(expected 'auto', 'pallas' or 'jnp')")


def _first_last_rank(ks: jax.Array, backend: str
                     ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Run boundaries + in-run rank of a *sorted* key array, via the
    dispatch seam: the Pallas ``wc_combine`` kernel or the jnp sweep."""
    impl, interpret = resolve_backend(backend)
    if impl == "pallas":
        from repro.kernels.wc_combine.ops import wc_combine_op
        return wc_combine_op(ks, interpret=interpret)
    idx = jnp.arange(ks.shape[0], dtype=jnp.int32)
    neq = ks[1:] != ks[:-1]
    is_first = jnp.concatenate([jnp.ones((1,), bool), neq])
    is_last = jnp.concatenate([neq, jnp.ones((1,), bool)])
    rank = idx - jax.lax.cummax(jnp.where(is_first, idx, 0))
    return is_first, is_last, rank


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CombinePlan:
    """The materialized wait queues of one synchronization window.

    All arrays are in *sorted* order (by key, then queue position); ``perm``
    maps sorted -> original batch positions.
    """
    perm: jax.Array          # (B,) int32: original index of sorted element
    keys_sorted: jax.Array   # (B,) int32
    is_first: jax.Array      # (B,) bool: head of a key run (the "coordinator")
    is_last: jax.Array       # (B,) bool: tail of a key run (the "executor")
    run_length: jax.Array    # (B,) int32: my queue length (WC batch size)
    rank: jax.Array          # (B,) int32: my position within my queue (0-based)
    n_unique: jax.Array      # () int32: number of distinct keys (executed writes)


def plan_combine(keys: jax.Array, pos: jax.Array, valid: jax.Array,
                 *, backend: str = "jnp") -> CombinePlan:
    """Build wait queues for a batch of write ops.

    ``keys``: (B,) slot ids; ``pos``: (B,) serialization priority (queue
    order); ``valid``: (B,) bool — invalid ops sort to the back and form a
    dedicated run that callers must mask out (they are never executors of a
    real key because the sort key is +inf for them).  ``backend`` selects
    the run-sweep implementation (DESIGN.md §10); the sort itself is XLA
    either way and the outputs are bit-identical.
    """
    b = keys.shape[0]
    k = jnp.where(valid, keys, _BIG)
    # Stable composite sort: primary key, secondary queue position.
    order = jnp.lexsort((pos, k))
    ks = k[order]
    is_first, is_last, rank = _first_last_rank(ks, backend)
    idx = jnp.arange(b, dtype=jnp.int32)
    seg_start = idx - rank
    seg_end = jax.lax.cummin(jnp.where(is_last, idx, _BIG), reverse=True)
    run_length = seg_end - seg_start + 1
    valid_sorted = valid[order]
    n_unique = jnp.sum(is_first & valid_sorted).astype(jnp.int32)
    return CombinePlan(
        perm=order.astype(jnp.int32), keys_sorted=ks, is_first=is_first,
        is_last=is_last, run_length=run_length, rank=rank, n_unique=n_unique,
    )


def segment_last(plan: CombinePlan, values: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Last-writer-wins combine: one (key, value) per wait queue.

    Returns (unique_keys, winning_values, winner_mask_sorted); the first two
    are length-B with garbage beyond ``plan.n_unique`` positions — callers
    scatter with the mask, so no compaction is required on device.
    """
    vs = values[plan.perm]
    return (jnp.where(plan.is_last, plan.keys_sorted, 0),
            jnp.where(plan.is_last, vs, 0),
            plan.is_last)


def segment_counts(plan: CombinePlan, valid: jax.Array) -> jax.Array:
    """Per-original-op queue length (the paper's "WC batch size"), unsorted order."""
    out = jnp.zeros_like(plan.run_length)
    return out.at[plan.perm].set(jnp.where(valid[plan.perm], plan.run_length, 0))


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class OpStats:
    """Per-original-op wait-queue statistics over the masked op subset."""
    is_tail: jax.Array    # (B,) bool — queue tail (the executor / last writer)
    mult_of: jax.Array    # (B,) int32 — queue length of my key (0 if unmasked)
    rank_of: jax.Array    # (B,) int32 — 0-based rank in my queue (0 if unmasked)
    retry_sum: jax.Array  # () int32 — sum of ranks = Σ_k m_k(m_k-1)/2


def stats_from_plan(plan: CombinePlan, mask: jax.Array) -> OpStats:
    """Queue statistics for a *subset* of an existing plan's valid ops.

    Precondition: ``mask ⊆`` the validity the plan was built with, so every
    masked lane sits inside its true key run.  Because the lexsort is stable
    and masked lanes keep their relative ``pos`` order, counting masked
    lanes inside each run reproduces ``per_key_stats(keys, pos, mask)``
    bit-for-bit — without paying a second sort (DESIGN.md §10.2).
    """
    b = plan.perm.shape[0]
    idx = jnp.arange(b, dtype=jnp.int32)
    mask_s = mask[plan.perm]
    m_i = mask_s.astype(jnp.int32)
    c = jnp.cumsum(m_i)               # masked lanes through me, inclusive
    cex = c - m_i                     # masked lanes strictly before me
    seg_start = idx - plan.rank
    seg_end = seg_start + plan.run_length - 1
    rank_s = cex - cex[seg_start]     # masked lanes before me, in-run
    mult_s = c[seg_end] - cex[seg_start]
    is_tail_s = mask_s & (c == c[seg_end])
    zeros_i = jnp.zeros((b,), jnp.int32)
    is_tail = jnp.zeros((b,), bool).at[plan.perm].set(is_tail_s)
    mult_of = zeros_i.at[plan.perm].set(jnp.where(mask_s, mult_s, 0))
    rank_of = zeros_i.at[plan.perm].set(jnp.where(mask_s, rank_s, 0))
    retry_sum = jnp.sum(jnp.where(mask_s, rank_s, 0))
    return OpStats(is_tail=is_tail, mult_of=mult_of, rank_of=rank_of,
                   retry_sum=retry_sum)


def per_key_stats(keys: jax.Array, pos: jax.Array, mask: jax.Array,
                  *, backend: str = "jnp") -> OpStats:
    """Queue statistics per masked op, grouped by key, ordered by ``pos``."""
    return stats_from_plan(plan_combine(keys, pos, mask, backend=backend),
                           mask)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class GroupPlan:
    """(key, compute-node) group structure of one window, in sorted order.

    ``perm`` sorts by (key, cn, pos); ``g_end`` is the sorted index of my
    group's last element.  One such sort serves every masked subset via
    ``group_last`` (DESIGN.md §10.2).
    """
    perm: jax.Array    # (B,) int32
    g_end: jax.Array   # (B,) int32


def plan_groups(keys: jax.Array, cn: jax.Array, pos: jax.Array,
                valid: jax.Array) -> GroupPlan:
    """Sort once by (key, cn, pos); invalid lanes form a +inf tail run."""
    k = jnp.where(valid, keys, _BIG)
    order = jnp.lexsort((pos, cn, k))
    ks, cs = k[order], cn[order]
    glast = jnp.concatenate([(ks[1:] != ks[:-1]) | (cs[1:] != cs[:-1]),
                             jnp.ones((1,), bool)])
    idx = jnp.arange(k.shape[0], dtype=jnp.int32)
    g_end = jax.lax.cummin(jnp.where(glast, idx, _BIG), reverse=True)
    return GroupPlan(perm=order.astype(jnp.int32), g_end=g_end)


def group_last(gplan: GroupPlan, mask: jax.Array) -> jax.Array:
    """Last masked lane of each (key, cn) group, unsorted order.

    Precondition: ``mask ⊆`` the validity ``plan_groups`` was built with.
    Equals ``local_executors(keys, cn, pos, mask)`` bit-for-bit (stable
    sort: masked lanes keep their relative order inside each group).
    """
    mask_s = mask[gplan.perm]
    c = jnp.cumsum(mask_s.astype(jnp.int32))
    is_lastm_s = mask_s & (c == c[gplan.g_end])
    return jnp.zeros(mask.shape, bool).at[gplan.perm].set(is_lastm_s)


def local_executors(keys: jax.Array, cn: jax.Array, pos: jax.Array,
                    mask: jax.Array) -> jax.Array:
    """Local write combining (§3.1): the last (by ``pos``) masked op of each
    (key, compute-node) group — the only one that leaves the CN."""
    return group_last(plan_groups(keys, cn, pos, mask), mask)


def local_executors_scatter(keys: jax.Array, cn: jax.Array, pos: jax.Array,
                            mask: jax.Array, n_slots: int, n_cns: int,
                            base=0) -> jax.Array:
    """Sort-free ``local_executors``: one O(B) scatter-max over a static
    ``(n_slots * n_cns,)`` cell table instead of a (key, cn, pos) lexsort.

    Bit-identical to ``local_executors`` under the ``OpBatch`` contract the
    engine already relies on — ``pos`` unique per batch (serialization
    priorities 0..B-1) and ``cn ∈ [0, n_cns)`` (``OpBatch.make`` takes cn
    mod n_cns; the liveness plane clips the same way): the unique max-pos
    masked lane of each (key, cn) cell IS the stable sort's group tail.
    The engine picks this form whenever a static CN count is in scope
    (``alive``/``died`` carry it as their shape) — DESIGN.md §10.2.
    ``base`` rebases global keys to shard-local cells under sharding; lanes
    outside ``mask`` never touch the table, so out-of-shard keys are inert.
    """
    slot = jnp.clip(keys - base, 0, n_slots - 1)
    gi = slot * n_cns + jnp.clip(cn, 0, n_cns - 1)
    buf = jnp.full((n_slots * n_cns,), -1, jnp.int32)
    buf = buf.at[gi].max(jnp.where(mask, pos, -1), mode="drop")
    return mask & (buf[gi] == pos)


def reader_waits(keys: jax.Array, pos: jax.Array, readers: jax.Array,
                 writers: jax.Array) -> jax.Array:
    """Per-reader count of lock-holding writers *ahead* of it in its queue.

    SCAN support (DESIGN.md §9): a reader joins the per-key wait queue at its
    op's batch position, so the number of masked ``writers`` on the same key
    with a strictly smaller ``pos`` is exactly how many exclusive holders the
    reader sits behind.  Precondition: no reader shares a (key, pos) pair
    with a writer (readers inherit their parent op's position; a lane is
    either a reader probe or a writer, never both on one slot).

    This standalone form pays its own lexsort; the engine's SCAN path fuses
    the same computation into the ``scan_probe`` kernel pass over the
    already-sorted probe lanes (DESIGN.md §10.3).

    Returns (N,) int32 — the wait rank for reader lanes, 0 elsewhere.
    """
    n = keys.shape[0]
    mask = readers | writers
    k = jnp.where(mask, keys, _BIG)
    order = jnp.lexsort((pos, k))
    ks = k[order]
    w_s = (writers & mask)[order].astype(jnp.int32)
    is_first = jnp.concatenate([jnp.ones((1,), bool), ks[1:] != ks[:-1]])
    excl = jnp.cumsum(w_s) - w_s                   # writers before me, globally
    base = jax.lax.cummax(jnp.where(is_first, excl, 0))
    waits_s = excl - base                          # writers before me, in-queue
    out = jnp.zeros((n,), jnp.int32)
    return out.at[order].set(jnp.where(readers[order], waits_s, 0))
