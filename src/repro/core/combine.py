"""Global write-combining as a batched primitive (§4.2, TPU adaptation).

The paper's mechanism: the MCS wait queue *is* a registry of concurrent
conflicting UPDATEs, so the whole queue is served by ONE combined write by
the queue-tail client ("executor"), with last-writer-wins resolution.

The batch analogue on an SPMD dataplane: the ops that would have formed a
wait queue are exactly the ops in the current batch that share a key.  A
stable sort by (key, queue-position) materializes every wait queue at once;
the *last* element of each run is the executor; everyone else is combined.

This module is the pure-jnp reference implementation; ``repro.kernels.
wc_combine`` provides the fused Pallas TPU kernel with an identical contract.

DESIGN.md §2.1 (the combine primitive): one lexsort materializes every wait
queue; reader ranks extend it to SCAN (§9.2).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["CombinePlan", "plan_combine", "segment_last", "segment_counts",
           "OpStats", "per_key_stats", "local_executors", "reader_waits"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CombinePlan:
    """The materialized wait queues of one synchronization window.

    All arrays are in *sorted* order (by key, then queue position); ``perm``
    maps sorted -> original batch positions.
    """
    perm: jax.Array          # (B,) int32: original index of sorted element
    keys_sorted: jax.Array   # (B,) int32
    is_first: jax.Array      # (B,) bool: head of a key run (the "coordinator")
    is_last: jax.Array       # (B,) bool: tail of a key run (the "executor")
    run_length: jax.Array    # (B,) int32: my queue length (WC batch size)
    rank: jax.Array          # (B,) int32: my position within my queue (0-based)
    n_unique: jax.Array      # () int32: number of distinct keys (executed writes)


def plan_combine(keys: jax.Array, pos: jax.Array, valid: jax.Array) -> CombinePlan:
    """Build wait queues for a batch of write ops.

    ``keys``: (B,) slot ids; ``pos``: (B,) serialization priority (queue
    order); ``valid``: (B,) bool — invalid ops sort to the back and form a
    dedicated run that callers must mask out (they are never executors of a
    real key because the sort key is +inf for them).
    """
    b = keys.shape[0]
    big = jnp.int32(2**31 - 1)
    k = jnp.where(valid, keys, big)
    # Stable composite sort: primary key, secondary queue position.
    order = jnp.lexsort((pos, k))
    ks = k[order]
    is_first = jnp.concatenate([jnp.ones((1,), bool), ks[1:] != ks[:-1]])
    is_last = jnp.concatenate([ks[1:] != ks[:-1], jnp.ones((1,), bool)])
    seg = jnp.cumsum(is_first.astype(jnp.int32)) - 1          # segment id per element
    ones = jnp.ones((b,), jnp.int32)
    counts = jax.ops.segment_sum(ones, seg, num_segments=b)   # per-segment length
    run_length = counts[seg]
    # rank within run = position - start of my segment
    seg_start = jax.ops.segment_min(jnp.arange(b, dtype=jnp.int32), seg, num_segments=b)
    rank = jnp.arange(b, dtype=jnp.int32) - seg_start[seg]
    valid_sorted = valid[order]
    n_unique = jnp.sum(is_first & valid_sorted).astype(jnp.int32)
    return CombinePlan(
        perm=order.astype(jnp.int32), keys_sorted=ks, is_first=is_first,
        is_last=is_last, run_length=run_length, rank=rank, n_unique=n_unique,
    )


def segment_last(plan: CombinePlan, values: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Last-writer-wins combine: one (key, value) per wait queue.

    Returns (unique_keys, winning_values, winner_mask_sorted); the first two
    are length-B with garbage beyond ``plan.n_unique`` positions — callers
    scatter with the mask, so no compaction is required on device.
    """
    vs = values[plan.perm]
    return (jnp.where(plan.is_last, plan.keys_sorted, 0),
            jnp.where(plan.is_last, vs, 0),
            plan.is_last)


def segment_counts(plan: CombinePlan, valid: jax.Array) -> jax.Array:
    """Per-original-op queue length (the paper's "WC batch size"), unsorted order."""
    out = jnp.zeros_like(plan.run_length)
    return out.at[plan.perm].set(jnp.where(valid[plan.perm], plan.run_length, 0))


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class OpStats:
    """Per-original-op wait-queue statistics over the masked op subset."""
    is_tail: jax.Array    # (B,) bool — queue tail (the executor / last writer)
    mult_of: jax.Array    # (B,) int32 — queue length of my key (0 if unmasked)
    rank_of: jax.Array    # (B,) int32 — 0-based rank in my queue (0 if unmasked)
    retry_sum: jax.Array  # () int32 — sum of ranks = Σ_k m_k(m_k-1)/2


def per_key_stats(keys: jax.Array, pos: jax.Array, mask: jax.Array) -> OpStats:
    """Queue statistics per masked op, grouped by key, ordered by ``pos``."""
    plan = plan_combine(keys, pos, mask)
    b = keys.shape[0]
    mask_s = mask[plan.perm]
    is_tail_s = plan.is_last & mask_s
    zeros_i = jnp.zeros((b,), jnp.int32)
    is_tail = jnp.zeros((b,), bool).at[plan.perm].set(is_tail_s)
    mult_of = zeros_i.at[plan.perm].set(jnp.where(mask_s, plan.run_length, 0))
    rank_of = zeros_i.at[plan.perm].set(jnp.where(mask_s, plan.rank, 0))
    retry_sum = jnp.sum(jnp.where(mask_s, plan.rank, 0))
    return OpStats(is_tail=is_tail, mult_of=mult_of, rank_of=rank_of,
                   retry_sum=retry_sum)


def reader_waits(keys: jax.Array, pos: jax.Array, readers: jax.Array,
                 writers: jax.Array) -> jax.Array:
    """Per-reader count of lock-holding writers *ahead* of it in its queue.

    SCAN support (DESIGN.md §9): a reader joins the per-key wait queue at its
    op's batch position, so the number of masked ``writers`` on the same key
    with a strictly smaller ``pos`` is exactly how many exclusive holders the
    reader sits behind.  Precondition: no reader shares a (key, pos) pair
    with a writer (readers inherit their parent op's position; a lane is
    either a reader probe or a writer, never both on one slot).

    Returns (N,) int32 — the wait rank for reader lanes, 0 elsewhere.
    """
    n = keys.shape[0]
    mask = readers | writers
    big = jnp.int32(2**31 - 1)
    k = jnp.where(mask, keys, big)
    order = jnp.lexsort((pos, k))
    ks = k[order]
    w_s = (writers & mask)[order].astype(jnp.int32)
    is_first = jnp.concatenate([jnp.ones((1,), bool), ks[1:] != ks[:-1]])
    seg = jnp.cumsum(is_first.astype(jnp.int32)) - 1
    excl = jnp.cumsum(w_s) - w_s                   # writers before me, globally
    seg_start = jax.ops.segment_min(jnp.arange(n, dtype=jnp.int32), seg,
                                    num_segments=n)
    waits_s = excl - excl[seg_start[seg]]          # writers before me, in-queue
    out = jnp.zeros((n,), jnp.int32)
    return out.at[order].set(jnp.where(readers[order], waits_s, 0))


def local_executors(keys: jax.Array, cn: jax.Array, pos: jax.Array,
                    mask: jax.Array) -> jax.Array:
    """Local write combining (§3.1): the last (by ``pos``) masked op of each
    (key, compute-node) group — the only one that leaves the CN."""
    big = jnp.int32(2**31 - 1)
    k = jnp.where(mask, keys, big)
    order = jnp.lexsort((pos, cn, k))
    ks, cs = k[order], cn[order]
    last = jnp.concatenate([(ks[1:] != ks[:-1]) | (cs[1:] != cs[:-1]),
                            jnp.ones((1,), bool)])
    out = jnp.zeros(keys.shape, bool).at[order].set(last)
    return out & mask
