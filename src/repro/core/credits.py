"""Contention-aware synchronization: the per-key AIMD credit scheme (§4.3).

Each compute node tracks, per data pointer, a ``credit`` (contention level)
and a ``retryRecord`` (CAS retries of the last optimistic attempt).  The
decision rule (Algorithm 1):

  * credit > 0  -> consume one credit, take the PESSIMISTIC path (MCS + WC);
  * credit == 0 -> take the OPTIMISTIC path (out-of-place write + CAS).

Feedback:
  * pessimistic, WC batch  > 1 : credit += 2            (additive increase)
  * pessimistic, WC batch == 1 : credit //= AIMD_FACTOR (multiplicative decrease)
  * optimistic, nRetry >= HOTNESS_THRESHOLD and the *previous* attempt also
    retried >= HOTNESS_THRESHOLD: credit += INITIAL_CREDIT (=36; Fig 15).

The table is a fixed-size direct-mapped hash (the paper stores per-key 8B of
metadata for hot keys only; a direct-mapped table gives the same O(1) cost
with graceful aliasing for cold keys — collisions can only mis-route a key to
a path that remains *correct*, only its cost changes; see §4.5.2).

DESIGN.md §2 (engine conventions; replication rule §3.3): the per-key AIMD
credit plane deciding optimistic vs pessimistic.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["CreditState", "credit_init", "credit_slot", "credit_decide",
           "credit_feedback"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CreditState:
    credit: jax.Array        # (table,) int32
    retry_record: jax.Array  # (table,) int32


def credit_init(table_size: int) -> CreditState:
    return CreditState(credit=jnp.zeros((table_size,), jnp.int32),
                       retry_record=jnp.zeros((table_size,), jnp.int32))


def credit_slot(keys: jax.Array, table_size: int) -> jax.Array:
    """Direct-mapped credit-table slot of each key (Fibonacci hash — good
    avalanche for sequential slot ids).  Public because the engine's orphan
    model (crash recovery, §4.6) consults the table read-only to decide
    which crashed writers were on the pessimistic path."""
    h = (keys.astype(jnp.uint32) * jnp.uint32(2654435761)) >> jnp.uint32(7)
    return (h % jnp.uint32(table_size)).astype(jnp.int32)


_slot = credit_slot


def credit_decide(state: CreditState, keys: jax.Array, is_write: jax.Array,
                  table_size: int) -> tuple[CreditState, jax.Array]:
    """Algorithm 1 lines 2-6 for a whole batch: returns (state', pessimistic).

    Batched semantics: every write to a hot key in this window consumes one
    credit (each would have consumed one on its own CN; the engine's table is
    per data-shard, so we decrement by the number of writers, floored at 0).
    """
    slots = _slot(keys, table_size)
    has_credit = state.credit[slots] > 0
    pess = has_credit & is_write
    dec = jax.ops.segment_sum(pess.astype(jnp.int32), slots, num_segments=table_size)
    credit = jnp.maximum(state.credit - dec, 0)
    return dataclasses.replace(state, credit=credit), pess


def credit_feedback(state: CreditState, keys: jax.Array, table_size: int,
                    pess: jax.Array, wc_batch: jax.Array,
                    opt: jax.Array, n_retry: jax.Array,
                    initial_credit: int = 36, hotness_threshold: int = 2,
                    aimd_factor: int = 2) -> CreditState:
    """Algorithm 1 lines 13-16 (pessimistic) and 20-22 (optimistic), batched.

    ``wc_batch``: per-op combined batch size (pessimistic ops only);
    ``n_retry``: per-op CAS retry count (optimistic ops only).
    """
    slots = _slot(keys, table_size)
    tsz = table_size
    # --- pessimistic feedback (applied once per wait queue => use the executor) ---
    grow = pess & (wc_batch > 1)
    shrink = pess & (wc_batch <= 1)
    inc = jax.ops.segment_max(jnp.where(grow, 2, 0), slots, num_segments=tsz)
    do_shrink = jax.ops.segment_max(shrink.astype(jnp.int32), slots, num_segments=tsz)
    do_grow = jax.ops.segment_max(grow.astype(jnp.int32), slots, num_segments=tsz)
    credit = state.credit + jnp.where(do_grow > 0, inc, 0)
    credit = jnp.where((do_shrink > 0) & (do_grow == 0), credit // aimd_factor, credit)
    # --- optimistic feedback: two consecutive attempts with >= threshold retries ---
    hot_now = opt & (n_retry >= hotness_threshold)
    prev_hot = state.retry_record[slots] >= hotness_threshold
    promote = jax.ops.segment_max((hot_now & prev_hot).astype(jnp.int32), slots,
                                  num_segments=tsz)
    credit = credit + promote * initial_credit
    # retryRecord <- nRetry of the latest optimistic attempt on this slot
    latest = jax.ops.segment_max(jnp.where(opt, n_retry, -1), slots, num_segments=tsz)
    retry_record = jnp.where(latest >= 0, latest, state.retry_record)
    return CreditState(credit=credit, retry_record=retry_record)
