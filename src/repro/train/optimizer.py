"""Sharded AdamW (bf16 params, f32 moments) — moments inherit the params'
logical sharding, so FSDP shards optimizer state for free (ZeRO-style).

DESIGN.md §3.2 (logical-axis rules): AdamW whose moments inherit param
sharding — FSDP-sharded state for free.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["OptState", "adamw_init", "adamw_update", "clip_by_global_norm"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class OptState:
    m: Any
    v: Any
    step: jax.Array


def adamw_init(params) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(m=jax.tree.map(zeros, params),
                    v=jax.tree.map(zeros, params),
                    step=jnp.zeros((), jnp.int32))


def clip_by_global_norm(grads, max_norm: float):
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), gn


def adamw_update(params, grads, opt: OptState, lr=3e-4, b1=0.9, b2=0.95,
                 eps=1e-8, wd=0.1, max_norm=1.0):
    grads, gnorm = clip_by_global_norm(grads, max_norm)
    step = opt.step + 1
    t = step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * g32
        v2 = b2 * v + (1 - b2) * jnp.square(g32)
        mhat = m2 / (1 - b1 ** t)
        vhat = v2 / (1 - b2 ** t)
        upd = mhat / (jnp.sqrt(vhat) + eps) + wd * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * upd).astype(p.dtype), m2, v2

    out = jax.tree.map(upd, params, grads, opt.m, opt.v)
    params2 = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    m2 = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    v2 = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return params2, OptState(m=m2, v=v2, step=step), gnorm
