"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel ships as <name>/<name>.py (pl.pallas_call + BlockSpec VMEM
tiling), ops.py (jit'd wrapper + shape checks + interpret switch) and
ref.py (pure-jnp oracle); tests sweep shapes/dtypes with interpret=True.

* flash_attention — blockwise-softmax GQA attention (train/prefill)
* paged_attention — block-table-indirected decode attention over the
  CIDER-managed page pool (scalar-prefetch grid)
* wc_combine      — the paper's global write-combining sweep (detect +
  rank wait queues over a sorted key run in one VMEM pass)
"""
