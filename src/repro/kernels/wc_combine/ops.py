"""Jit'd wrapper for wc_combine.

DESIGN.md §2.1 (the combine primitive): public jit wrapper for the
wc_combine kernel.  Non-block-multiple N is padded with the +inf
invalid-key sentinel and the tail masked off (DESIGN.md §10.1), so odd
batch sizes (elastic-membership runs shrink B) dispatch instead of
crashing.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.wc_combine.ref import wc_combine_ref
from repro.kernels.wc_combine.wc_combine import wc_combine

__all__ = ["wc_combine_op", "wc_combine_ref"]

_BIG = 2**31 - 1   # python int: this module may first be imported inside a jit trace


def wc_combine_op(keys_sorted, block=1024, interpret=None):
    if keys_sorted.dtype != jnp.int32:
        keys_sorted = keys_sorted.astype(jnp.int32)
    n = keys_sorted.shape[0]
    block = min(block, n)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    pad = (-n) % block
    if pad:
        # Pad with the +inf sentinel: sorted order is preserved (no real key
        # exceeds it) and the padding either opens its own run or extends a
        # trailing sentinel run — either way the real prefix's is_first/rank
        # are untouched.  Only is_last[n-1] can be swallowed (when padding
        # extends the final run), so restore it after slicing.
        keys_sorted = jnp.concatenate(
            [keys_sorted, jnp.full((pad,), _BIG, jnp.int32)])
    first, last, rank = wc_combine(keys_sorted, block=block,
                                   interpret=interpret)
    if pad:
        first, last, rank = first[:n], last[:n], rank[:n]
        last = last.at[n - 1].set(True)
    return first, last, rank
