"""Jit'd wrapper for wc_combine.

DESIGN.md §2.1 (the combine primitive): public jit wrapper for the
wc_combine kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.wc_combine.ref import wc_combine_ref
from repro.kernels.wc_combine.wc_combine import wc_combine

__all__ = ["wc_combine_op", "wc_combine_ref"]


def wc_combine_op(keys_sorted, block=1024, interpret=None):
    if keys_sorted.dtype != jnp.int32:
        keys_sorted = keys_sorted.astype(jnp.int32)
    n = keys_sorted.shape[0]
    block = min(block, n)
    if n % block:
        raise ValueError(f"N={n} not divisible by block={block}")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return wc_combine(keys_sorted, block=block, interpret=interpret)
