"""Global write-combining Pallas kernel: one VMEM pass over a SORTED key run
emitting, per element, (is_first, is_last, rank) — the materialized wait
queues of §4.2 (detect + combine in one sweep).

Cross-block runs are handled by a sequential grid with a carry scratch
(previous block's last key + its accumulated run length): TPU grid execution
is ordered, so block i reads the carry block i-1 wrote.

Used by: the dataplane engine (combine path), the MoE dispatch
(rank-within-expert), and the embedding-gradient combiner.

DESIGN.md §2.1 (the combine primitive): Pallas twin of
core/combine.plan_combine — identical contract, fused VMEM pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(keys_ref, first_ref, last_ref, rank_ref, carry_ref, *,
            block: int, n_blocks: int):
    bi = pl.program_id(0)

    @pl.when(bi == 0)
    def _init():
        carry_ref[0] = jnp.int32(-2**31 + 1)   # "no previous key"
        carry_ref[1] = jnp.int32(0)            # run length so far

    k = keys_ref[...]                          # (block,)
    prev_key = carry_ref[0]
    prev_len = carry_ref[1]
    idx = jax.lax.broadcasted_iota(jnp.int32, (block, 1), 0)[:, 0]
    kprev = jnp.where(idx == 0, prev_key, jnp.roll(k, 1))
    first = k != kprev
    # rank within run: idx - start_of_run (+ carry for a continued first run)
    start = jax.lax.cummax(jnp.where(first, idx, jnp.int32(-2**31 + 1)))
    in_carry_run = start == (-2**31 + 1)       # run continues from prev block
    rank = jnp.where(in_carry_run, idx + prev_len, idx - start)
    # is_last: next element differs (last block: trailing element is last)
    knext = jnp.where(idx == block - 1, jnp.int32(-2**31 + 2), jnp.roll(k, -1))
    last = k != knext
    first_ref[...] = first
    last_ref[...] = last
    rank_ref[...] = rank
    # carry out: last key + length of its (possibly continued) run
    tail_rank = rank[block - 1] + 1
    carry_ref[0] = k[block - 1]
    carry_ref[1] = tail_rank


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def wc_combine(keys_sorted, *, block=1024, interpret=False):
    """keys_sorted: (N,) int32 ascending.  Returns (is_first, is_last, rank).
    The final element of block i and first of block i+1 are stitched via the
    sequential carry, so ``is_last``/``rank`` are globally correct except
    that is_last at a block boundary is resolved by the NEXT block's
    is_first — callers get exact semantics via the returned pair:
    element i is a true run tail iff is_last[i] and (i == N-1 or
    is_first[i+1]); the wrapper fixes this up (cheap elementwise pass)."""
    n = keys_sorted.shape[0]
    block = min(block, n)
    n_blocks = n // block
    kernel = functools.partial(_kernel, block=block, n_blocks=n_blocks)
    first, last, rank = pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=[pl.BlockSpec((block,), lambda i: (i,)),
                   pl.BlockSpec((block,), lambda i: (i,)),
                   pl.BlockSpec((block,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.bool_),
                   jax.ShapeDtypeStruct((n,), jnp.bool_),
                   jax.ShapeDtypeStruct((n,), jnp.int32)],
        scratch_shapes=[pltpu.SMEM((2,), jnp.int32)],
        interpret=interpret,
    )(keys_sorted)
    # stitch block boundaries: i is a tail iff the next element starts a run
    nxt_first = jnp.concatenate([first[1:], jnp.ones((1,), bool)])
    return first, last & nxt_first, rank
