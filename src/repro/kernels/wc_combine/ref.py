"""Pure-jnp oracle for wc_combine (same contract as core.combine).

DESIGN.md §2.1 (the combine primitive): pure-jnp oracle sharing
core/combine's contract.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wc_combine_ref(keys_sorted):
    n = keys_sorted.shape[0]
    k = keys_sorted
    first = jnp.concatenate([jnp.ones((1,), bool), k[1:] != k[:-1]])
    last = jnp.concatenate([k[1:] != k[:-1], jnp.ones((1,), bool)])
    idx = jnp.arange(n, dtype=jnp.int32)
    start = jax.lax.cummax(jnp.where(first, idx, 0))
    rank = idx - start
    return first, last, rank
