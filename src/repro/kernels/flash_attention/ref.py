"""Pure-jnp oracle for the flash_attention kernel.

DESIGN.md §1 (kernels layer): the pure-jnp oracle the kernel is equivalence-
tested against.
"""
from __future__ import annotations

import jax.numpy as jnp
import jax

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal=True, window=0):
    """q: (B, H, S, D); k/v: (B, KH, S, D) -> (B, H, S, D)."""
    b, h, s, d = q.shape
    kh = k.shape[1]
    if kh != h:
        k = jnp.repeat(k, h // kh, axis=1)
        v = jnp.repeat(v, h // kh, axis=1)
    sc = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                    k.astype(jnp.float32)) * d ** -0.5
    qp, kp = jnp.arange(s), jnp.arange(s)
    valid = jnp.ones((s, s), bool)
    if causal:
        valid &= kp[None, :] <= qp[:, None]
    if window:
        valid &= kp[None, :] > qp[:, None] - window
    sc = jnp.where(valid[None, None], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
