"""Jit'd public wrapper for the flash_attention kernel (shape checks +
interpret switch; interpret=True is the validated CPU path, False targets
real TPU).

DESIGN.md §1 (kernels layer): public jit wrapper — shape checks + interpret
switch for the CPU-validated path.
"""
from __future__ import annotations

import jax

from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref

__all__ = ["flash_attention_op", "flash_attention_ref"]


def flash_attention_op(q, k, v, *, causal=True, window=0, block_q=128,
                       block_k=128, interpret=None):
    b, h, s, d = q.shape
    if k.shape != v.shape or k.shape[0] != b or k.shape[2] != s:
        raise ValueError(f"kv shape mismatch: {k.shape} vs q {q.shape}")
    if h % k.shape[1]:
        raise ValueError(f"q heads {h} not a multiple of kv heads {k.shape[1]}")
    if s % min(block_q, s) or s % min(block_k, s):
        raise ValueError(f"seq {s} not divisible by blocks {block_q}/{block_k}")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return flash_attention(q, k, v, causal=causal, window=window,
                           block_q=block_q, block_k=block_k,
                           interpret=interpret)
