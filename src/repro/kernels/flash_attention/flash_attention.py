"""Flash attention Pallas TPU kernel (blockwise softmax, GQA-aware).

Grid: (batch, q_heads, q_blocks, kv_blocks) — kv innermost so the running
(max, sum, acc) scratch carries across kv steps in VMEM.  GQA: the kv
BlockSpec index map folds the query head onto its kv head (h // group), so
kv heads are never materialized per-q-head in HBM.

Block shapes are (block_q, head_dim) / (block_k, head_dim) VMEM tiles;
head_dim is expected 128-aligned (pad if not) and block_q/block_k multiples
of the 8x128 VPU lanes.

DESIGN.md §1 (kernels layer): fused blockwise-softmax attention behind
models/attention; exact against ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            causal: bool, window: int, block_q: int, block_k: int,
            n_k: int, scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)
    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    valid = jnp.ones((block_q, block_k), bool)
    if causal:
        valid &= k_pos <= q_pos
    if window:
        valid &= k_pos > q_pos - window
    s = jnp.where(valid, s, NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(-1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    m_ref[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, block_q=128,
                    block_k=128, interpret=False):
    """q: (B, H, S, D); k/v: (B, KH, S, D).  Returns (B, H, S, D)."""
    b, h, s, d = q.shape
    kh = k.shape[1]
    g = h // kh
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    n_q, n_k = s // block_q, s // block_k
    grid = (b, h, n_q, n_k)
    kernel = functools.partial(
        _kernel, causal=causal, window=window, block_q=block_q,
        block_k=block_k, n_k=n_k, scale=d ** -0.5)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bi, hi, qi, ki: (bi, hi // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bi, hi, qi, ki: (bi, hi // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
