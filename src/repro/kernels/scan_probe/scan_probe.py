"""Fused SCAN reader-probe Pallas kernel: one VMEM pass over lanes SORTED
by (key, pos) emitting, per lane, the existence bit observed just before it
(``e_before``) and the count of writer lanes strictly ahead in its key run
(``waits``) — the engine's step-5c probe resolution and ``reader_waits``
rank in a single sweep (DESIGN.md §10.3), replacing two full sorts.

Cross-block runs use the same sequential-grid carry idiom as wc_combine
(DESIGN.md §2.1): TPU grid execution is ordered, so block i reads the SMEM
carry block i-1 wrote.  The carry holds (previous block's last key, the
last setcode seen in its still-open run [-1 if none], the writer count so
far in that run).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -2**31 + 1              # python int: jnp constants would be captured


def _kernel(keys_ref, set_ref, writer_ref, einit_ref,
            eb_ref, waits_ref, carry_ref, *, block: int):
    bi = pl.program_id(0)

    @pl.when(bi == 0)
    def _init():
        carry_ref[0] = jnp.int32(_NEG)   # "no previous key"
        carry_ref[1] = jnp.int32(-1)     # open run has no setter yet
        carry_ref[2] = jnp.int32(0)      # writers so far in open run

    k = keys_ref[...]                    # (block,) int32
    sc = set_ref[...]                    # (block,) int32 in {-1, 0, 1}
    w = writer_ref[...]                  # (block,) int32 in {0, 1}
    ei = einit_ref[...]                  # (block,) int32 in {0, 1}
    prev_key = carry_ref[0]
    carry_set = carry_ref[1]
    carry_w = carry_ref[2]
    idx = jax.lax.broadcasted_iota(jnp.int32, (block, 1), 0)[:, 0]
    kprev = jnp.where(idx == 0, prev_key, jnp.roll(k, 1))
    first = k != kprev
    start = jax.lax.cummax(jnp.where(first, idx, jnp.int32(_NEG)))
    in_carry = start == _NEG             # run continues from previous block
    start_c = jnp.where(in_carry, 0, start)
    # last setter strictly before me, within this block and run
    enc = jnp.where(sc >= 0, 2 * idx + sc, -1)
    g = jax.lax.cummax(enc)
    g_excl = jnp.where(idx == 0, jnp.int32(-1), jnp.roll(g, 1))
    has = (g_excl >= 0) & ((g_excl >> 1) >= start_c)
    e_b = jnp.where(has, (g_excl & 1) == 1,
                    jnp.where(in_carry & (carry_set >= 0),
                              carry_set == 1, ei == 1))
    # writers strictly ahead of me in my run
    cw = jnp.cumsum(w)
    cex = cw - w
    base = jax.lax.cummax(jnp.where(first, cex, 0))
    waits = cex - jnp.where(in_carry, 0, base) + jnp.where(in_carry, carry_w, 0)
    eb_ref[...] = e_b
    waits_ref[...] = waits
    # carry out: tail lane's key + its run's last setcode and writer count
    t = block - 1
    g_inc = g[t]
    has_t = (g_inc >= 0) & ((g_inc >> 1) >= start_c[t])
    carry_ref[0] = k[t]
    carry_ref[1] = jnp.where(has_t, g_inc & 1,
                             jnp.where(in_carry[t], carry_set, jnp.int32(-1)))
    carry_ref[2] = waits[t] + w[t]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def scan_probe(keys_sorted, setcode, writer, e_init, *,
               block=1024, interpret=False):
    """All inputs (N,) int32, N a multiple of ``block``, sorted by (key,
    pos).  ``writer``/``e_init`` are 0/1 ints (bool loads are avoided in
    the kernel body).  Returns ``(e_before bool, waits int32)``."""
    n = keys_sorted.shape[0]
    block = min(block, n)
    n_blocks = n // block
    kernel = functools.partial(_kernel, block=block)
    spec = pl.BlockSpec((block,), lambda i: (i,))
    e_before, waits = pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[spec, spec, spec, spec],
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.bool_),
                   jax.ShapeDtypeStruct((n,), jnp.int32)],
        scratch_shapes=[pltpu.SMEM((3,), jnp.int32)],
        interpret=interpret,
    )(keys_sorted, setcode, writer, e_init)
    return e_before, waits
