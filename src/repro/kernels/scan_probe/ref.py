"""Pure-jnp oracle for the fused SCAN reader-probe pass.

DESIGN.md §10.3: one prefix sweep over lanes sorted by (key, pos) yields,
per lane, the existence bit observed *just before* it linearizes and the
number of lock-holding writer lanes strictly ahead of it in its key run —
the two quantities the engine's SCAN step needs, without a second sort.

Contract (all arrays length N, sorted by (key, pos); invalid lanes carry
the +inf key sentinel and setcode -1):

* ``keys_sorted`` int32 — run grouping key;
* ``setcode``     int32 ∈ {-1: keep, 0: set-absent, 1: set-present} — the
  lane's existence transfer (INSERT→1, successful DELETE→0, else -1);
* ``writer``      bool  — lane holds the slot lock (counts toward waits);
* ``e_init``      bool  — slot existence at window start (read when no
  setter precedes the lane in its run).

Returns ``(e_before, waits)``: bool/int32, both length N.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["scan_probe_ref"]


def scan_probe_ref(keys_sorted, setcode, writer, e_init):
    n = keys_sorted.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    is_first = jnp.concatenate(
        [jnp.ones((1,), bool), keys_sorted[1:] != keys_sorted[:-1]])
    start = jax.lax.cummax(jnp.where(is_first, idx, 0))
    # last setter strictly before me, in-run: encode (2*idx + bit) and take
    # a running max — the decode survives iff the argmax sits in my run.
    enc = jnp.where(setcode >= 0, 2 * idx + setcode, -1)
    g = jax.lax.cummax(enc)
    g_excl = jnp.concatenate([jnp.full((1,), -1, jnp.int32), g[:-1]])
    has = (g_excl >= 0) & ((g_excl >> 1) >= start)
    e_before = jnp.where(has, (g_excl & 1) == 1, e_init)
    # writers strictly ahead of me in my run
    w_i = writer.astype(jnp.int32)
    cex = jnp.cumsum(w_i) - w_i
    base = jax.lax.cummax(jnp.where(is_first, cex, 0))
    waits = cex - base
    return e_before, waits
