"""Jit'd wrapper for scan_probe.

DESIGN.md §10.3 (fused SCAN reader-probe pass): public wrapper with the
same padded-tail handling as wc_combine — non-block-multiple N is padded
with (+inf key, setcode -1, no writer, absent) lanes, which open or extend
a trailing sentinel run *after* every real lane; both outputs are prefix
sweeps, so slicing back to N needs no fix-up.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.scan_probe.ref import scan_probe_ref
from repro.kernels.scan_probe.scan_probe import scan_probe

__all__ = ["scan_probe_op", "scan_probe_ref"]

_BIG = 2**31 - 1   # python int: this module may first be imported inside a jit trace


def scan_probe_op(keys_sorted, setcode, writer, e_init,
                  block=1024, interpret=None):
    if keys_sorted.dtype != jnp.int32:
        keys_sorted = keys_sorted.astype(jnp.int32)
    n = keys_sorted.shape[0]
    block = min(block, n)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    pad = (-n) % block
    setcode = setcode.astype(jnp.int32)
    writer_i = writer.astype(jnp.int32)
    einit_i = e_init.astype(jnp.int32)
    if pad:
        zi = jnp.zeros((pad,), jnp.int32)
        keys_sorted = jnp.concatenate(
            [keys_sorted, jnp.full((pad,), _BIG, jnp.int32)])
        setcode = jnp.concatenate([setcode, zi - 1])
        writer_i = jnp.concatenate([writer_i, zi])
        einit_i = jnp.concatenate([einit_i, zi])
    e_before, waits = scan_probe(keys_sorted, setcode, writer_i, einit_i,
                                 block=block, interpret=interpret)
    if pad:
        e_before, waits = e_before[:n], waits[:n]
    return e_before, waits
