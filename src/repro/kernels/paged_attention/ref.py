"""Pure-jnp oracle for paged_attention: densify the pages, then softmax.

DESIGN.md §1 (kernels layer): densify-then-softmax oracle the paged kernel
is tested against.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def paged_attention_ref(q, k_pages, v_pages, block_table, lengths):
    b, h, d = q.shape
    npool, page, kh, _ = k_pages.shape
    np_ = block_table.shape[1]
    g = h // kh
    # densify: (B, NP*page, KH, D)
    kd = k_pages[block_table].reshape(b, np_ * page, kh, d)
    vd = v_pages[block_table].reshape(b, np_ * page, kh, d)
    qg = q.reshape(b, kh, g, d).astype(jnp.float32) * d ** -0.5
    sc = jnp.einsum("bkgd,bskd->bkgs", qg, kd.astype(jnp.float32))
    pos = jnp.arange(np_ * page)
    sc = jnp.where(pos[None, None, None, :] < lengths[:, None, None, None],
                   sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, vd.astype(jnp.float32))
    return out.reshape(b, h, d).astype(q.dtype)
