"""Jit'd wrapper for paged_attention (shape checks + interpret switch).

DESIGN.md §1 (kernels layer): public jit wrapper — shape checks + interpret
switch.
"""
from __future__ import annotations

import jax

from repro.kernels.paged_attention.paged_attention import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_ref

__all__ = ["paged_attention_op", "paged_attention_ref"]


def paged_attention_op(q, k_pages, v_pages, block_table, lengths,
                       interpret=None):
    b, h, d = q.shape
    if k_pages.shape != v_pages.shape:
        raise ValueError("k/v page pools differ")
    if h % k_pages.shape[2]:
        raise ValueError("q heads not a multiple of kv heads")
    if block_table.shape[0] != b or lengths.shape != (b,):
        raise ValueError("block_table/lengths batch mismatch")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return paged_attention(q, k_pages, v_pages, block_table, lengths,
                           interpret=interpret)
