"""Paged decode-attention Pallas kernel.

One new token attends over a block-table-indirected paged KV cache — the
CIDER-managed page store (DESIGN.md §2.1): pages live in a global pool
(HBM); each sequence's ``block_table`` row lists its pages in order.

Grid: (batch, kv_heads, n_pages); the page blocks of k/v are gathered via a
``PrefetchScalarGridSpec`` index map reading the block table — the kernel
never sees a dense (B, Smax) cache.  Running (m, l, acc) scratch carries
across the page dimension; pages at or beyond ``ceil(length/page)`` are
masked out entirely.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
            acc_ref, m_ref, l_ref, *, page: int, n_pages: int, g: int,
            scale: float):
    bi = pl.program_id(0)
    pi = pl.program_id(2)

    @pl.when(pi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = len_ref[bi]
    q = q_ref[0, 0].astype(jnp.float32) * scale          # (g, d)
    k = k_ref[0, 0].astype(jnp.float32)                  # (page, d)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (g, page)
    pos = pi * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
    s = jnp.where(pos < length, s, NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(-1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    m_ref[...] = m_new

    @pl.when(pi == n_pages - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q, k_pages, v_pages, block_table, lengths, *,
                    interpret=False):
    """q: (B, H, D); k/v_pages: (NPOOL, page, KH, D); block_table: (B, NP)
    i32 page ids; lengths: (B,) i32.  Returns (B, H, D)."""
    b, h, d = q.shape
    npool, page, kh, _ = k_pages.shape
    np_ = block_table.shape[1]
    g = h // kh
    qg = q.reshape(b, kh, g, d)
    kp = k_pages.transpose(0, 2, 1, 3)                   # (NPOOL, KH, page, D)
    vp = v_pages.transpose(0, 2, 1, 3)
    kernel = functools.partial(_kernel, page=page, n_pages=np_, g=g,
                               scale=d ** -0.5)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,                       # block_table, lengths
            grid=(b, kh, np_),
            in_specs=[
                pl.BlockSpec((1, 1, g, d),
                             lambda bi, hi, pi, bt, ln: (bi, hi, 0, 0)),
                pl.BlockSpec((1, 1, page, d),
                             lambda bi, hi, pi, bt, ln: (bt[bi, pi], hi, 0, 0)),
                pl.BlockSpec((1, 1, page, d),
                             lambda bi, hi, pi, bt, ln: (bt[bi, pi], hi, 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, g, d),
                                   lambda bi, hi, pi, bt, ln: (bi, hi, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((g, d), jnp.float32),
                pltpu.VMEM((g,), jnp.float32),
                pltpu.VMEM((g,), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, kh, g, d), q.dtype),
        interpret=interpret,
    )(block_table, lengths, qg, kp, vp)
    return out.reshape(b, h, d)
