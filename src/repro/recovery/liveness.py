"""Liveness schedules — the control plane of the engine path's failure model.

A :class:`LivenessSchedule` is a ``(W, n_cns)`` boolean matrix: row ``w``
masks the compute nodes alive through synchronization window ``w``.  It is
the single source of truth the whole recovery stack derives from:

* ``runner.make_stream(..., alive=sched.alive)`` threads it through the
  fused scan, where the engine drops dead CNs' ops at the window boundary
  and strands their in-flight locks (``engine.apply_batch`` step 5b);
* ``sched.drop_mask(...)`` reproduces the per-op validity the engine
  applied, for host-side metrics (``runner.modeled_latency`` masking);
* ``sched.died()`` exposes the crash edges (alive -> dead transitions) that
  scenario generators and tests reason about.

Builders cover the membership patterns the recovery scenarios need:
``crash`` (CNs die at a window and stay dead), ``rolling`` (staggered
down-for-k-windows restarts), and ``elastic`` (arbitrary join/leave event
lists).  Rejoin needs no special handling anywhere downstream: a returning
CN simply starts issuing ops again (the store and the replicated credit
table were never CN-local state).

Memory-node liveness (replication, DESIGN.md §13): :class:`MNLiveness` is
the same idea on the *memory* side — a ``(W, n_replicas)`` mask over the
replica MNs a SNAPSHOT-replicated store writes to.  Unlike CNs, MN replicas
are fail-stop with no rejoin (a returning replica would need an
anti-entropy resync the cost model does not bill), and at least one replica
must survive every window; the schedule's ``segments()`` are what
``recovery.orchestrator.run_recovery_replicated`` splits the stream at,
re-running each segment at the surviving replica count.

DESIGN.md §8.1 (the liveness plane): (W, n_cns) alive-mask schedules with
crash/rolling/elastic builders.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

__all__ = ["LivenessSchedule", "always_alive", "crash", "rolling", "elastic",
           "MNLiveness", "mn_always_alive", "mn_crash"]


@dataclasses.dataclass(frozen=True)
class LivenessSchedule:
    """Per-window CN liveness. ``alive[w, c]``: CN ``c`` lives through
    window ``w``."""
    alive: np.ndarray          # (W, n_cns) bool

    def __post_init__(self):
        a = np.asarray(self.alive, bool)
        if a.ndim != 2:
            raise ValueError(f"alive must be (W, n_cns), got {a.shape}")
        object.__setattr__(self, "alive", a)

    @property
    def windows(self) -> int:
        return self.alive.shape[0]

    @property
    def n_cns(self) -> int:
        return self.alive.shape[1]

    def died(self) -> np.ndarray:
        """(W, n_cns) crash edges: CN alive at window start, dead through the
        window.  Row 0 is all-False by convention — nothing was in flight
        before the stream began (``runner._prev_alive``)."""
        prev = np.vstack([self.alive[:1], self.alive[:-1]])
        return prev & ~self.alive

    def n_alive(self) -> np.ndarray:
        return self.alive.sum(axis=1)

    def cn_of(self, n_ops: int, lanes_per_cn: int | None = None) -> np.ndarray:
        """(B,) CN id per batch lane — the exact ``OpBatch.make`` assignment."""
        pos = np.arange(n_ops)
        if lanes_per_cn is None:
            lanes_per_cn = max(n_ops // max(self.n_cns, 1), 1)
        return (pos // lanes_per_cn) % max(self.n_cns, 1)

    def drop_mask(self, n_ops: int, lanes_per_cn: int | None = None
                  ) -> np.ndarray:
        """(W, B) per-op liveness: True where the issuing CN is alive — the
        mask the engine AND-ed into ``valid`` (dead lanes never complete)."""
        return self.alive[:, self.cn_of(n_ops, lanes_per_cn)]

    def first_crash_window(self) -> int | None:
        """First window with a crash edge (None if the schedule has none)."""
        rows = np.flatnonzero(self.died().any(axis=1))
        return int(rows[0]) if rows.size else None


@dataclasses.dataclass(frozen=True)
class MNLiveness:
    """Per-window *memory-node replica* liveness (DESIGN.md §13).

    ``alive[w, r]``: replica MN ``r`` serves window ``w``.  Fail-stop with
    no rejoin — a dead replica stays dead (rejoining would require an
    anti-entropy resync the cost model does not bill) — and at least one
    replica survives every window, both enforced at construction.  The CN
    plane (:class:`LivenessSchedule`) rides the stream itself; this plane
    rides the orchestrator, because a replica death changes the *engine
    config* (``EngineConfig.n_replicas``) for every window after it.
    """
    alive: np.ndarray          # (W, n_replicas) bool

    def __post_init__(self):
        a = np.asarray(self.alive, bool)
        if a.ndim != 2:
            raise ValueError(f"alive must be (W, n_replicas), got {a.shape}")
        if not a.any(axis=1).all():
            raise ValueError("every window needs >= 1 surviving replica")
        if (~a[:-1] & a[1:]).any():
            raise ValueError("MN replicas are fail-stop: no rejoin")
        object.__setattr__(self, "alive", a)

    @property
    def windows(self) -> int:
        return self.alive.shape[0]

    @property
    def n_replicas(self) -> int:
        return self.alive.shape[1]

    def died(self) -> np.ndarray:
        """(W, n_replicas) crash edges; row 0 all-False by convention."""
        prev = np.vstack([self.alive[:1], self.alive[:-1]])
        return prev & ~self.alive

    def n_alive(self) -> np.ndarray:
        return self.alive.sum(axis=1)

    def survivors(self, window: int) -> tuple[int, ...]:
        """Replica ids serving ``window``."""
        return tuple(np.flatnonzero(self.alive[window]).tolist())

    def first_crash_window(self) -> int | None:
        rows = np.flatnonzero(self.died().any(axis=1))
        return int(rows[0]) if rows.size else None

    def segments(self) -> list[tuple[int, int, tuple[int, ...]]]:
        """Constant-membership runs ``(lo, hi, survivors)`` covering
        ``[0, W)`` — the split points ``run_recovery_replicated`` re-runs
        the stream at, one ``EngineConfig.n_replicas`` per segment."""
        out, lo = [], 0
        for w in range(1, self.windows):
            if self.died()[w].any():
                out.append((lo, w, self.survivors(lo)))
                lo = w
        out.append((lo, self.windows, self.survivors(lo)))
        return out


def mn_always_alive(windows: int, n_replicas: int) -> MNLiveness:
    return MNLiveness(np.ones((windows, n_replicas), bool))


def mn_crash(windows: int, n_replicas: int, dead_replicas: Sequence[int],
             at_window: int) -> MNLiveness:
    """``dead_replicas`` fail-stop at ``at_window`` and never return."""
    alive = np.ones((windows, n_replicas), bool)
    alive[at_window:, list(dead_replicas)] = False
    return MNLiveness(alive)


def always_alive(windows: int, n_cns: int) -> LivenessSchedule:
    return LivenessSchedule(np.ones((windows, n_cns), bool))


def crash(windows: int, n_cns: int, dead_cns: Sequence[int],
          at_window: int) -> LivenessSchedule:
    """``dead_cns`` crash at ``at_window`` and never return (fail-stop)."""
    alive = np.ones((windows, n_cns), bool)
    alive[at_window:, list(dead_cns)] = False
    return LivenessSchedule(alive)


def rolling(windows: int, n_cns: int, down_windows: int = 2,
            start: int = 1, stagger: int | None = None,
            group: int = 1) -> LivenessSchedule:
    """Rolling restart: CN groups of ``group`` go down for ``down_windows``
    windows each, one group every ``stagger`` windows (default: back to back),
    starting at ``start`` — the whole fleet cycles through a restart."""
    if stagger is None:
        stagger = down_windows
    alive = np.ones((windows, n_cns), bool)
    for g in range((n_cns + group - 1) // group):
        lo = start + g * stagger
        cns = range(g * group, min((g + 1) * group, n_cns))
        alive[lo:lo + down_windows, list(cns)] = False
    return LivenessSchedule(alive)


def elastic(windows: int, n_cns: int,
            events: Sequence[tuple[int, Sequence[int], bool]],
            initial_alive: Sequence[int] | None = None) -> LivenessSchedule:
    """Membership from an event list: each ``(window, cns, alive)`` flips
    the given CNs from that window on.  ``initial_alive`` (default: all)
    sets the starting membership — scale-up scenarios begin with a subset."""
    alive = np.zeros((windows, n_cns), bool)
    cur = np.zeros((n_cns,), bool)
    if initial_alive is None:
        cur[:] = True
    else:
        cur[list(initial_alive)] = True
    evs = sorted(events, key=lambda e: e[0])
    i = 0
    for w in range(windows):
        while i < len(evs) and evs[i][0] == w:
            cur[list(evs[i][1])] = evs[i][2]
            i += 1
        alive[w] = cur
    if i < len(evs):
        raise ValueError(f"event at window {evs[i][0]} beyond {windows} windows")
    return LivenessSchedule(alive)
