"""Recovery orchestration: drive a window stream through CN crashes and
shard failovers, and summarize the recovery bill.

``run_recovery`` is the single-device path: one fused ``run_windows`` scan
with the liveness plane attached, returning the per-window I/O bill (the
``repair_cas`` / ``orphan_windows`` trajectories are what time-to-repair is
read from).

``run_recovery_sharded`` is the elastic path: the stream is split at each
:class:`FailoverEvent`, each segment runs under ``dist.store``'s sharded
scan on the current membership, and ``dist.store.failover_reown``
re-partitions the dead shards' slots onto the survivors between segments.
The previous segment's last alive row is threaded into the next segment
(``prev_alive``), so CN crashes at the failover boundary still strand locks.
The concatenated per-window results and bill are bit-equal to a
single-device ``run_recovery`` over the same stream — shard death never
changes the data-plane bill, it only adds the control-plane ``recovery_io``
(the assertion ``benchmarks/recovery.py`` and ``tests/test_recovery.py``
make).

DESIGN.md §8.3 (failover ownership rule): splits runs around FailoverEvents
and asserts the bit-equal recovery bill.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import runner
from repro.core.credits import CreditState
from repro.core.engine import Results, StoreState
from repro.core.runner import WindowStream
from repro.core.types import EngineConfig, IOMetrics
from repro.dist import store as dstore
from repro.launch.mesh import make_local_mesh

__all__ = ["FailoverEvent", "RecoveryRun", "run_recovery",
           "run_recovery_replicated", "run_recovery_sharded", "slice_stream",
           "time_to_repair"]


@dataclasses.dataclass(frozen=True)
class FailoverEvent:
    """At ``window``, the complement of ``survivors`` dies and its slot
    partition is re-owned: windows ``>= window`` run on ``len(survivors)``
    shards.  ``survivors`` are shard ids of the *preceding* topology."""
    window: int
    survivors: tuple[int, ...]


@dataclasses.dataclass
class RecoveryRun:
    """One orchestrated run: per-window results/bill plus the recovery
    control-plane costs."""
    results: Results       # (W, B) stacked
    io: IOMetrics          # per-window bill, leaves (W,)
    state: StoreState
    credits: CreditState
    valid: np.ndarray      # (W, B) post-drop validity (latency masking)
    n_shards: int          # final shard count (1 on the single-device path)
    recovery_io: list[dict]  # one dict per failover (dstore.failover_reown)

    def io_sum(self) -> IOMetrics:
        return jax.tree.map(lambda x: jnp.sum(x, axis=0), self.io)


def slice_stream(stream: WindowStream, lo: int, hi: int) -> WindowStream:
    """Windows ``[lo, hi)`` of a stream (every leaf's leading axis)."""
    return jax.tree.map(lambda x: x[lo:hi], stream)


def _post_drop_valid(stream: WindowStream) -> np.ndarray:
    alive = np.asarray(stream.alive)
    cn = np.asarray(stream.batch.cn)
    w = alive.shape[0]
    return np.asarray(stream.valid) & alive[np.arange(w)[:, None],
                                            np.clip(cn, 0, alive.shape[1] - 1)]


def run_recovery(cfg: EngineConfig, state: StoreState, credits: CreditState,
                 stream: WindowStream) -> RecoveryRun:
    """Single-device reference run (``state``/``credits`` are donated)."""
    state, credits, res, io = runner.run_windows(cfg, state, credits, stream,
                                                 io_per_window=True)
    return RecoveryRun(results=res, io=io, state=state, credits=credits,
                       valid=_post_drop_valid(stream), n_shards=1,
                       recovery_io=[])


def run_recovery_sharded(cfg: EngineConfig, n_shards: int, state: StoreState,
                         credits: CreditState, stream: WindowStream,
                         failovers: Sequence[FailoverEvent] = (),
                         ) -> RecoveryRun:
    """Sharded run with elastic membership (``state``/``credits`` donated).

    ``state`` must be an ``n_shards``-way store (``sharded_store_init`` /
    ``sharded_populate``); each failover's survivor count must divide
    ``cfg.n_slots``/``cfg.heap_slots`` (``dstore.shard_extents``).
    """
    w = stream.shape[0]
    evs = sorted(failovers, key=lambda e: e.window)
    if any(not 0 < e.window <= w for e in evs):
        raise ValueError(f"failover windows must lie in (0, {w}]")
    bounds = [0] + [e.window for e in evs] + [w]
    if len(set(bounds)) != len(bounds):
        raise ValueError("failover windows must be distinct and interior")
    ress, ios, recovery_io = [], [], []
    prev_alive = None
    for i, (lo, hi) in enumerate(zip(bounds[:-1], bounds[1:])):
        if i > 0:
            state, rio = dstore.failover_reown(cfg, n_shards, state,
                                               evs[i - 1].survivors)
            rio["window"] = evs[i - 1].window
            recovery_io.append(rio)
            n_shards = len(evs[i - 1].survivors)
            # the replicated credit table survives failover for free, but it
            # must shed the dead topology's device placement (like the store
            # planes failover_reown carries) before the survivors' mesh
            credits = jax.tree.map(dstore.host_rehome, credits)
            if prev_alive is not None:
                prev_alive = dstore.host_rehome(prev_alive)
        seg = slice_stream(stream, lo, hi)
        mesh = make_local_mesh(data=n_shards)
        state, credits, res, io = dstore.run_windows_sharded(
            cfg, mesh, state, credits, seg, io_per_window=True,
            prev_alive=prev_alive)
        prev_alive = seg.alive[-1]
        ress.append(res)
        ios.append(io)
    # segment outputs are committed to different meshes — concat on host
    cat = lambda *xs: np.concatenate([np.asarray(x) for x in xs],  # noqa: E731
                                     axis=0)
    return RecoveryRun(
        results=jax.tree.map(cat, *ress) if len(ress) > 1 else ress[0],
        io=jax.tree.map(cat, *ios) if len(ios) > 1 else ios[0],
        state=state, credits=credits, valid=_post_drop_valid(stream),
        n_shards=n_shards, recovery_io=recovery_io)


def run_recovery_replicated(cfg: EngineConfig, state: StoreState,
                            credits: CreditState, stream: WindowStream,
                            mn: "object") -> RecoveryRun:
    """Replicated-MN run with fail-stop replica deaths (DESIGN.md §13).

    ``mn`` is a :class:`repro.recovery.liveness.MNLiveness` whose
    ``n_replicas`` must equal ``cfg.n_replicas`` and whose ``windows`` must
    match the stream.  The stream is split at ``mn.segments()`` — the same
    segment-splitting machinery ``run_recovery_sharded`` uses for CN-side
    shard death — and each segment runs single-device at that segment's
    surviving replica count (``dataclasses.replace(cfg, n_replicas=...)``).
    Between segments ``dist.store.promote_replica`` promotes the lowest
    surviving replica and re-runs the §4.6 orphaned-lock repair against it,
    billing the sweep into ``recovery_io`` (control-plane, OUT of
    ``IOMetrics``).  The previous segment's last alive row threads through
    (``prev_alive``), so a CN crash at the MN-failover boundary still
    strands locks.

    Because promotion moves no data, the concatenated per-window results
    and data-plane bill are bit-equal to running the same segments directly
    through ``run_windows`` with the ``n_replicas`` swap and no promotion —
    the drop-mask reference ``benchmarks/replication.py`` and
    ``tests/test_replication.py`` assert against.
    """
    w = stream.shape[0]
    if mn.windows != w:
        raise ValueError(f"MNLiveness covers {mn.windows} windows, "
                         f"stream has {w}")
    if mn.n_replicas != cfg.n_replicas:
        raise ValueError(f"MNLiveness has {mn.n_replicas} replicas, "
                         f"cfg.n_replicas={cfg.n_replicas}")
    segs = mn.segments()
    ress, ios, recovery_io = [], [], []
    prev_alive = None
    prev_survivors = segs[0][2]
    for i, (lo, hi, survivors) in enumerate(segs):
        if i > 0:
            dead = tuple(sorted(set(prev_survivors) - set(survivors)))
            state, rio = dstore.promote_replica(cfg, state, survivors, dead)
            rio["window"] = lo
            recovery_io.append(rio)
        seg = slice_stream(stream, lo, hi)
        seg_cfg = dataclasses.replace(cfg, n_replicas=len(survivors))
        state, credits, res, io = runner.run_windows(
            seg_cfg, state, credits, seg, io_per_window=True,
            prev_alive=prev_alive)
        prev_alive = seg.alive[-1]
        prev_survivors = survivors
        ress.append(res)
        ios.append(io)
    cat = lambda *xs: np.concatenate([np.asarray(x) for x in xs],  # noqa: E731
                                     axis=0)
    return RecoveryRun(
        results=jax.tree.map(cat, *ress) if len(ress) > 1 else ress[0],
        io=jax.tree.map(cat, *ios) if len(ios) > 1 else ios[0],
        state=state, credits=credits, valid=_post_drop_valid(stream),
        n_shards=1, recovery_io=recovery_io)


def time_to_repair(io: IOMetrics, crash_window: int | None) -> dict:
    """Repair timeline out of a per-window bill.

    ``windows_to_repair``: windows from the first crash until the last
    repair activity (a break CAS fired, or an orphaned lock still
    outstanding at window end) — 1 means every strand was broken within the
    crash window itself.  ``stranded_final`` counts locks still orphaned at
    stream end: lazily-repaired slots nobody locked again (harmless to
    optimistic traffic — CIDER's case — but reported, not hidden).
    """
    rc = np.asarray(io.repair_cas)
    ow = np.asarray(io.orphan_windows)
    if crash_window is None:
        return {"windows_to_repair": 0, "repair_cas": int(rc.sum()),
                "orphan_slot_windows": int(ow.sum()), "stranded_final": 0}
    act = np.flatnonzero((rc > 0) | (ow > 0))
    act = act[act >= crash_window]
    last = int(act[-1]) if act.size else crash_window - 1
    return {
        "windows_to_repair": max(last - crash_window + 1, 0),
        "repair_cas": int(rc.sum()),
        "orphan_slot_windows": int(ow.sum()),
        "stranded_final": int(ow[-1]),
    }
