"""Crash recovery and elastic membership for the CIDER engine path (§4.6).

Three planes, one failure model (DESIGN.md §8):

* **liveness** (:mod:`repro.recovery.liveness`) — per-window CN alive
  masks, threaded through the fused runner so dead CNs' ops drop at the
  window boundary exactly as a real crash strands them;
* **lock repair** — lives in ``repro.core.engine`` (step 5b): orphaned
  pessimistic locks are detected by the next waiter via the §4.6 stale-
  epoch read and broken with a repair CAS, billed through the exact verb
  model (``IOMetrics.repair_cas``/``orphan_windows``,
  ``Results.orphan_wait``);
* **failover** — ``repro.dist.store.failover_reown`` re-owns dead shards'
  slot partitions onto survivors; :mod:`repro.recovery.orchestrator`
  splits a run around failover events and asserts nothing about the
  data-plane bill changes.
* **MN replication** (DESIGN.md §13) — :class:`MNLiveness` masks the
  *memory-node replicas* instead of the CNs; ``run_recovery_replicated``
  splits the stream at replica deaths, drops ``EngineConfig.n_replicas``
  to the survivor count per segment, and ``dist.store.promote_replica``
  re-arms the §4.6 repair against the promoted replica between segments.

Scenario generators live in :mod:`repro.workloads.recovery`; the committed
benchmarks are ``BENCH_recovery.json`` (``benchmarks/recovery.py``) and
``BENCH_replication.json`` (``benchmarks/replication.py``).
"""
from repro.recovery.liveness import (LivenessSchedule, MNLiveness,
                                     always_alive, crash, elastic,
                                     mn_always_alive, mn_crash, rolling)
from repro.recovery.orchestrator import (FailoverEvent, RecoveryRun,
                                         run_recovery,
                                         run_recovery_replicated,
                                         run_recovery_sharded, slice_stream,
                                         time_to_repair)

__all__ = [
    "LivenessSchedule", "always_alive", "crash", "rolling", "elastic",
    "MNLiveness", "mn_always_alive", "mn_crash",
    "FailoverEvent", "RecoveryRun", "run_recovery", "run_recovery_replicated",
    "run_recovery_sharded", "slice_stream", "time_to_repair",
]
