"""Crash recovery and elastic membership for the CIDER engine path (§4.6).

Three planes, one failure model (DESIGN.md §8):

* **liveness** (:mod:`repro.recovery.liveness`) — per-window CN alive
  masks, threaded through the fused runner so dead CNs' ops drop at the
  window boundary exactly as a real crash strands them;
* **lock repair** — lives in ``repro.core.engine`` (step 5b): orphaned
  pessimistic locks are detected by the next waiter via the §4.6 stale-
  epoch read and broken with a repair CAS, billed through the exact verb
  model (``IOMetrics.repair_cas``/``orphan_windows``,
  ``Results.orphan_wait``);
* **failover** — ``repro.dist.store.failover_reown`` re-owns dead shards'
  slot partitions onto survivors; :mod:`repro.recovery.orchestrator`
  splits a run around failover events and asserts nothing about the
  data-plane bill changes.

Scenario generators live in :mod:`repro.workloads.recovery`; the committed
benchmark is ``BENCH_recovery.json`` (``benchmarks/recovery.py``).
"""
from repro.recovery.liveness import (LivenessSchedule, always_alive, crash,
                                     elastic, rolling)
from repro.recovery.orchestrator import (FailoverEvent, RecoveryRun,
                                         run_recovery, run_recovery_sharded,
                                         slice_stream, time_to_repair)

__all__ = [
    "LivenessSchedule", "always_alive", "crash", "rolling", "elastic",
    "FailoverEvent", "RecoveryRun", "run_recovery", "run_recovery_sharded",
    "slice_stream", "time_to_repair",
]
