"""Serving driver: continuous batching + CIDER-managed prefix cache.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
        --requests 16 --max-new 8

DESIGN.md §1 (launch layer): serving driver wiring scheduler + pagetable +
models on the shared meshes.
"""
from __future__ import annotations

import argparse

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.common import unbox
from repro.models.model import Model
from repro.serving.scheduler import Request, Scheduler


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--shared-prefix", type=int, default=16,
                    help="tokens of shared system prompt (prefix-cache hits)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    model = Model(cfg)
    params = unbox(model.init(jax.random.key(0)))
    smax = args.prompt_len + args.max_new
    page = 16
    sched = Scheduler(n_slots=args.slots, n_pages=1024, page_size=page)

    rng = np.random.default_rng(0)
    shared = rng.integers(0, cfg.vocab, args.shared_prefix)
    for rid in range(args.requests):
        tail = rng.integers(0, cfg.vocab, args.prompt_len - args.shared_prefix)
        sched.submit(Request(rid=rid, tokens=np.concatenate([shared, tail]),
                             max_new=args.max_new))

    decode = jax.jit(model.decode_step)
    states = {}
    served = 0
    while sched.queue or sched.active():
        sched.step_admit()
        for slot, req in sched.active():
            if slot not in states or states[slot][0] is not req:
                # (re)prefill this slot — in production the prefix-cache hit
                # skips recomputing req.cached_blocks * page tokens
                st = model.init_decode_state(1, smax=smax)
                tok = jnp.asarray(req.tokens[None, :], jnp.int32)
                for t in range(req.tokens.shape[0]):
                    logits, st = decode(params, st, tok[:, t:t + 1],
                                        jnp.int32(t))
                states[slot] = (req, st, logits)
            req, st, logits = states[slot]
            nxt = int(jnp.argmax(logits[0, -1]))
            sched.complete_token(slot, nxt)
            if not req.done:
                logits, st = decode(params, st,
                                    jnp.asarray([[nxt]], jnp.int32),
                                    jnp.int32(req.pos - 1))
                states[slot] = (req, st, logits)
            else:
                states.pop(slot, None)
                served += 1
    hit_rate = sched.stats["prefix_hits"] / max(
        sched.stats["prefix_hits"] + sched.stats["prefix_misses"], 1)
    print(f"served {served} requests; prefix-cache hit rate {hit_rate:.2f}; "
          f"stats {sched.stats}")
    return sched.stats


if __name__ == "__main__":
    main()
