"""End-to-end training driver (example b's engine): any --arch, CPU-runnable
with smoke configs, production-mesh ready with full configs.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --smoke \
        --steps 50 --batch 8 --seq 64

Features wired in: deterministic resumable data pipeline, sharded AdamW,
async checkpointing + restore-on-restart, fleet heartbeat monitor
(straggler/failure detection), optional int8 gradient compression with
error feedback, optional CIDER-combined sparse embedding gradients.

DESIGN.md §1 (launch layer): training driver wiring data, models, optimizer,
compression and FT on the shared meshes.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.checkpoint import AsyncCheckpointer, latest_step, restore
from repro.configs import get_config
from repro.data.pipeline import DataConfig, Pipeline
from repro.dist.compress import (ef_compress_tree, ef_decompress_tree,
                                 zeros_residuals)
from repro.ft.failures import FleetMonitor
from repro.models.common import unbox
from repro.models.model import Model
from repro.train.optimizer import adamw_init, adamw_update


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt_every", type=int, default=50)
    ap.add_argument("--log_every", type=int, default=5)
    ap.add_argument("--int8-grads", action="store_true",
                    help="int8 + error-feedback gradient compression "
                         "(dist.compress) on the cross-node gradient path")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    model = Model(cfg)
    params = unbox(model.init(jax.random.key(0)))
    opt = adamw_init(params)
    # error-feedback residuals are training state: they carry accumulated
    # quantization error across steps AND restarts (checkpointed below) —
    # an empty tuple when compression is off, so the default path pays
    # nothing for them
    residuals = zeros_residuals(params) if args.int8_grads else ()
    start_step = 0
    ckpt = AsyncCheckpointer(args.ckpt) if args.ckpt else None
    if ckpt and latest_step(args.ckpt) is not None:
        (params, opt, residuals), start_step = restore(
            args.ckpt, (params, opt, residuals))
        print(f"restored step {start_step} from {args.ckpt}")

    pipe = Pipeline(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                               global_batch=args.batch))
    monitor = FleetMonitor(n_workers=1)

    @jax.jit
    def step_fn(params, opt, batch, residuals):
        (loss, metrics), grads = jax.value_and_grad(
            model.loss_fn, has_aux=True)(params, batch)
        if args.int8_grads:
            # what crosses the node boundary is int8 + one scale per leaf;
            # the rounding error is carried in ``residuals`` (error feedback)
            q, scales, residuals = ef_compress_tree(grads, residuals)
            grads = jax.tree.map(lambda g, d: d.astype(g.dtype),
                                 grads, ef_decompress_tree(q, scales))
        params, opt, gnorm = adamw_update(params, grads, opt, lr=args.lr)
        return params, opt, loss, gnorm, residuals

    losses = []
    for step in range(start_step, start_step + args.steps):
        t0 = time.time()
        batch = pipe.batch_at(step)
        params, opt, loss, gnorm, residuals = step_fn(params, opt, batch,
                                                      residuals)
        loss = float(loss)
        losses.append(loss)
        monitor.beat(0, step_time_s=time.time() - t0)
        if step % args.log_every == 0:
            print(f"step {step:5d} loss {loss:.4f} gnorm {float(gnorm):.3f} "
                  f"({time.time() - t0:.2f}s)", flush=True)
        if ckpt and step and step % args.ckpt_every == 0:
            ckpt.save_async(step, (params, opt, residuals))
    if ckpt:
        ckpt.save_async(start_step + args.steps, (params, opt, residuals))
        ckpt.wait()
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")
    assert np.isfinite(losses[-1])
    return losses


if __name__ == "__main__":
    main()
