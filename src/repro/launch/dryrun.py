"""Multi-pod dry-run driver: lower every (arch x cell x mesh) and record memory,
cost and collective analysis without touching real hardware.

DESIGN.md §5 (dry-run shape-cell policy): the grid, the skip rules, and the
per-cell JSON this module emits.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, record memory/cost/collective analysis (deliverable e).

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b \
        --cell train_4k --mesh single --out results/dryrun

Results are cached per cell as JSON so the sweep is resumable.  The two
XLA_FLAGS lines above MUST stay the first statements: jax locks the device
count on first init, and only the dry-run wants 512 host devices.
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ALL_ARCHS, get_config
from repro.dist.ctx import use_mesh
from repro.dist.sharding import (batch_shardings, decode_state_shardings,
                                 param_shardings)
from repro.launch.mesh import make_production_mesh
from repro.models.common import unbox
from repro.models.model import Model
from repro.rooflines.hlo_parser import cost_dict, parse_hlo
from repro.rooflines.roofline import model_flops, roofline
from repro.train.optimizer import OptState, adamw_init, adamw_update

SHAPES = {
    "train_4k": ("train", 4096, 256),
    "prefill_32k": ("prefill", 32768, 32),
    "decode_32k": ("decode", 32768, 128),
    "long_500k": ("decode", 524288, 1),
}


def cell_supported(cfg, cell: str) -> tuple[bool, str]:
    kind = SHAPES[cell][0]
    if kind == "decode" and not cfg.has_decode:
        return False, "encoder-only: no decode step"
    if cell == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full attention: 500k decode skipped (DESIGN.md)"
    return True, ""


def make_train_step(model):
    def train_step(params, opt, batch):
        (loss, metrics), grads = jax.value_and_grad(
            model.loss_fn, has_aux=True)(params, batch)
        params2, opt2, gnorm = adamw_update(params, grads, opt)
        return params2, opt2, {"loss": loss, "gnorm": gnorm}
    return train_step


def lower_cell(arch: str, cell: str, multi_pod: bool):
    cfg = get_config(arch)
    kind, seq, gb = SHAPES[cell]
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = Model(cfg)
    boxed = model.init_abstract()
    psh = param_shardings(boxed, mesh)
    pspec = unbox(boxed)
    if kind == "train":
        ospec = jax.eval_shape(adamw_init, pspec)
        osh = OptState(m=psh, v=psh, step=NamedSharding(mesh, P()))
        bspec = model.input_specs("train", seq, gb)
        bsh = batch_shardings(bspec, mesh)
        fn = make_train_step(model)
        with mesh, use_mesh(mesh):
            lowered = jax.jit(fn, in_shardings=(psh, osh, bsh)).lower(
                pspec, ospec, bspec)
    elif kind == "prefill":
        bspec = model.input_specs("prefill", seq, gb)
        bsh = batch_shardings(bspec, mesh)
        if cfg.family == "encoder":
            def fn(params, batch):
                from repro.models import transformer as tfm
                logits, _, _ = tfm.forward(cfg, params, batch["tokens"],
                                           batch.get("frontend"))
                return logits
        else:
            def fn(params, batch):
                return model.prefill(params, batch["tokens"],
                                     batch.get("frontend"))
        with mesh, use_mesh(mesh):
            lowered = jax.jit(fn, in_shardings=(psh, bsh)).lower(pspec, bspec)
    else:  # decode
        specs = model.input_specs("decode", seq, gb)
        ssh = decode_state_shardings(specs["state"], mesh)
        tsh = NamedSharding(mesh, P(("pod", "data") if multi_pod else "data"))
        if gb % (mesh.shape.get("pod", 1) * mesh.shape["data"]) != 0:
            tsh = NamedSharding(mesh, P())

        def fn(params, state, token, pos):
            return model.decode_step(params, state, token, pos)

        with mesh, use_mesh(mesh):
            lowered = jax.jit(fn, in_shardings=(
                psh, ssh, tsh, NamedSharding(mesh, P()))).lower(
                pspec, specs["state"], specs["token"], specs["pos"])
    return cfg, model, mesh, lowered


def run_cell(arch: str, cell: str, multi_pod: bool, outdir: str) -> dict:
    tag = f"{arch}__{cell}__{'multi' if multi_pod else 'single'}"
    path = os.path.join(outdir, tag + ".json")
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    cfg = get_config(arch)
    ok, why = cell_supported(cfg, cell)
    rec = {"arch": arch, "cell": cell,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "chips": 512 if multi_pod else 256}
    if not ok:
        rec.update(status="skipped", reason=why)
    else:
        t0 = time.time()
        try:
            cfg, model, mesh, lowered = lower_cell(arch, cell, multi_pod)
            compiled = lowered.compile()
            t1 = time.time()
            mem = compiled.memory_analysis()
            cost = cost_dict(compiled)
            hlo = compiled.as_text()
            parsed = parse_hlo(hlo)
            kind, seq, gb = SHAPES[cell]
            n_params = model.n_params()
            mf = model_flops(cfg, kind, seq, gb, n_params)
            terms = roofline(parsed.dot_flops, parsed.hbm_bytes,
                             parsed.coll_bytes, mf, rec["chips"])
            rec.update(
                status="ok", compile_s=round(t1 - t0, 1),
                n_params=n_params,
                xla_flops=float(cost.get("flops", -1.0)),
                bytes_per_chip=_mem_dict(mem),
                hlo_dot_flops_per_chip=parsed.dot_flops,
                hlo_hbm_bytes_per_chip=parsed.hbm_bytes,
                coll_bytes_per_chip=parsed.coll_bytes,
                coll_by_kind=parsed.coll_by_kind,
                n_collectives=parsed.n_collectives,
                trip_counts=parsed.trip_counts,
                model_flops=mf,
                roofline=terms.row(),
            )
        except Exception as e:  # noqa: BLE001 — a failed cell is a bug report
            rec.update(status="error", error=f"{type(e).__name__}: {e}",
                       trace=traceback.format_exc()[-2000:])
    os.makedirs(outdir, exist_ok=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def _mem_dict(mem) -> dict:
    if mem is None:
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes"):
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--cell", default="all", choices=list(SHAPES) + ["all"])
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()
    archs = ALL_ARCHS if args.arch == "all" else [args.arch]
    cells = list(SHAPES) if args.cell == "all" else [args.cell]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    n_ok = n_skip = n_err = 0
    for arch in archs:
        for cell in cells:
            for mp in meshes:
                rec = run_cell(arch, cell, mp, args.out)
                s = rec["status"]
                n_ok += s == "ok"
                n_skip += s == "skipped"
                n_err += s == "error"
                line = f"[{s:7s}] {arch:22s} {cell:12s} {rec['mesh']:8s}"
                if s == "ok":
                    r = rec["roofline"]
                    line += (f" compile={rec['compile_s']:6.1f}s"
                             f" bott={r['bottleneck']:10s}"
                             f" frac={r['roofline_fraction']:.3f}")
                elif s == "error":
                    line += " " + rec["error"][:80]
                print(line, flush=True)
    print(f"\nDRYRUN ok={n_ok} skipped={n_skip} errors={n_err}")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
