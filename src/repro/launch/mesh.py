"""Production mesh builders (kept as FUNCTIONS so importing this module never
touches jax device state).

DESIGN.md §3.1 (mesh axes): the production and local mesh builders.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod (v5e); multi_pod adds a leading 2-pod axis.
    The ``pod`` axis composes with ``data`` for all batch/FSDP sharding, so
    scaling pods is a config change, not a code change."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1):
    """CPU-test mesh with the production axis names.  ``data > 1`` (sharded
    store tests) needs ``--xla_force_host_platform_device_count >= data``
    (set in tests/conftest.py before jax backend init)."""
    return jax.make_mesh((data, 1), ("data", "model"))
