"""phi-3-vision-4.2b — phi3-mini backbone + CLIP patch STUB
[hf:microsoft/Phi-3-vision-128k-instruct].

DESIGN.md §5 (dry-run policy): registry entry — exact published dims + smoke
variant consumed by the shape-cell grid.
"""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b", family="vlm", n_layers=32, d_model=3072,
    n_heads=32, n_kv=32, d_ff=8192, vocab=32064, head_dim=96,
    frontend="vision", frontend_dim=1024, n_patches=576)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv=4, d_ff=256, vocab=512,
    head_dim=32, frontend_dim=16, n_patches=4, attn_chunk=64, smoke=True)
