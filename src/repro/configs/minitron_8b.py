"""minitron-8b — pruned nemotron, dense GQA, 256k vocab [arXiv:2407.14679].

DESIGN.md §5 (dry-run policy): registry entry — exact published dims + smoke
variant consumed by the shape-cell grid.
"""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b", family="dense", n_layers=32, d_model=4096,
    n_heads=32, n_kv=8, d_ff=16384, vocab=256000, head_dim=128)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv=2, d_ff=256, vocab=512,
    head_dim=32, attn_chunk=64, smoke=True)
