"""hubert-xlarge — audio encoder-only backbone; conv frontend is a STUB
(input_specs supplies 49 Hz frame embeddings) [arXiv:2106.07447].

DESIGN.md §5 (dry-run policy): registry entry — exact published dims + smoke
variant consumed by the shape-cell grid.
"""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", family="encoder", n_layers=48, d_model=1280,
    n_heads=16, n_kv=16, d_ff=5120, vocab=504, head_dim=80, causal=False,
    frontend="audio", frontend_dim=512)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv=4, d_ff=256, vocab=64,
    head_dim=32, frontend_dim=16, attn_chunk=64, smoke=True)
