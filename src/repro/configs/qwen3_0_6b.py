"""qwen3-0.6b — dense GQA with qk_norm [hf:Qwen/Qwen3-0.6B].

head_dim=128 (decoupled from d_model/n_heads, as in the HF config).

DESIGN.md §5 (dry-run policy): registry entry — exact published dims + smoke
variant consumed by the shape-cell grid.
"""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b", family="dense", n_layers=28, d_model=1024,
    n_heads=16, n_kv=8, d_ff=3072, vocab=151936, head_dim=128,
    qk_norm=True, tie_embed=True, rope_theta=1e6)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv=2, d_ff=256, vocab=512,
    head_dim=32, attn_chunk=64, smoke=True)
