"""Assigned-architecture registry: ``get_config(name, smoke=False)``.

Each module defines CONFIG (the exact published dims) and SMOKE (a reduced
same-family config for CPU smoke tests).  Select with ``--arch <id>``.

DESIGN.md §5 (dry-run policy): the architecture registry the shape-cell grid
enumerates.
"""
from __future__ import annotations

import importlib

ALL_ARCHS = [
    "mistral-large-123b", "minitron-8b", "qwen2.5-32b", "qwen3-0.6b",
    "hubert-xlarge", "mamba2-1.3b", "phi-3-vision-4.2b", "kimi-k2-1t-a32b",
    "deepseek-moe-16b", "recurrentgemma-9b",
]

_MOD = {n: n.replace("-", "_").replace(".", "_") for n in ALL_ARCHS}


def get_config(name: str, smoke: bool = False):
    if name not in _MOD:
        raise KeyError(f"unknown arch {name!r}; choose from {ALL_ARCHS}")
    mod = importlib.import_module(f"repro.configs.{_MOD[name]}")
    return mod.SMOKE if smoke else mod.CONFIG
