"""qwen2.5-32b — dense GQA with QKV bias [hf:Qwen/Qwen2.5-32B].

DESIGN.md §5 (dry-run policy): registry entry — exact published dims + smoke
variant consumed by the shape-cell grid.
"""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b", family="dense", n_layers=64, d_model=5120,
    n_heads=40, n_kv=8, d_ff=27648, vocab=152064, head_dim=128,
    qkv_bias=True, rope_theta=1e6)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv=2, d_ff=256, vocab=512,
    head_dim=32, attn_chunk=64, smoke=True)
