"""kimi-k2-1t-a32b — trillion-param MoE: 384 routed experts top-8 + 1 shared
[arXiv:2501.kimi2 paper table].

DESIGN.md §5 (dry-run policy): registry entry — exact published dims + smoke
variant consumed by the shape-cell grid.
"""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe", n_layers=61, d_model=7168,
    n_heads=64, n_kv=8, d_ff=2048, vocab=163840, head_dim=112,
    n_experts=384, top_k=8, n_shared=1)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv=2, d_ff=64, vocab=512,
    head_dim=32, n_experts=8, top_k=2, n_shared=1, capacity_factor=8.0, attn_chunk=64, smoke=True)
