"""recurrentgemma-9b — hybrid RG-LRU + local attention, (rec, rec, attn)
pattern, MQA kv=1, window 2048 [arXiv:2402.19427].

DESIGN.md §5 (dry-run policy): registry entry — exact published dims + smoke
variant consumed by the shape-cell grid.
"""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid", n_layers=38, d_model=4096,
    n_heads=16, n_kv=1, d_ff=12288, vocab=256000, head_dim=256,
    window=2048, d_rnn=4096)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=5, d_model=64, n_heads=4, n_kv=1, d_ff=128, vocab=512,
    head_dim=16, window=16, d_rnn=64, attn_chunk=32, smoke=True)
