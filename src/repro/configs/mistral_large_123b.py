"""mistral-large-123b — dense 88L GQA [hf:mistralai/Mistral-Large-Instruct-2407].

DESIGN.md §5 (dry-run policy): registry entry — exact published dims + smoke
variant consumed by the shape-cell grid.
"""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mistral-large-123b", family="dense", n_layers=88, d_model=12288,
    n_heads=96, n_kv=8, d_ff=28672, vocab=32768, head_dim=128,
    rope_theta=1e6)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv=2, d_ff=256, vocab=512,
    head_dim=32, attn_chunk=64, smoke=True)
