"""mamba2-1.3b — attention-free SSD (state-space duality) [arXiv:2405.21060].

DESIGN.md §5 (dry-run policy): registry entry — exact published dims + smoke
variant consumed by the shape-cell grid.
"""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b", family="ssm", n_layers=48, d_model=2048,
    n_heads=1, n_kv=1, d_ff=0, vocab=50280, ssm_state=128, ssm_headdim=64,
    ssm_expand=2, ssm_groups=1, ssm_chunk=256)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, vocab=512, ssm_state=16, ssm_headdim=16,
    ssm_chunk=16, smoke=True)
