"""deepseek-moe-16b — fine-grained MoE: 64 routed top-6 + 2 shared experts
[arXiv:2401.06066].

DESIGN.md §5 (dry-run policy): registry entry — exact published dims + smoke
variant consumed by the shape-cell grid.
"""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe", n_layers=28, d_model=2048,
    n_heads=16, n_kv=16, d_ff=1408, vocab=102400, head_dim=128,
    n_experts=64, top_k=6, n_shared=2)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv=4, d_ff=64, vocab=512,
    head_dim=32, n_experts=8, top_k=2, n_shared=2, capacity_factor=8.0, attn_chunk=64, smoke=True)
