"""Aggregate dry-run JSONs into the EXPERIMENTS.md roofline table.

    PYTHONPATH=src python -m repro.rooflines.report results/dryrun

DESIGN.md §5 (dry-run policy): folds per-cell dry-run JSONs into the
roofline summary table.
"""
from __future__ import annotations

import glob
import json
import sys


def load(outdir: str):
    recs = []
    for path in sorted(glob.glob(f"{outdir}/*.json")):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def table(recs, mesh="16x16") -> str:
    rows = ["| arch | cell | status | compute s | memory s | coll s | "
            "bottleneck | MODEL_FLOPs | useful | roofline frac | "
            "bytes/chip (args+temp) |",
            "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["mesh"] != mesh:
            continue
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['cell']} | {r['status']}: "
                        f"{r.get('reason', r.get('error', ''))[:60]} "
                        f"| | | | | | | | |")
            continue
        t = r["roofline"]
        mem = r.get("bytes_per_chip", {})
        gb = (mem.get("argument_size_in_bytes", 0)
              + mem.get("temp_size_in_bytes", 0)) / 1e9
        rows.append(
            f"| {r['arch']} | {r['cell']} | ok | {t['compute_s']:.3g} | "
            f"{t['memory_s']:.3g} | {t['collective_s']:.3g} | "
            f"{t['bottleneck']} | {t['model_flops']:.3g} | "
            f"{t['useful_ratio']:.3f} | {t['roofline_fraction']:.4f} | "
            f"{gb:.1f} GB |")
    return "\n".join(rows)


def main():
    outdir = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    recs = load(outdir)
    ok = [r for r in recs if r["status"] == "ok" and r["mesh"] == "16x16"]
    print("### Single-pod (16x16 = 256 chips)\n")
    print(table(recs, "16x16"))
    print("\n### Multi-pod (2x16x16 = 512 chips)\n")
    print(table(recs, "2x16x16"))
    # hillclimb candidates
    worst = min(ok, key=lambda r: r["roofline"]["roofline_fraction"])
    coll = max(ok, key=lambda r: r["roofline"]["collective_s"]
               / max(r["roofline"]["step_time_s"], 1e-12))
    print("\nworst roofline fraction:", worst["arch"], worst["cell"],
          worst["roofline"]["roofline_fraction"])
    print("most collective-bound:", coll["arch"], coll["cell"],
          round(coll["roofline"]["collective_s"]
                / coll["roofline"]["step_time_s"], 3))


if __name__ == "__main__":
    main()
