"""Post-SPMD HLO text parser for the roofline terms.

Works on ``compiled.as_text()`` (optimized, partitioned HLO: all shapes are
PER-CHIP).  Extracts, with while-loop trip-count multiplication — XLA's own
``cost_analysis`` counts a scan body once, and the optimized while carries
``backend_config={"known_trip_count":{"n":...}}`` which we read directly:

* per-chip collective wire bytes, by op kind, using ring formulas:
    all-gather          (g-1)/g * out_bytes
    reduce-scatter      (g-1)   * out_bytes            (in = g * out)
    all-reduce          2*(g-1)/g * bytes
    all-to-all          (g-1)/g * bytes
    collective-permute  bytes
* dot FLOPs (2 * prod(result_dims) * contracted_size) — the MXU term —
  counted in every computation (CPU HLO wraps dots in called fusions);
* HBM traffic estimate: result + operand bytes of instructions in
  *sequencing* computations only (entry + while bodies) — called fusion
  bodies are represented by their call-site line.

DESIGN.md §5 (dry-run policy): extracts per-chip flops/bytes/collective
terms from partitioned HLO text.
"""
from __future__ import annotations

import dataclasses
import re

__all__ = ["HLOCost", "parse_hlo", "cost_dict"]


def cost_dict(compiled) -> dict:
    """Normalize ``compiled.cost_analysis()`` to a dict: jax<=0.4.x returns
    a list with one entry per executable module."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_WHILE_RE = re.compile(
    r"while\(.*?\), condition=%?([\w.\-]+), body=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"\b(?:calls|to_apply)=%?([\w.\-]+)")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_DOT_RE = re.compile(r"=\s*\S+\s+dot\(")
_DOT_OPS_RE = re.compile(r"dot\(%([\w.\-]+), %([\w.\-]+)\)")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+) = ([a-z0-9]+)\[([\d,]*)\]")
_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_GROUPS_BRACE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_OPCODE_RE = re.compile(r"=\s*(?:\([^=]*?\)|[a-z0-9]+\[[\d,]*\]\S*)\s+"
                        r"([a-z][\w\-]*)[\s(]")
_REF_RE = re.compile(r"%([\w.\-]+)")
# ops that move no HBM bytes of their own (views / control / plumbing)
_NO_TRAFFIC = {
    "parameter", "get-tuple-element", "tuple", "bitcast", "constant",
    "while", "conditional", "call", "after-all", "domain", "reshape",
    "partition-id", "replica-id", "opt-barrier", "add-dependency",
}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _shapes_on(line: str):
    return [(m.group(1), m.group(2)) for m in _SHAPE_RE.finditer(line)
            if m.group(1) in _DTYPE_BYTES]


@dataclasses.dataclass
class HLOCost:
    dot_flops: float = 0.0            # per-chip MXU FLOPs
    hbm_bytes: float = 0.0            # per-chip HBM traffic estimate
    coll_bytes: float = 0.0           # per-chip collective wire bytes
    coll_by_kind: dict = dataclasses.field(default_factory=dict)
    n_collectives: int = 0
    n_whiles: int = 0
    trip_counts: dict = dataclasses.field(default_factory=dict)


def _split_computations(text: str):
    comps: dict[str, list[str]] = {}
    cur = None
    entry = None
    for line in text.splitlines():
        s = line.rstrip()
        if cur is None or (s and not s.startswith(" ")):
            m = _COMP_HDR.match(s) if ("{" in s and "->" in s) else None
            if m:
                cur = m.group(2)
                comps[cur] = []
                if m.group(1):
                    entry = cur
                continue
        if cur is not None:
            if s.strip() == "}":
                cur = None
            else:
                comps[cur].append(s)
    return comps, entry


def parse_hlo(text: str) -> HLOCost:
    comps, entry = _split_computations(text)
    # instruction name -> (dtype, dims) for operand-shape lookup
    defs: dict[str, tuple[str, str]] = {}
    for lines in comps.values():
        for line in lines:
            dm = _DEF_RE.match(line)
            if dm and dm.group(2) in _DTYPE_BYTES:
                defs[dm.group(1)] = (dm.group(2), dm.group(3))
    # which computations are bodies of called fusions / reducers?
    fusion_called: set[str] = set()
    for lines in comps.values():
        for line in lines:
            for m in _CALLS_RE.finditer(line):
                fusion_called.add(m.group(1))
    # multiplier fixed-point over while edges (x trip) and call edges (x 1)
    mult = dict.fromkeys(comps, 0.0)
    if entry in mult:
        mult[entry] = 1.0
    trips: dict[str, int] = {}
    for _ in range(12):
        nxt = dict.fromkeys(comps, 0.0)
        if entry in nxt:
            nxt[entry] = 1.0
        for name, lines in comps.items():
            m0 = mult.get(name, 0.0)
            if m0 == 0.0:
                continue
            for line in lines:
                wm = _WHILE_RE.search(line)
                if wm:
                    cond, body = wm.group(1), wm.group(2)
                    tm = _TRIP_RE.search(line)
                    if tm:
                        t = int(tm.group(1))
                    else:
                        consts = [int(c.group(1)) for cl in comps.get(cond, [])
                                  for c in _CONST_RE.finditer(cl)]
                        t = max(consts) if consts else 1
                    trips[body] = t
                    if body in nxt:
                        nxt[body] += m0 * t
                    if cond in nxt:
                        nxt[cond] += m0 * (t + 1)
                for cm in _CALLS_RE.finditer(line):
                    if cm.group(1) in nxt:
                        nxt[cm.group(1)] += m0
        if nxt == mult:
            break
        mult = nxt

    cost = HLOCost(trip_counts=trips)
    for name, lines in comps.items():
        m = mult.get(name, 0.0)
        if m == 0.0:
            continue
        sequencing = name not in fusion_called
        for line in lines:
            shapes = _shapes_on(line)
            if not shapes:
                continue
            cmatch = _COLL_RE.search(line)
            if cmatch and "=" in line:
                kind = cmatch.group(1)
                out_b = _shape_bytes(*shapes[0])
                gb = _GROUPS_BRACE.search(line)
                gi = _GROUPS_IOTA.search(line)
                if gb:
                    g = len(gb.group(1).split(","))
                elif gi:
                    g = int(gi.group(2))
                else:
                    g = 2
                g = max(g, 2)
                if kind == "all-gather":
                    wire = out_b * (g - 1) / g
                elif kind == "reduce-scatter":
                    wire = out_b * (g - 1)
                elif kind == "all-reduce":
                    wire = 2 * out_b * (g - 1) / g
                elif kind == "all-to-all":
                    wire = out_b * (g - 1) / g
                else:  # collective-permute
                    wire = out_b
                cost.coll_bytes += m * wire
                cost.coll_by_kind[kind] = cost.coll_by_kind.get(kind, 0.0) \
                    + m * wire
                cost.n_collectives += 1
            if _DOT_RE.search(line):
                out_dt, out_dims = shapes[0]
                ops = _DOT_OPS_RE.search(line)
                lhs = defs.get(ops.group(1), ("f32", "")) if ops \
                    else (shapes[1] if len(shapes) > 1 else ("f32", ""))
                cd = _CDIMS_RE.search(line)
                csize = 1
                if cd and lhs[1]:
                    ldims = [int(x) for x in lhs[1].split(",") if x]
                    for ci in cd.group(1).split(","):
                        if ci and int(ci) < len(ldims):
                            csize *= ldims[int(ci)]
                n_out = 1
                for d in out_dims.split(","):
                    if d:
                        n_out *= int(d)
                cost.dot_flops += m * 2.0 * n_out * csize
            if sequencing:
                om = _OPCODE_RE.search(line)
                opcode = om.group(1) if om else ""
                if opcode and opcode not in _NO_TRAFFIC:
                    # result bytes + operand bytes (resolved via defs)
                    nbytes = _shape_bytes(*shapes[0])
                    refs = _REF_RE.findall(line.split("(", 1)[1]) \
                        if "(" in line else []
                    for r in refs[:8]:
                        if r in defs:
                            nbytes += _shape_bytes(*defs[r])
                    cost.hbm_bytes += m * nbytes
        cost.n_whiles += sum(1 for l in lines if _WHILE_RE.search(l))
    return cost
