"""Three-term roofline model for TPU v5e (target hardware).

All HLO-derived quantities are PER-CHIP (the post-SPMD module is the
per-chip program), so:

    compute term    = chip_dot_flops / 197e12        [s]
    memory term     = chip_hbm_bytes / 819e9         [s]
    collective term = chip_wire_bytes / 50e9         [s]

The dominant term is the bottleneck; roofline fraction of a cell =
useful_model_flops / (chips * peak * dominant_term).

DESIGN.md §5 (dry-run policy): three-term (compute/HBM/ICI) per-chip step-
time model for the dry-run grid.
"""
from __future__ import annotations

import dataclasses

__all__ = ["V5E", "RooflineTerms", "roofline", "model_flops"]

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # bytes/s / chip
ICI_BW = 50e9             # bytes/s / link (per-chip aggregate modeled as 1 link)
V5E = dict(peak_flops=PEAK_FLOPS, hbm_bw=HBM_BW, ici_bw=ICI_BW)


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    chip_flops: float
    chip_hbm_bytes: float
    chip_wire_bytes: float
    model_flops: float            # 6*N_act*D (train) / 2*N_act*D (inference)
    useful_ratio: float           # model_flops / (chips * chip_flops)
    roofline_fraction: float      # model_flops / (chips*peak*dominant)
    step_time_s: float            # max of the three terms (no-overlap bound)

    def row(self) -> dict:
        return dataclasses.asdict(self)


def roofline(chip_flops: float, chip_hbm_bytes: float, chip_wire_bytes: float,
             model_flops: float, chips: int) -> RooflineTerms:
    ct = chip_flops / PEAK_FLOPS
    mt = chip_hbm_bytes / HBM_BW
    lt = chip_wire_bytes / ICI_BW
    terms = {"compute": ct, "memory": mt, "collective": lt}
    bott = max(terms, key=terms.get)
    step = max(ct, mt, lt)
    return RooflineTerms(
        compute_s=ct, memory_s=mt, collective_s=lt, bottleneck=bott,
        chip_flops=chip_flops, chip_hbm_bytes=chip_hbm_bytes,
        chip_wire_bytes=chip_wire_bytes, model_flops=model_flops,
        useful_ratio=model_flops / max(chips * chip_flops, 1.0),
        roofline_fraction=model_flops / max(chips * PEAK_FLOPS * step, 1e-30),
        step_time_s=step)


def model_flops(cfg, kind: str, seq: int, global_batch: int,
                n_params: int) -> float:
    """6*N*D for training, 2*N*D for inference (N = active params)."""
    n_act = n_params
    if cfg.family == "moe":
        d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
        expert_p = cfg.n_layers * e * 3 * d * f
        n_act = n_params - expert_p + cfg.n_layers * (cfg.top_k * 3 * d * f)
    if kind == "train":
        tokens = seq * global_batch
        return 6.0 * n_act * tokens
    if kind == "prefill":
        tokens = seq * global_batch
        return 2.0 * n_act * tokens
    # decode: one token per sequence; SSM/hybrid read O(1) state, attention
    # reads the KV cache (memory-bound) — FLOPs side stays 2*N_act per token
    return 2.0 * n_act * global_batch
