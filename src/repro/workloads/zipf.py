"""Zipfian sampling for YCSB-style skewed workloads.

Two implementations:

* ``ZipfSampler`` — Hörmann & Derflinger rejection-inversion (the algorithm
  used by YCSB's ``ScrambledZipfianGenerator`` ancestry). O(1) per sample,
  O(1) setup — usable for 60M-key universes where the naive zeta table is
  infeasible.  numpy-based (host-side workload generation).
* ``zipf_cdf_table`` / ``sample_zipf_jax`` — a truncated-CDF table sampler for
  the JAX data pipeline (token streams): exact for the head, uniform tail
  bucket; fully jittable and counter-based (stateless RNG) so the pipeline is
  deterministic and resumable.

References: W. Hörmann, G. Derflinger, "Rejection-inversion to generate
variates from monotone discrete distributions", TOMACS 6(3), 1996; YCSB
(Cooper et al., SoCC'10).

DESIGN.md §1 (workloads layer): the skewed-key samplers under every YCSB
generator (§9.4).
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp

__all__ = ["ZipfSampler", "zipf_cdf_table", "sample_zipf_jax", "scramble"]

_GOLDEN64 = np.uint64(0x9E3779B97F4A7C15)


def scramble(ids: np.ndarray, n: int) -> np.ndarray:
    """YCSB-style scrambling: map rank->key id via a 64-bit mix so that the
    hot ranks are scattered over the key space (hot keys are not adjacent)."""
    x = ids.astype(np.uint64)
    x = (x + np.uint64(1)) * _GOLDEN64
    x ^= x >> np.uint64(31)
    x *= np.uint64(0xBF58476D1CE4E5B9)
    x ^= x >> np.uint64(27)
    return (x % np.uint64(n)).astype(np.int64)


@dataclasses.dataclass
class ZipfSampler:
    """Rejection-inversion Zipf(theta) sampler over ranks [0, n)."""

    n: int
    theta: float = 0.99
    seed: int = 0

    def __post_init__(self) -> None:
        if not (0.0 <= self.theta) or self.theta == 1.0:
            raise ValueError(f"theta must be >=0 and != 1, got {self.theta}")
        self._rng = np.random.default_rng(self.seed)
        q = self.theta
        self._q = q
        # H(x) = (x^(1-q) - 1) / (1-q)   (integral of x^-q)
        self._one_minus_q = 1.0 - q
        self._one_minus_q_inv = 1.0 / self._one_minus_q
        self._h_x1 = self._H(1.5) - 1.0
        self._h_n = self._H(self.n + 0.5)
        self._s = 2.0 - self._H_inv(self._H(2.5) - 2.0 ** -q)

    def _H(self, x: float | np.ndarray):
        return (np.power(x, self._one_minus_q) - 1.0) * self._one_minus_q_inv

    def _H_inv(self, x: float | np.ndarray):
        return np.power(1.0 + x * self._one_minus_q, self._one_minus_q_inv)

    def sample(self, size: int, scrambled: bool = True) -> np.ndarray:
        """Draw ``size`` ranks (optionally scrambled into key ids)."""
        if self.theta == 0.0:
            out = self._rng.integers(0, self.n, size=size, dtype=np.int64)
            return out
        out = np.empty(size, dtype=np.int64)
        filled = 0
        while filled < size:
            todo = size - filled
            u = self._rng.random(todo)
            hx = self._h_x1 + u * (self._h_n - self._h_x1)
            x = self._H_inv(hx)
            k = np.floor(x + 0.5)
            accept = (k - x <= self._s) | (hx >= self._H(k + 0.5) - np.power(k, -self._q))
            acc = k[accept].astype(np.int64) - 1  # 0-based rank
            take = min(todo, acc.shape[0])
            out[filled : filled + take] = acc[:take]
            filled += take
        np.clip(out, 0, self.n - 1, out=out)
        if scrambled:
            out = scramble(out, self.n)
        return out


def zipf_cdf_table(n: int, theta: float, head: int = 8192) -> np.ndarray:
    """CDF over ``head`` explicit top ranks + 1 tail bucket (uniform inside).

    Returns float32 array of shape (head + 1,): cumulative probabilities.
    """
    head = min(head, n)
    ranks = np.arange(1, head + 1, dtype=np.float64)
    w = ranks ** (-theta)
    if n > head:
        # integral approximation of the tail mass sum_{head+1..n} k^-theta
        if theta == 1.0:
            tail = np.log(n + 0.5) - np.log(head + 0.5)
        else:
            tail = ((n + 0.5) ** (1 - theta) - (head + 0.5) ** (1 - theta)) / (1 - theta)
    else:
        tail = 0.0
    total = w.sum() + tail
    cdf = np.concatenate([np.cumsum(w), [w.sum() + tail]]) / total
    return cdf.astype(np.float32)


def sample_zipf_jax(key: jax.Array, shape: tuple, cdf: jax.Array, n: int,
                    head: int | None = None) -> jax.Array:
    """Jittable Zipf sampler from a ``zipf_cdf_table``.

    Head ranks are exact; the tail bucket is uniform over [head, n). Rank ids
    are scrambled with the same 64-bit mix as the numpy path so hot keys are
    scattered across the key space.
    """
    if head is None:
        head = cdf.shape[0] - 1
    k_u, k_t = jax.random.split(key)
    u = jax.random.uniform(k_u, shape)
    idx = jnp.searchsorted(cdf, u)  # 0..head ; == head means tail bucket
    tail_draw = jax.random.randint(k_t, shape, head, jnp.maximum(n, head + 1))
    ranks = jnp.where(idx >= head, tail_draw, idx).astype(jnp.uint32)
    # 32-bit variant of the scramble (uint64 unsupported on default jax config)
    x = ranks + jnp.uint32(1)
    x = x * jnp.uint32(0x9E3779B9)
    x = x ^ (x >> 15)
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    return (x % jnp.uint32(n)).astype(jnp.int32)
