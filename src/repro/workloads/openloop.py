"""Open-loop arrival streams: Poisson / MMPP load binned into fixed windows.

Layer: workloads (DESIGN.md §1, §12) — contract: host-side arrival-process
generators emitting *partially filled* ``(W, B)`` window planes plus an
explicit validity plane, drop-in inputs for ``repro.core.runner.make_stream``.

Every other generator in this package is **closed-loop**: each window is a
full batch, so clients implicitly wait for the previous window to finish
before issuing — offered load always equals service capacity and queueing
collapse is invisible by construction.  FUSEE-style thin clients are
**open-loop**: requests arrive on their own clock regardless of service
progress, and the latency-vs-offered-load curve (the hockey stick) is what
exposes where a SyncMode's queues give out.  This module models that:

* each CN ``c`` receives ``Poisson(rho * lanes_per_cn)`` arrivals per window
  (``arrival="poisson"``), or a 2-state Markov-modulated Poisson process
  (``"mmpp"``: quiet/burst phases per CN with the burst rate scaled by
  ``burst_mult``, normalized so the *mean* rate still equals
  ``rho * lanes_per_cn`` — ``rho`` stays comparable across processes);
* arrivals queue FIFO per CN; each window issues at most ``lanes_per_cn``
  of them into the CN's lane block, recording per-op queueing delay in
  whole windows (``delay_windows``); excess backlog carries over;
* unfilled lanes are ``OpKind.NOP`` with ``valid=False`` — the window shape
  stays static for the fused ``lax.scan`` while occupancy varies, and the
  engine bills invalid lanes zero verbs.

The **dense re-pack contract** (DESIGN.md §12, tested not assumed): packing
each window's valid lanes to the front — preserving lane order and carrying
the explicit CN plane — must leave the bill, the store state, and the per-op
results bit-identical (results land at permuted lanes; ``repack.order``
maps them back).  Serialization sorts by (key, pos) and a stable pack
preserves relative pos order; local write-combining groups by (key, cn) and
the CN plane rides along — so nothing observable may move.

End-to-end open-loop latency = ``delay_windows * window_us`` (queueing in
whole windows) + the in-window modeled completion time from
``repro.core.runner.modeled_latency``.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.types import OpKind
from repro.workloads.ycsb import (OpBatchNp, WORKLOADS, WorkloadSpec,
                                  generate_ops)

__all__ = ["OpenLoopSpec", "OpenLoopStream", "generate_openloop_stream",
           "dense_repack", "open_loop_latency"]


@dataclasses.dataclass(frozen=True)
class OpenLoopSpec:
    """One open-loop experiment cell.

    ``rho`` is offered load as a fraction of per-window service capacity:
    mean arrivals per CN per window = ``rho * lanes_per_cn``.  ``rho < 1``
    drains; ``rho >= 1`` grows backlog without bound — the regime the
    hockey-stick curve sweeps across.
    """

    n_cns: int = 4
    lanes_per_cn: int = 64
    windows: int = 32
    rho: float = 0.7
    n_keys: int = 4096
    mix: WorkloadSpec = WORKLOADS["write-intensive"]
    theta: float | None = None
    arrival: str = "poisson"        # "poisson" | "mmpp"
    burst_mult: float = 4.0         # MMPP burst-phase rate multiplier
    p_enter_burst: float = 0.10     # quiet -> burst, per window
    p_exit_burst: float = 0.30      # burst -> quiet, per window
    seed: int = 0

    def __post_init__(self):
        if self.arrival not in ("poisson", "mmpp"):
            raise ValueError(f"unknown arrival process {self.arrival!r}")
        if self.rho <= 0:
            raise ValueError("rho must be positive")


@dataclasses.dataclass
class OpenLoopStream:
    """Generated window planes (numpy, ``(W, B)`` with ``B = n_cns * L``).

    ``delay_windows[w, b]`` is how many whole windows the op at lane ``b``
    of window ``w`` sat in its CN's FIFO before being issued (0 = issued in
    its arrival window; 0 on invalid lanes).  ``arrivals``/``phases`` are
    the raw per-(window, CN) process draws kept for the statistical-law
    tests; ``backlog_end`` is what each CN still had queued at the horizon.
    """

    kinds: np.ndarray          # (W, B) uint8 OpKind, NOP on invalid lanes
    keys: np.ndarray           # (W, B) int64
    values: np.ndarray        # (W, B) int64
    cn: np.ndarray             # (W, B) int32 issuing CN (explicit plane)
    valid: np.ndarray          # (W, B) bool
    delay_windows: np.ndarray  # (W, B) int32
    arrivals: np.ndarray       # (W, n_cns) int64 raw arrival counts
    phases: np.ndarray         # (W, n_cns) int8 MMPP phase (0 quiet, 1 burst)
    backlog_end: np.ndarray    # (n_cns,) int64 unserved arrivals at horizon
    order: np.ndarray | None = None  # (W, B) repack permutation (see dense_repack)

    @property
    def offered(self) -> int:
        return int(self.arrivals.sum())

    @property
    def delivered(self) -> int:
        return int(self.valid.sum())


def _arrival_counts(spec: OpenLoopSpec, rng: np.random.Generator
                    ) -> tuple[np.ndarray, np.ndarray]:
    """Draw the (W, n_cns) arrival-count and phase planes."""
    w, c = spec.windows, spec.n_cns
    base = spec.rho * spec.lanes_per_cn
    if spec.arrival == "poisson":
        phases = np.zeros((w, c), np.int8)
        counts = rng.poisson(base, size=(w, c))
        return counts.astype(np.int64), phases
    # MMPP: per-CN 2-state chain, started from the stationary distribution so
    # window 0 is not special; rates normalized to keep the mean at `base`.
    pe, px = spec.p_enter_burst, spec.p_exit_burst
    pi_burst = pe / (pe + px) if (pe + px) > 0 else 0.0
    mean_mult = (1.0 - pi_burst) + pi_burst * spec.burst_mult
    rates = np.array([base / mean_mult, base * spec.burst_mult / mean_mult])
    phases = np.zeros((w, c), np.int8)
    phase = (rng.random(c) < pi_burst).astype(np.int8)
    for t in range(w):
        phases[t] = phase
        u = rng.random(c)
        flip = np.where(phase == 0, u < pe, u < px)
        phase = np.where(flip, 1 - phase, phase).astype(np.int8)
    counts = rng.poisson(rates[phases])
    return counts.astype(np.int64), phases


def generate_openloop_stream(spec: OpenLoopSpec) -> OpenLoopStream:
    """Draw arrivals, run the per-CN FIFO queues, and bin into windows.

    Op *content* is drawn per CN at arrival time (one ``generate_ops`` call
    over the CN's total arrivals), so an op's identity does not depend on
    when the queue got around to issuing it — only its lane and its
    ``delay_windows`` do.
    """
    rng = np.random.default_rng(spec.seed)
    w, c, lanes = spec.windows, spec.n_cns, spec.lanes_per_cn
    b = c * lanes
    counts, phases = _arrival_counts(spec, rng)

    kinds = np.full((w, b), OpKind.NOP, np.uint8)
    keys = np.zeros((w, b), np.int64)
    values = np.zeros((w, b), np.int64)
    valid = np.zeros((w, b), bool)
    delay = np.zeros((w, b), np.int32)
    backlog_end = np.zeros(c, np.int64)

    for cn_id in range(c):
        total = int(counts[:, cn_id].sum())
        ops = generate_ops(spec.mix, max(total, 1), spec.n_keys, 1,
                           seed=spec.seed + 7919 * (cn_id + 1),
                           theta=spec.theta)
        # arrival window of each queued op, in FIFO order
        arrive_w = np.repeat(np.arange(w, dtype=np.int64), counts[:, cn_id])
        lo = cn_id * lanes
        issued = 0
        for t in range(w):
            avail = int(counts[: t + 1, cn_id].sum()) - issued
            n = min(avail, lanes)
            if n > 0:
                sl = slice(issued, issued + n)
                kinds[t, lo:lo + n] = ops.kinds[sl]
                keys[t, lo:lo + n] = ops.keys[sl]
                values[t, lo:lo + n] = ops.values[sl]
                valid[t, lo:lo + n] = True
                delay[t, lo:lo + n] = t - arrive_w[sl]
                issued += n
        backlog_end[cn_id] = total - issued

    cn_plane = np.broadcast_to(
        np.repeat(np.arange(c, dtype=np.int32), lanes), (w, b)).copy()
    return OpenLoopStream(kinds=kinds, keys=keys, values=values, cn=cn_plane,
                          valid=valid, delay_windows=delay, arrivals=counts,
                          phases=phases, backlog_end=backlog_end)


def dense_repack(ol: OpenLoopStream) -> OpenLoopStream:
    """Pack each window's valid lanes to the front (stable, order-preserving).

    Returns a same-shape stream whose ``order`` records the permutation:
    lane ``b`` of the repacked window ``w`` holds what lane
    ``order[w, b]`` of the original held, so per-op engine results can be
    mapped back with ``res[..., order]`` for the bit-equality check.  The
    explicit CN plane rides along, which is precisely why the (key, cn)
    write-combining groups — and hence the bill — cannot change.
    """
    # stable argsort of ~valid puts valid lanes first, original order kept
    order = np.argsort(~ol.valid, axis=1, kind="stable")
    take = np.take_along_axis
    return OpenLoopStream(
        kinds=take(ol.kinds, order, axis=1),
        keys=take(ol.keys, order, axis=1),
        values=take(ol.values, order, axis=1),
        cn=take(ol.cn, order, axis=1),
        valid=take(ol.valid, order, axis=1),
        delay_windows=take(ol.delay_windows, order, axis=1),
        arrivals=ol.arrivals, phases=ol.phases,
        backlog_end=ol.backlog_end, order=order)


def open_loop_latency(ol: OpenLoopStream, lat_us: np.ndarray,
                      window_us: float) -> np.ndarray:
    """End-to-end per-op latency: queueing delay + in-window completion.

    ``lat_us`` is ``repro.core.runner.modeled_latency`` over the same stream
    (NaN on invalid lanes); ``window_us`` is the wall length of one
    synchronization window, which the scale benchmark sets to the modeled
    service time of a full window so queue delay and service time share a
    clock.  Invalid lanes come back NaN — feed the result straight to
    ``latency_stats``.
    """
    lat = np.asarray(lat_us, np.float64).reshape(ol.valid.shape)
    total = ol.delay_windows.astype(np.float64) * float(window_us) + lat
    return np.where(ol.valid, total, np.nan)
