"""Dynamic-contention workload generators (time-varying window streams).

CIDER's headline mechanism — the contention-aware AIMD credit scheme (§4.3,
Algorithm 1) — exists because hotness *changes over time*, but a stationary
Zipf draw (``repro.workloads.ycsb``) never exercises the adaptation path end
to end: credits must grow while a key is hot and drain (multiplicative
decrease) after the hotspot moves.  These generators produce ``(W, B)``
``OpBatchNp`` streams whose contention profile is a function of the window
index — drop-in inputs for ``repro.core.runner.make_stream`` /
``run_windows`` — modeled on the paper's dynamic/skew experiments
(Figs 13-15) and the client/skew sweep style of FUSEE and Outback.

Four scenario families (registry: ``SCENARIOS``):

* ``hotspot_shift`` — a compact hot set absorbs ``hot_frac`` of the traffic;
  at window ``shift_window`` it jumps to a disjoint key set, while exactly
  one UPDATE per *old* hot key per window keeps probing the abandoned set —
  so the AIMD drain (WC batch == 1 -> ``credit //= aimd_factor``) is
  observable as a trajectory instead of leaving stale credits frozen.
* ``flash_crowd`` — the hot fraction ramps 0 -> ``peak_frac`` -> 0 as a
  triangle (a flash crowd arriving and dispersing).
* ``churn`` — alternating INSERT / DELETE phases over an initially EMPTY
  key region, on top of a stationary skewed UPDATE/SEARCH mix on the
  populated region (scenarios carry ``populated_frac`` < 1).
* ``skew_drift`` — Zipf theta interpolates linearly ``theta0 -> theta1``
  across windows (Fig 13's skew sweep as one non-stationary stream).

DESIGN.md §7.1 (scenario generators): time-varying contention streams that
exercise the AIMD adaptation end to end.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.core.types import OpKind
from repro.workloads.ycsb import OpBatchNp, WorkloadSpec, generate_ops
from repro.workloads.zipf import ZipfSampler

__all__ = ["Scenario", "SCENARIOS", "hotspot_shift", "flash_crowd", "churn",
           "skew_drift"]


def _finish(kinds: np.ndarray, keys: np.ndarray, n_clients: int,
            rng: np.random.Generator) -> OpBatchNp:
    w, b = kinds.shape
    values = rng.integers(1, 2**31 - 1, size=(w, b), dtype=np.int64)
    clients = np.broadcast_to((np.arange(b) % n_clients).astype(np.int32),
                              (w, b)).copy()
    return OpBatchNp(kinds=kinds.astype(np.uint8), keys=keys.astype(np.int64),
                     values=values, clients=clients)


def hotspot_shift(windows: int, n_ops: int, n_keys: int, n_clients: int,
                  seed: int = 0, *, write_ratio: float = 0.5,
                  theta: float = 0.99, hot_keys: int = 8,
                  hot_frac: float = 0.5, shift_window: int | None = None,
                  return_sets: bool = False):
    """Hot set A for windows [0, shift), disjoint hot set B afterwards.

    Post-shift, every old hot key still receives exactly ONE update per
    window (a drain probe): with leftover credit the probe takes the
    pessimistic path alone (WC batch 1), which is precisely the AIMD
    multiplicative-decrease branch.  ``return_sets=True`` additionally
    returns ``(set_a, set_b)`` so tests can track per-key credit drain.
    """
    if shift_window is None:
        shift_window = windows // 2
    if n_ops < hot_keys:
        raise ValueError(f"n_ops ({n_ops}) must be >= hot_keys ({hot_keys}) "
                         f"to place one drain probe per old hot key")
    rng = np.random.default_rng(seed + 17)
    zipf = ZipfSampler(n_keys, theta, seed=seed)
    keys = zipf.sample(windows * n_ops).reshape(windows, n_ops)
    perm = rng.permutation(n_keys)[: 2 * hot_keys]
    set_a, set_b = perm[:hot_keys], perm[hot_keys:]
    kinds = np.where(rng.random((windows, n_ops)) < write_ratio,
                     OpKind.UPDATE, OpKind.SEARCH).astype(np.uint8)
    for w in range(windows):
        hot = rng.random(n_ops) < hot_frac
        cur = set_a if w < shift_window else set_b
        keys[w, hot] = rng.choice(cur, size=int(hot.sum()))
        if w >= shift_window:
            # drain probes: one UPDATE per old hot key, distinct lanes
            # (cold lanes preferred; at high hot_frac fall back to any lane)
            pool = np.flatnonzero(~hot)
            if pool.size < hot_keys:
                pool = np.arange(n_ops)
            lanes = rng.choice(pool, size=hot_keys, replace=False)
            keys[w, lanes] = set_a
            kinds[w, lanes] = OpKind.UPDATE
    ops = _finish(kinds, keys, n_clients, rng)
    return (ops, (set_a, set_b)) if return_sets else ops


def flash_crowd(windows: int, n_ops: int, n_keys: int, n_clients: int,
                seed: int = 0, *, write_ratio: float = 0.5,
                theta: float = 0.99, hot_keys: int = 8,
                peak_frac: float = 0.8, peak_window: int | None = None,
                ) -> OpBatchNp:
    """Triangular ramp: the hot fraction climbs linearly from 0 at window 0
    to ``peak_frac`` at ``peak_window`` and back down to 0 at the end."""
    if peak_window is None:
        peak_window = windows // 2
    rng = np.random.default_rng(seed + 29)
    zipf = ZipfSampler(n_keys, theta, seed=seed)
    keys = zipf.sample(windows * n_ops).reshape(windows, n_ops)
    hot_set = rng.permutation(n_keys)[:hot_keys]
    kinds = np.where(rng.random((windows, n_ops)) < write_ratio,
                     OpKind.UPDATE, OpKind.SEARCH).astype(np.uint8)
    last = windows - 1
    for w in range(windows):
        if w == peak_window:            # the apex is always the full crowd,
            ramp = 1.0                  # even when it sits on an endpoint
        elif w < peak_window:
            ramp = w / peak_window
        else:
            ramp = (last - w) / (last - peak_window)
        hot = rng.random(n_ops) < peak_frac * ramp
        keys[w, hot] = rng.choice(hot_set, size=int(hot.sum()))
    return _finish(kinds, keys, n_clients, rng)


def churn(windows: int, n_ops: int, n_keys: int, n_clients: int,
          seed: int = 0, *, write_ratio: float = 0.5, theta: float = 0.99,
          churn_frac: float = 0.15, phase_len: int | None = None,
          populated_frac: float = 0.5) -> OpBatchNp:
    """INSERT/DELETE phases over the initially-empty region
    ``[populated_frac * n_keys, n_keys)``: phases of ``phase_len`` windows
    alternate between inserting fresh keys there and deleting them, on top
    of a stationary skewed UPDATE/SEARCH mix on the populated region."""
    if phase_len is None:
        phase_len = max(windows // 8, 1)
    rng = np.random.default_rng(seed + 43)
    n_pop = int(populated_frac * n_keys)
    zipf = ZipfSampler(n_pop, theta, seed=seed)
    keys = zipf.sample(windows * n_ops).reshape(windows, n_ops)
    kinds = np.where(rng.random((windows, n_ops)) < write_ratio,
                     OpKind.UPDATE, OpKind.SEARCH).astype(np.uint8)
    for w in range(windows):
        cm = rng.random(n_ops) < churn_frac
        keys[w, cm] = rng.integers(n_pop, n_keys, size=int(cm.sum()))
        kind = (OpKind.INSERT if (w // phase_len) % 2 == 0 else OpKind.DELETE)
        kinds[w, cm] = kind
    return _finish(kinds, keys, n_clients, rng)


def skew_drift(windows: int, n_ops: int, n_keys: int, n_clients: int,
               seed: int = 0, *, write_ratio: float = 0.5,
               theta0: float = 0.4, theta1: float = 1.2) -> OpBatchNp:
    """theta(w) interpolates linearly from ``theta0`` to ``theta1``: the
    stream starts near-uniform (optimistic-friendly) and ends heavily skewed
    (combining-friendly), forcing the credit scheme to follow the drift."""
    spec = WorkloadSpec("skew-drift", write_ratio, 1.0 - write_ratio)
    wins = []
    for w in range(windows):
        th = theta0 + (theta1 - theta0) * w / max(windows - 1, 1)
        if abs(th - 1.0) < 1e-6:          # ZipfSampler excludes theta == 1
            th += 1e-4
        wins.append(generate_ops(spec, n_ops, n_keys, n_clients,
                                 seed=seed + w, theta=th))
    return OpBatchNp(kinds=np.stack([o.kinds for o in wins]),
                     keys=np.stack([o.keys for o in wins]),
                     values=np.stack([o.values for o in wins]),
                     clients=np.stack([o.clients for o in wins]))


@dataclasses.dataclass(frozen=True)
class Scenario:
    """A registered dynamic-contention scenario.

    ``generate`` has the uniform signature
    ``(windows, n_ops, n_keys, n_clients, seed=0, **overrides)``;
    ``populated_frac`` tells harnesses how much of ``[0, n_keys)`` to
    pre-populate (churn needs empty headroom for its INSERT phases).
    """
    name: str
    generate: Callable[..., OpBatchNp]
    populated_frac: float = 1.0
    description: str = ""

    def populate_keys(self, n_keys: int) -> np.ndarray:
        return np.arange(int(self.populated_frac * n_keys))


SCENARIOS = {
    "hotspot_shift": Scenario(
        "hotspot_shift", hotspot_shift,
        description="hot set jumps to disjoint keys at the mid window; "
                    "drain probes keep the old set observable"),
    "flash_crowd": Scenario(
        "flash_crowd", flash_crowd,
        description="hot fraction ramps 0 -> peak -> 0 triangularly"),
    "churn": Scenario(
        "churn", churn, populated_frac=0.5,
        description="alternating INSERT/DELETE phases over an empty region "
                    "plus a stationary skewed update mix"),
    "skew_drift": Scenario(
        "skew_drift", skew_drift,
        description="Zipf theta drifts linearly theta0 -> theta1"),
}
