"""YCSB workloads + Zipf samplers."""
