"""YCSB workloads, Zipf samplers, and dynamic-contention scenarios."""
