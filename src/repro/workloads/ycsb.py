"""YCSB workload generation (Cooper et al., SoCC'10).

Layer: workloads (DESIGN.md §1, §9) — contract: host-side op-stream
generators emitting ``OpBatchNp`` arrays the fused runner stacks into
``WindowStream``s; composition contracts are tested, not assumed.

Two families:

* the paper's three ad-hoc mixes (Table 1: ``WORKLOADS`` — write-intensive
  50/50, read-intensive 95/5, write-only 100) via ``generate_ops`` /
  ``generate_window_stream``; "write" means UPDATE of an existing key, with
  configurable fresh-key INSERT / DELETE fractions partitioning the write
  budget disjointly;
* the full **YCSB core suite A–F** (``YCSB`` + ``generate_ycsb_stream``) —
  the benchmark behind the paper's "up to 6.6x under YCSB" headline:

  ====  =======================  =====================================
  A     50% read / 50% update    Zipf(0.99) over the populated universe
  B     95% read /  5% update    same
  C     100% read                same
  D     95% read /  5% insert    reads follow the *latest* distribution
                                 (Zipf over recency behind the insert
                                 frontier)
  E     95% scan /  5% insert    scan start Zipf, length ~ U[1, scan_max]
                                 (count rides ``values`` — OpKind.SCAN)
  F     50% read / 50% RMW       each read-modify-write occupies two
                                 adjacent lanes: SEARCH then UPDATE of the
                                 same key (serialized by batch position)
  ====  =======================  =====================================

Keys are drawn Zipf(theta=0.99 by default) over a populated universe of
``n_keys`` (paper: 60M, 8-byte keys / 8-byte values).  D and E grow the
universe: INSERTs take distinct fresh keys at the frontier (``n_keys``
upward), and window w's reads/scans draw over the frontier as of the start
of window w, so every generated point read targets a key that exists.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.types import OpKind
from repro.workloads.zipf import ZipfSampler

__all__ = ["WorkloadSpec", "WORKLOADS", "generate_ops",
           "generate_window_stream", "YCSBSpec", "YCSB",
           "generate_ycsb_stream"]


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    name: str
    write_ratio: float
    read_ratio: float
    theta: float = 0.99
    insert_fraction: float = 0.0   # fraction of writes that are fresh-key INSERTs
    delete_fraction: float = 0.0   # fraction of writes that are DELETEs


WORKLOADS = {
    "write-intensive": WorkloadSpec("write-intensive", 0.50, 0.50),
    "read-intensive": WorkloadSpec("read-intensive", 0.05, 0.95),
    "write-only": WorkloadSpec("write-only", 1.00, 0.00),
}


@dataclasses.dataclass
class OpBatchNp:
    """Host-side generated op stream (numpy)."""

    kinds: np.ndarray   # (T,) uint8 OpKind
    keys: np.ndarray    # (T,) int64 key ids
    values: np.ndarray  # (T,) int64 payload (value id written by this op)
    clients: np.ndarray  # (T,) int32 issuing client id


def generate_ops(spec: WorkloadSpec, n_ops: int, n_keys: int, n_clients: int,
                 seed: int = 0, theta: float | None = None) -> OpBatchNp:
    """Generate a flat op stream; ops are interleaved round-robin over clients
    (client c issues ops c, c+n_clients, ... — matching closed-loop clients)."""
    df, inf = spec.delete_fraction, spec.insert_fraction
    if df + inf > 1.0:
        raise ValueError(
            f"delete_fraction ({df}) + insert_fraction ({inf}) must be <= 1")
    rng = np.random.default_rng(seed + 1)
    theta = spec.theta if theta is None else theta
    zipf = ZipfSampler(n_keys, theta, seed=seed)
    keys = zipf.sample(n_ops)
    kinds = np.full(n_ops, OpKind.SEARCH, dtype=np.uint8)
    u = rng.random(n_ops)
    is_write = u < spec.write_ratio
    kinds[is_write] = OpKind.UPDATE
    if df > 0 or inf > 0:
        # ONE draw partitions the write fraction disjointly:
        # [0, df) -> DELETE, [df, df+inf) -> INSERT, the rest stay UPDATE.
        v = rng.random(n_ops)
        kinds[is_write & (v < df)] = OpKind.DELETE
        is_ins = is_write & (v >= df) & (v < df + inf)
        if inf > 0:
            kinds[is_ins] = OpKind.INSERT
            # fresh keys beyond the populated universe
            keys = np.where(is_ins, rng.integers(n_keys, 2 * n_keys, n_ops),
                            keys)
    values = rng.integers(1, 2**31 - 1, size=n_ops, dtype=np.int64)
    clients = (np.arange(n_ops) % n_clients).astype(np.int32)
    return OpBatchNp(kinds=kinds, keys=keys, values=values, clients=clients)


def generate_window_stream(spec: WorkloadSpec, windows: int, n_ops: int,
                           n_keys: int, n_clients: int, seed: int = 0,
                           theta: float | None = None) -> OpBatchNp:
    """Generate ``windows`` stacked synchronization windows, arrays ``(W, n_ops)``.

    Window ``w`` is exactly ``generate_ops(spec, n_ops, ..., seed=seed + w)``,
    so a stream fed to ``repro.core.runner.run_windows`` replays the batches a
    per-window loop over ``generate_ops`` would have produced.
    """
    wins = [generate_ops(spec, n_ops, n_keys, n_clients, seed=seed + w,
                         theta=theta) for w in range(windows)]
    return OpBatchNp(kinds=np.stack([o.kinds for o in wins]),
                     keys=np.stack([o.keys for o in wins]),
                     values=np.stack([o.values for o in wins]),
                     clients=np.stack([o.clients for o in wins]))


# ---------------------------------------------------------------------------
# The YCSB core suite (A-F)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class YCSBSpec:
    """One YCSB core workload: request-type fractions + key distribution.

    Fractions are over *requests*; an ``rmw`` request (workload F) occupies
    two adjacent lanes (SEARCH then UPDATE of the same key), so its lane
    share is twice its request share.  ``latest`` switches reads to the
    recency distribution (workload D); ``scan_max`` bounds E's uniform
    scan-length draw.  Keep it <= the engine's static
    ``EngineConfig.scan_max``: the engine truncates longer runs
    (``Results.rows`` covers the clipped range only) and
    ``runner.modeled_latency`` clips its per-leaf bill to the same bound,
    so an oversized draw degrades to the engine's range, never diverges.
    """
    name: str
    read: float = 0.0
    update: float = 0.0
    insert: float = 0.0
    scan: float = 0.0
    rmw: float = 0.0
    theta: float = 0.99
    latest: bool = False
    scan_max: int = 16

    def __post_init__(self):
        tot = self.read + self.update + self.insert + self.scan + self.rmw
        if abs(tot - 1.0) > 1e-9:
            raise ValueError(f"request fractions must sum to 1, got {tot}")


YCSB = {
    "A": YCSBSpec("A", read=0.50, update=0.50),
    "B": YCSBSpec("B", read=0.95, update=0.05),
    "C": YCSBSpec("C", read=1.00),
    "D": YCSBSpec("D", read=0.95, insert=0.05, latest=True),
    "E": YCSBSpec("E", scan=0.95, insert=0.05),
    "F": YCSBSpec("F", read=0.50, rmw=0.50),
}


def generate_ycsb_stream(spec: YCSBSpec, windows: int, n_ops: int,
                         n_keys: int, n_clients: int, seed: int = 0,
                         theta: float | None = None) -> OpBatchNp:
    """Generate the full-suite op stream: ``(windows, n_ops)`` arrays.

    * INSERTs (D, E) take distinct fresh keys at the frontier (``n_keys``
      upward), one per insert — the caller must size the engine keyspace
      (``EngineConfig.n_slots``) for ``n_keys`` plus the expected inserts.
    * Window ``w``'s reads/scans draw over the frontier as of the *start*
      of window ``w``, so every point read targets an existing key.
    * D's reads draw a recency rank r ~ Zipf(theta) and touch key
      ``frontier - 1 - r`` — YCSB's "latest" distribution.
    * E's SCAN lanes carry their length (uniform on [1, scan_max]) in
      ``values``; lengths past the keyspace end are truncated by the engine.
    * F's RMW requests emit two adjacent lanes — SEARCH then UPDATE of the
      same key — serialized by batch position exactly like a client that
      reads, modifies, then writes.
    * ``clients`` records the closed-loop issuing client (round-robin over
      ``n_clients``), the same bookkeeping ``generate_ops`` emits for the
      simulator path; the engine path assigns CNs in
      ``runner.make_stream(n_cns=...)`` independently of this field.
    """
    theta = spec.theta if theta is None else theta
    frontier = n_keys
    kinds_w, keys_w, vals_w = [], [], []
    probs = np.array([spec.read, spec.update, spec.insert, spec.scan,
                      spec.rmw])
    for w in range(windows):
        rng = np.random.default_rng((seed, w))
        zipf = ZipfSampler(frontier, theta, seed=seed * 7919 + w)
        # request draw; RMW requests expand to 2 lanes, so draw n_ops
        # requests and truncate the expansion back to n_ops lanes
        req = rng.choice(5, size=n_ops, p=probs)
        lens = np.where(req == 4, 2, 1)
        lane_req = np.repeat(np.arange(n_ops), lens)[:n_ops]
        first = np.concatenate([[True], lane_req[1:] != lane_req[:-1]])
        rk = req[lane_req]
        kinds = np.full(n_ops, OpKind.SEARCH, dtype=np.uint8)
        kinds[rk == 1] = OpKind.UPDATE
        kinds[rk == 2] = OpKind.INSERT
        kinds[rk == 3] = OpKind.SCAN
        kinds[(rk == 4) & ~first] = OpKind.UPDATE      # RMW second lane
        # keys: one draw per request, shared by both RMW lanes
        if spec.latest:
            recency = zipf.sample(n_ops, scrambled=False)
            req_keys = frontier - 1 - recency
        else:
            req_keys = zipf.sample(n_ops)
        is_ins = rk == 2
        n_ins = int(is_ins.sum())
        keys = req_keys[lane_req]
        keys[is_ins] = frontier + np.arange(n_ins)     # distinct fresh keys
        values = rng.integers(1, 2**31 - 1, size=n_ops, dtype=np.int64)
        is_scan = kinds == OpKind.SCAN
        values[is_scan] = rng.integers(1, spec.scan_max + 1,
                                       size=int(is_scan.sum()))
        frontier += n_ins
        kinds_w.append(kinds)
        keys_w.append(keys)
        vals_w.append(values)
    clients = np.broadcast_to(np.arange(n_ops) % n_clients,
                              (windows, n_ops)).astype(np.int32)
    return OpBatchNp(kinds=np.stack(kinds_w), keys=np.stack(keys_w),
                     values=np.stack(vals_w), clients=clients.copy())
