"""YCSB-style workload generation (Cooper et al., SoCC'10).

The paper evaluates three mixes (Table 1):

* write-intensive: 50% SEARCH / 50% UPDATE-or-INSERT
* read-intensive:  95% SEARCH /  5% UPDATE-or-INSERT
* write-only:            100% UPDATE-or-INSERT

Keys are drawn Zipf(theta=0.99 by default) over a populated universe of
``n_keys`` (paper: 60M, 8-byte keys / 8-byte values).  "Write" means UPDATE of
an existing key, or INSERT when the drawn key does not exist (the paper's
definition, §5.1); with a fully-populated universe writes are UPDATEs, and a
configurable ``insert_fraction`` draws fresh keys beyond the populated range.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.types import OpKind
from repro.workloads.zipf import ZipfSampler

__all__ = ["WorkloadSpec", "WORKLOADS", "generate_ops", "generate_window_stream"]


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    name: str
    write_ratio: float
    read_ratio: float
    theta: float = 0.99
    insert_fraction: float = 0.0   # fraction of writes that are fresh-key INSERTs
    delete_fraction: float = 0.0   # fraction of writes that are DELETEs


WORKLOADS = {
    "write-intensive": WorkloadSpec("write-intensive", 0.50, 0.50),
    "read-intensive": WorkloadSpec("read-intensive", 0.05, 0.95),
    "write-only": WorkloadSpec("write-only", 1.00, 0.00),
}


@dataclasses.dataclass
class OpBatchNp:
    """Host-side generated op stream (numpy)."""

    kinds: np.ndarray   # (T,) uint8 OpKind
    keys: np.ndarray    # (T,) int64 key ids
    values: np.ndarray  # (T,) int64 payload (value id written by this op)
    clients: np.ndarray  # (T,) int32 issuing client id


def generate_ops(spec: WorkloadSpec, n_ops: int, n_keys: int, n_clients: int,
                 seed: int = 0, theta: float | None = None) -> OpBatchNp:
    """Generate a flat op stream; ops are interleaved round-robin over clients
    (client c issues ops c, c+n_clients, ... — matching closed-loop clients)."""
    df, inf = spec.delete_fraction, spec.insert_fraction
    if df + inf > 1.0:
        raise ValueError(
            f"delete_fraction ({df}) + insert_fraction ({inf}) must be <= 1")
    rng = np.random.default_rng(seed + 1)
    theta = spec.theta if theta is None else theta
    zipf = ZipfSampler(n_keys, theta, seed=seed)
    keys = zipf.sample(n_ops)
    kinds = np.full(n_ops, OpKind.SEARCH, dtype=np.uint8)
    u = rng.random(n_ops)
    is_write = u < spec.write_ratio
    kinds[is_write] = OpKind.UPDATE
    if df > 0 or inf > 0:
        # ONE draw partitions the write fraction disjointly:
        # [0, df) -> DELETE, [df, df+inf) -> INSERT, the rest stay UPDATE.
        v = rng.random(n_ops)
        kinds[is_write & (v < df)] = OpKind.DELETE
        is_ins = is_write & (v >= df) & (v < df + inf)
        if inf > 0:
            kinds[is_ins] = OpKind.INSERT
            # fresh keys beyond the populated universe
            keys = np.where(is_ins, rng.integers(n_keys, 2 * n_keys, n_ops),
                            keys)
    values = rng.integers(1, 2**31 - 1, size=n_ops, dtype=np.int64)
    clients = (np.arange(n_ops) % n_clients).astype(np.int32)
    return OpBatchNp(kinds=kinds, keys=keys, values=values, clients=clients)


def generate_window_stream(spec: WorkloadSpec, windows: int, n_ops: int,
                           n_keys: int, n_clients: int, seed: int = 0,
                           theta: float | None = None) -> OpBatchNp:
    """Generate ``windows`` stacked synchronization windows, arrays ``(W, n_ops)``.

    Window ``w`` is exactly ``generate_ops(spec, n_ops, ..., seed=seed + w)``,
    so a stream fed to ``repro.core.runner.run_windows`` replays the batches a
    per-window loop over ``generate_ops`` would have produced.
    """
    wins = [generate_ops(spec, n_ops, n_keys, n_clients, seed=seed + w,
                         theta=theta) for w in range(windows)]
    return OpBatchNp(kinds=np.stack([o.kinds for o in wins]),
                     keys=np.stack([o.keys for o in wins]),
                     values=np.stack([o.values for o in wins]),
                     clients=np.stack([o.clients for o in wins]))
