"""Recovery workload generators: op streams PLUS liveness schedules.

Each scenario emits the pair the recovery stack consumes — a ``(W, B)``
``OpBatchNp`` stream and a :class:`repro.recovery.liveness.LivenessSchedule`
over the same windows — modeled on the failure experiments of FUSEE
(client-crash repair) and DINOMO (elasticity):

* ``crash_storm`` — a fail-stop storm: a fraction of the CNs dies at one
  window and never returns.  The update mix keeps a compact cross-CN hot
  set, so the storm strands locks on queues that surviving writers are
  blocked behind — the §4.6 repair path under maximum pressure.
* ``rolling_restart`` — CN groups go down for a few windows each in a
  staggered wave (a fleet-wide binary rollout): every group's in-flight
  locks strand on the way down, and the group rejoins with no state to
  rebuild (credits and store are global).
* ``elastic_scale`` — membership as capacity management: the stream starts
  on half the CNs, scales up at one window (join strands nothing), then
  scales a quarter back down (leave == planned crash; same repair bill).

Traffic is the same skewed UPDATE/SEARCH mix the dynamic-contention
scenarios use, with the hot set strided across lanes so hot writers span
CNs (otherwise baseline local WC absorbs the queue and nothing strands).

DESIGN.md §8.4 (recovery benchmark): op stream + liveness schedule pairs for
the crash scenarios.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.core.types import OpKind
from repro.recovery.liveness import LivenessSchedule, crash, elastic, rolling
from repro.workloads.ycsb import OpBatchNp
from repro.workloads.zipf import ZipfSampler

__all__ = ["RecoveryScenario", "RECOVERY_SCENARIOS", "crash_storm",
           "rolling_restart", "elastic_scale"]


def _hot_mix(windows: int, n_ops: int, n_keys: int, n_clients: int,
             seed: int, *, write_ratio: float = 0.6, theta: float = 0.99,
             hot_keys: int = 8, hot_frac: float = 0.5) -> OpBatchNp:
    """Stationary skewed UPDATE/SEARCH mix with a strided cross-CN hot set."""
    rng = np.random.default_rng(seed + 71)
    zipf = ZipfSampler(n_keys, theta, seed=seed)
    keys = zipf.sample(windows * n_ops).reshape(windows, n_ops)
    hot_set = rng.permutation(n_keys)[:hot_keys]
    kinds = np.where(rng.random((windows, n_ops)) < write_ratio,
                     OpKind.UPDATE, OpKind.SEARCH).astype(np.uint8)
    for w in range(windows):
        hot = rng.random(n_ops) < hot_frac
        keys[w, hot] = rng.choice(hot_set, size=int(hot.sum()))
    # stride a hot UPDATE across lanes so every CN carries hot writers
    stride = max(n_ops // 64, 4)
    keys[:, ::stride] = hot_set[0]
    kinds[:, ::stride] = OpKind.UPDATE
    values = rng.integers(1, 2**31 - 1, size=(windows, n_ops), dtype=np.int64)
    clients = np.broadcast_to((np.arange(n_ops) % n_clients).astype(np.int32),
                              (windows, n_ops)).copy()
    return OpBatchNp(kinds=kinds, keys=keys.astype(np.int64), values=values,
                     clients=clients)


def crash_storm(windows: int, n_ops: int, n_keys: int, n_clients: int,
                n_cns: int, seed: int = 0, *, storm_frac: float = 0.25,
                crash_window: int | None = None,
                ) -> tuple[OpBatchNp, LivenessSchedule]:
    """``storm_frac`` of the CNs fail-stop at ``crash_window`` (default
    ``windows // 3``), spread across the CN id space so dead writers land in
    every hot queue."""
    if crash_window is None:
        crash_window = max(windows // 3, 1)
    rng = np.random.default_rng(seed + 101)
    n_dead = max(int(storm_frac * n_cns), 1)
    dead = rng.choice(n_cns, size=n_dead, replace=False)
    ops = _hot_mix(windows, n_ops, n_keys, n_clients, seed)
    return ops, crash(windows, n_cns, dead, crash_window)


def rolling_restart(windows: int, n_ops: int, n_keys: int, n_clients: int,
                    n_cns: int, seed: int = 0, *, down_windows: int = 1,
                    group: int | None = None, start: int = 1,
                    ) -> tuple[OpBatchNp, LivenessSchedule]:
    """Staggered restart wave: groups of ``group`` CNs (default: the fleet
    split over the post-``start`` windows) down ``down_windows`` each."""
    if group is None:
        usable = max(windows - start - down_windows, 1)
        group = max((n_cns * down_windows + usable - 1) // usable, 1)
    ops = _hot_mix(windows, n_ops, n_keys, n_clients, seed)
    return ops, rolling(windows, n_cns, down_windows=down_windows,
                        start=start, group=group)


def elastic_scale(windows: int, n_ops: int, n_keys: int, n_clients: int,
                  n_cns: int, seed: int = 0, *, join_window: int | None = None,
                  leave_window: int | None = None,
                  ) -> tuple[OpBatchNp, LivenessSchedule]:
    """Scale-up then scale-down: start on the first half of the CNs, the
    second half joins at ``join_window``, a quarter leaves at
    ``leave_window``."""
    if join_window is None:
        join_window = max(windows // 3, 1)
    if leave_window is None:
        leave_window = max(2 * windows // 3, join_window + 1)
    half, quarter = n_cns // 2, max(n_cns // 4, 1)
    ops = _hot_mix(windows, n_ops, n_keys, n_clients, seed)
    sched = elastic(
        windows, n_cns,
        events=[(join_window, range(half, n_cns), True),
                (leave_window, range(quarter), False)],
        initial_alive=range(half))
    return ops, sched


@dataclasses.dataclass(frozen=True)
class RecoveryScenario:
    """A registered recovery scenario: ``generate(windows, n_ops, n_keys,
    n_clients, n_cns, seed=0, **overrides) -> (ops, LivenessSchedule)``."""
    name: str
    generate: Callable[..., tuple[OpBatchNp, LivenessSchedule]]
    description: str = ""

    def populate_keys(self, n_keys: int) -> np.ndarray:
        return np.arange(n_keys)


RECOVERY_SCENARIOS = {
    "crash_storm": RecoveryScenario(
        "crash_storm", crash_storm,
        description="a quarter of the CNs fail-stop at one window; their "
                    "in-flight locks strand on the hot queues"),
    "rolling_restart": RecoveryScenario(
        "rolling_restart", rolling_restart,
        description="staggered down-for-k-windows restart wave over the "
                    "whole fleet; every group strands on the way down"),
    "elastic_scale": RecoveryScenario(
        "elastic_scale", elastic_scale,
        description="scale-up (join: strands nothing) then scale-down "
                    "(leave == planned crash: same repair bill)"),
}
