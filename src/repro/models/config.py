"""Model configuration shared by all 10 assigned architectures.

DESIGN.md §1 (models layer): the one ModelConfig dataclass all architecture
registries instantiate.
"""
from __future__ import annotations

import dataclasses

__all__ = ["ModelConfig"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encoder | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    qkv_bias: bool = False       # qwen2.5
    qk_norm: bool = False        # qwen3
    causal: bool = True          # False for encoder-only (hubert)
    tie_embed: bool = False
    rope_theta: float = 1e4
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared: int = 0            # shared experts (deepseek-moe)
    capacity_factor: float = 1.25
    # --- SSM (mamba2) ---
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_groups: int = 1
    conv_width: int = 4
    ssm_chunk: int = 256
    # --- hybrid (recurrentgemma): pattern unit = (rec, rec, attn) ---
    window: int = 0              # local-attention window
    d_rnn: int = 0               # 0 -> d_model
    # --- frontend stubs ---
    frontend: str = ""           # "" | "audio" | "vision"
    frontend_dim: int = 0
    n_patches: int = 0           # vision: tokens contributed by the image
    # --- numerics / perf knobs ---
    attn_chunk: int = 1024
    remat: str = "block"         # "block" | "none"
    moe_local_dispatch: bool = True   # per-data-shard MoE grouping (§Perf)
    # reduced smoke-config marker
    smoke: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def rnn_width(self) -> int:
        return self.d_rnn or self.d_model

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch serve a 500k-token context? (SSM / bounded window)"""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decode(self) -> bool:
        return self.family != "encoder"

    def param_count_estimate(self) -> int:
        d, f, v = self.d_model, self.d_ff, self.vocab
        hd = self.hd
        attn = d * hd * (self.n_heads + 2 * self.n_kv) + self.n_heads * hd * d
        if self.family == "moe":
            ffn = self.n_experts * 3 * d * f + d * self.n_experts \
                + self.n_shared * 3 * d * f
        elif self.family == "ssm":
            di, g, n, h = self.d_inner, self.ssm_groups, self.ssm_state, self.ssm_heads
            ffn = d * (2 * di + 2 * g * n + h) + di * d
            attn = 0
        else:
            ffn = 3 * d * f
        emb = v * d * (1 if self.tie_embed else 2)
        return self.n_layers * (attn + ffn) + emb
