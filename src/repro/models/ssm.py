"""Mamba-2 SSD (state-space duality) layer — chunked quadratic-within /
linear-across algorithm (Dao & Gu, arXiv:2405.21060 §6).

Train/prefill: O(S * L) with chunk length L (default 256); decode: O(1)
recurrent state (B, H, P, N).  Pure jnp; numerically validated against the
naive recurrence in tests.

DESIGN.md §1 (models layer): Mamba-2 SSD chunked scan layer.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = ["ssd_chunked", "ssd_decode_step", "ssd_naive"]


def _segsum(a):
    """a: (..., L) -> (..., L, L) lower-triangular cumulative sums:
    out[i, j] = sum(a[j+1..i]) for j < i; -inf above diagonal."""
    L = a.shape[-1]
    cs = jnp.cumsum(a, -1)
    d = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool), 0)
    return jnp.where(mask, d, -jnp.inf)


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_chunked(x, dt, A, B, C, D, *, chunk=256):
    """SSD forward.

    x: (b, s, h, p)   inputs per head
    dt: (b, s, h)     softplus-activated step sizes
    A: (h,)           negative state decay rates (A < 0)
    B, C: (b, s, g, n) input/output projections (g groups broadcast to h)
    D: (h,)           skip connection
    Returns (y (b,s,h,p), final_state (b,h,p,n)).
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    L = min(chunk, s)
    nc = s // L
    rep = h // g
    a = (dt * A[None, None, :]).astype(jnp.float32)          # (b,s,h) log-decay
    xb = (x * dt[..., None]).astype(jnp.float32)             # dt-scaled input
    Bc = jnp.repeat(B, rep, axis=2).astype(jnp.float32)      # (b,s,h,n)
    Cc = jnp.repeat(C, rep, axis=2).astype(jnp.float32)
    # chunked views: (b, nc, L, ...)
    ar = a.reshape(b, nc, L, h)
    xr = xb.reshape(b, nc, L, h, p)
    Br = Bc.reshape(b, nc, L, h, n)
    Cr = Cc.reshape(b, nc, L, h, n)
    # 1. intra-chunk (quadratic): y_diag = (C B^T  *  decay) x
    Ldec = jnp.exp(_segsum(ar.transpose(0, 1, 3, 2)))        # (b,nc,h,L,L)
    scores = jnp.einsum("bclhn,bcshn->bchls", Cr, Br)        # (b,nc,h,L,L)
    y_diag = jnp.einsum("bchls,bchls,bcshp->bclhp", scores, Ldec,
                        xr)
    # 2. chunk-final states: decay-weighted input summary per chunk
    a_cum = jnp.cumsum(ar, axis=2)                           # (b,nc,L,h)
    a_tail = a_cum[:, :, -1:, :] - a_cum                     # decay to chunk end
    states = jnp.einsum("bclhn,bclh,bclhp->bchpn", Br, jnp.exp(a_tail), xr)
    # 3. inter-chunk recurrence over nc (associative scan over chunks)
    a_tot = a_cum[:, :, -1, :]                               # (b,nc,h)

    def comb(left, right):
        sL, aL = left
        sR, aR = right
        return sR + sL * jnp.exp(aR)[..., None, None], aL + aR

    st_in, a_in = jax.lax.associative_scan(
        comb, (states, a_tot), axis=1)
    # state entering chunk c = scanned state of chunk c-1 (shift right)
    st_prev = jnp.concatenate(
        [jnp.zeros_like(st_in[:, :1]), st_in[:, :-1]], axis=1)  # (b,nc,h,p,n)
    # 4. inter-chunk contribution: C_t decay(t) state_prev
    y_off = jnp.einsum("bclhn,bclh,bchpn->bclhp", Cr, jnp.exp(a_cum), st_prev)
    y = (y_diag + y_off).reshape(b, s, h, p)
    y = y + x.astype(jnp.float32) * D[None, None, :, None]
    final = st_in[:, -1]                                     # (b,h,p,n)
    return y.astype(x.dtype), final


def ssd_decode_step(state, x_t, dt_t, A, B_t, C_t, D):
    """One recurrent step.  state: (b,h,p,n); x_t: (b,h,p); dt_t: (b,h);
    B_t/C_t: (b,g,n).  Returns (y_t (b,h,p), state')."""
    h = x_t.shape[1]
    g = B_t.shape[1]
    rep = h // g
    Bh = jnp.repeat(B_t, rep, axis=1).astype(jnp.float32)    # (b,h,n)
    Ch = jnp.repeat(C_t, rep, axis=1).astype(jnp.float32)
    da = jnp.exp((dt_t * A[None, :]).astype(jnp.float32))    # (b,h)
    xs = (x_t * dt_t[..., None]).astype(jnp.float32)         # (b,h,p)
    state = state * da[..., None, None] + xs[..., None] * Bh[:, :, None, :]
    y = jnp.einsum("bhpn,bhn->bhp", state, Ch)
    y = y + x_t.astype(jnp.float32) * D[None, :, None]
    return y.astype(x_t.dtype), state


def ssd_naive(x, dt, A, B, C, D):
    """O(S) sequential reference (oracle for tests)."""
    b, s, h, p = x.shape
    n = B.shape[3]
    state = jnp.zeros((b, h, p, n), jnp.float32)
    ys = []
    for t in range(s):
        y, state = ssd_decode_step(state, x[:, t], dt[:, t], A, B[:, t],
                                   C[:, t], D)
        ys.append(y)
    return jnp.stack(ys, 1), state
