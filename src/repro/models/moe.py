"""Mixture-of-Experts layer: shared + routed experts with top-k routing and
sort-based capacity grouping.

The dispatch is deliberately the SAME primitive as CIDER's global write
combining (DESIGN.md §2.1): flatten (token, expert) assignments, sort by
expert, rank-within-run, and gather each expert's tokens into a contiguous
(E, C, D) block — one grouped matmul per expert instead of per-token traffic.
Tokens beyond an expert's capacity are dropped (GShard-style); capacity
defaults to 1.25x the balanced share.

Sharding: experts -> "model" (EP); tokens -> ("pod","data").  With
``rows > 1`` (the §Perf optimization, default in the launchers) the grouping
runs PER DATA-SHARD ROW: each row's (E, C_row, D) dispatch buffer is built
from tokens already resident on that data shard, so the dispatch gather is
collective-free; the only cross-chip traffic left is the per-layer psum of
the combined outputs over the model axis.  The baseline (rows=1) sorts
globally and lets XLA SPMD all-gather the token table — the dry-run shows
that difference as ~100x collective bytes (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.dist.ctx import shard

__all__ = ["route_topk", "moe_ffn", "moe_capacity"]


def moe_capacity(n_tokens: int, n_experts: int, top_k: int,
                 factor: float = 1.25) -> int:
    c = int(n_tokens * top_k * factor / n_experts) + 1
    # 128-aligned: MXU tiles + divisible by the 16-way data axis so the
    # (E, C, D) dispatch buffer shards over experts AND capacity
    return max(128, (c + 127) // 128 * 128)


def route_topk(logits, top_k):
    """logits: (T, E) -> (weights (T,k) softmaxed over chosen, experts (T,k))."""
    gates = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    w, ix = jax.lax.top_k(gates, top_k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    return w, ix


def _group_by_expert(expert_ids, n_experts, capacity):
    """expert_ids: (T*k,) -> (slot (T*k,) destination in [0, E*C) or E*C when
    dropped).  Sort-based ranking — the wc_combine primitive."""
    tk = expert_ids.shape[0]
    pos = jnp.arange(tk, dtype=jnp.int32)
    order = jnp.lexsort((pos, expert_ids))
    es = expert_ids[order]
    is_first = jnp.concatenate([jnp.ones((1,), bool), es[1:] != es[:-1]])
    seg_start = jax.lax.cummax(jnp.where(is_first, pos, 0))
    rank_sorted = pos - seg_start
    rank = jnp.zeros((tk,), jnp.int32).at[order].set(rank_sorted)
    dropped = rank >= capacity
    slot = jnp.where(dropped, n_experts * capacity,
                     expert_ids * capacity + rank)
    return slot.astype(jnp.int32), dropped


def _routed_ffn(x, router_w, experts_gate, experts_up, experts_down,
                top_k: int, cap: int):
    """Dispatch + grouped expert matmuls + combine for one token block."""
    t, d = x.shape
    e = experts_gate.shape[0]
    logits = x @ router_w                                   # (T, E)
    w, ix = route_topk(logits, top_k)                       # (T, k)
    me = jnp.mean(jax.nn.softmax(logits.astype(jnp.float32), -1), axis=0)
    ce = jnp.zeros((e,), jnp.float32).at[ix.reshape(-1)].add(
        jnp.ones((t * top_k,), jnp.float32)) / (t * top_k)
    aux = e * jnp.sum(me * ce)
    # ---- dispatch: sort-group (the global-WC primitive) ----
    flat_e = ix.reshape(-1).astype(jnp.int32)               # (T*k,)
    slot, dropped = _group_by_expert(flat_e, e, cap)
    src_tok = jnp.repeat(jnp.arange(t, dtype=jnp.int32), top_k)
    tok_of_slot = jnp.full((e * cap + 1,), 0, jnp.int32).at[slot].set(
        src_tok, mode="drop")
    filled = jnp.zeros((e * cap + 1,), bool).at[slot].set(
        ~dropped, mode="drop")
    xg = jnp.where(filled[:e * cap, None], x[tok_of_slot[:e * cap]], 0)
    xg = xg.reshape(e, cap, d)
    # ---- expert computation: grouped matmuls ----
    h = jnp.einsum("ecd,edf->ecf", xg, experts_gate)
    h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", xg, experts_up)
    yg = jnp.einsum("ecf,efd->ecd", h, experts_down)        # (E, C, D)
    # ---- combine: weighted scatter-add back to tokens ----
    wk = w.reshape(-1).astype(yg.dtype)
    y_slot = yg.reshape(e * cap, d)
    contrib = y_slot[jnp.where(dropped, 0, slot)] * jnp.where(
        dropped, 0.0, wk)[:, None]
    y = jnp.zeros((t, d), yg.dtype).at[src_tok].add(contrib)
    return y, aux


@functools.partial(jax.jit, static_argnames=("top_k", "capacity_factor",
                                             "rows"))
def moe_ffn(x, router_w, experts_gate, experts_up, experts_down,
            shared_gate=None, shared_up=None, shared_down=None,
            *, top_k: int, capacity_factor: float = 1.25, rows: int = 1):
    """x: (T, D). experts_*: (E, D, F) / (E, F, D). Returns (T, D), aux_loss."""
    t, d = x.shape
    e = experts_gate.shape[0]
    x = shard(x, ("act_tokens", None))
    if rows > 1 and t % rows == 0:
        # §Perf: per-data-shard-row grouping — dispatch stays shard-local
        tl = t // rows
        cap = moe_capacity(tl, e, top_k, capacity_factor)
        xr = shard(x.reshape(rows, tl, d), ("act_rows", None, None))
        fn = functools.partial(_routed_ffn, router_w=router_w,
                               experts_gate=experts_gate,
                               experts_up=experts_up,
                               experts_down=experts_down,
                               top_k=top_k, cap=cap)
        y, aux = jax.vmap(fn)(xr)
        y = shard(y, ("act_rows", None, None)).reshape(t, d)
        aux = aux.mean()
    else:
        cap = moe_capacity(t, e, top_k, capacity_factor)
        y, aux = _routed_ffn(x, router_w, experts_gate, experts_up,
                             experts_down, top_k, cap)
    y = shard(y, ("act_tokens", None))
    if shared_gate is not None:
        hs = jax.nn.silu(x @ shared_gate) * (x @ shared_up)
        y = y + hs @ shared_down
    return y.astype(x.dtype), aux
