"""Shared model building blocks: RMSNorm, RoPE, initializers with logical
sharding axes.

Every parameter is created through ``Param``/``init_leaf`` which records a
tuple of *logical axis names* alongside the array; ``repro.dist.sharding``
maps logical axes -> mesh axes (FSDP/TP/EP) for any mesh shape.

DESIGN.md §3.2 (logical-axis rules): boxed Params + shared building blocks
carrying logical sharding axes.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["Param", "ParamTree", "rms_norm", "rope", "apply_rope",
           "init_dense", "init_embed", "init_scalar", "unbox", "axes_of",
           "count_params"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Param:
    """An array + its logical sharding axes (a MaxText-style 'boxed' param)."""
    value: jax.Array
    axes: tuple = dataclasses.field(metadata=dict(static=True))


ParamTree = Any


def unbox(tree: ParamTree):
    return jax.tree.map(lambda p: p.value if isinstance(p, Param) else p, tree,
                        is_leaf=lambda x: isinstance(x, Param))


def axes_of(tree: ParamTree):
    return jax.tree.map(lambda p: p.axes if isinstance(p, Param) else None, tree,
                        is_leaf=lambda x: isinstance(x, Param))


def init_dense(key, in_dim, out_dims, axes, dtype=jnp.bfloat16, scale=None):
    """Fan-in scaled truncated-normal init for a (in, *out) weight."""
    shape = (in_dim,) + tuple(out_dims)
    scale = scale if scale is not None else 1.0 / np.sqrt(in_dim)
    w = jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * scale
    return Param(w.astype(dtype), axes)


def init_embed(key, vocab, d, dtype=jnp.bfloat16):
    w = jax.random.normal(key, (vocab, d), jnp.float32) * 0.02
    return Param(w.astype(dtype), ("vocab", "embed"))


def init_scalar(shape, axes, fill=1.0, dtype=jnp.float32):
    return Param(jnp.full(shape, fill, dtype), axes)


def rms_norm(x, gamma, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * gamma).astype(dt)


def rope(positions, head_dim, theta=1e4):
    """Rotary embedding tables: returns (sin, cos) of shape (*pos, head_dim/2)."""
    freqs = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                             / head_dim))
    angles = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.sin(angles), jnp.cos(angles)


def apply_rope(x, sin, cos):
    """x: (..., seq, heads, head_dim); sin/cos: (seq, head_dim/2)."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    s = sin[..., :, None, :]
    c = cos[..., :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], -1)


def count_params(tree: ParamTree) -> int:
    leaves = jax.tree.leaves(unbox(tree))
    return int(sum(np.prod(l.shape) for l in leaves))
