"""Block definitions + parameter init for every assigned family, with
scan-over-layers (O(1) HLO in depth) and configurable remat.

Families:
  dense   — GQA attention (+qkv_bias/+qk_norm variants) + SwiGLU
  moe     — GQA attention + shared/routed top-k experts
  ssm     — Mamba-2 (SSD) mixing, no attention
  hybrid  — RecurrentGemma: (rec, rec, local-attn) triples + MLP each layer
  encoder — bidirectional dense (hubert backbone)
  vlm     — dense decoder fed by a vision-stub prefix (phi-3-vision backbone)

DESIGN.md §1 (models layer): block assembly + scan-over-layers for every
assigned family.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import rglru as rg
from repro.models import ssm as ssd
from repro.models.attention import decode_attention, gqa_attention
from repro.models.common import (Param, apply_rope, init_dense, init_embed,
                                 init_scalar, rms_norm, rope)
from repro.models.config import ModelConfig
from repro.dist.ctx import shard

__all__ = ["init_params", "forward", "decode_step", "init_decode_state"]


# ===========================================================================
# Parameter init (all stacked layers carry a leading "layers" axis)
# ===========================================================================

def _init_attn(cfg: ModelConfig, key, L) -> dict:
    d, h, k_, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.hd
    ks = jax.random.split(key, 8)
    p = {
        "ln1": init_scalar((L, d), ("layers", "embed")),
        "wq": init_dense(ks[0], d, (h, hd), ("layers", "embed", "heads", "head_dim")),
        "wk": init_dense(ks[1], d, (k_, hd), ("layers", "embed", "kv", "head_dim")),
        "wv": init_dense(ks[2], d, (k_, hd), ("layers", "embed", "kv", "head_dim")),
        "wo": init_dense(ks[3], h * hd, (d,), ("layers", "heads", "embed")),
    }
    # stack leading layer axis onto dense inits
    for i, name in enumerate(("wq", "wk", "wv", "wo")):
        w = p[name]
        stacked = jax.random.truncated_normal(
            jax.random.fold_in(ks[4], i), -2.0, 2.0,
            (L,) + w.value.shape, jnp.float32) * (1.0 / (d ** 0.5))
        p[name] = Param(stacked.astype(jnp.bfloat16), w.axes)
    if cfg.qkv_bias:
        p["bq"] = init_scalar((L, h, hd), ("layers", "heads", "head_dim"), 0.0)
        p["bk"] = init_scalar((L, k_, hd), ("layers", "kv", "head_dim"), 0.0)
        p["bv"] = init_scalar((L, k_, hd), ("layers", "kv", "head_dim"), 0.0)
    if cfg.qk_norm:
        p["qnorm"] = init_scalar((L, hd), ("layers", "head_dim"))
        p["knorm"] = init_scalar((L, hd), ("layers", "head_dim"))
    return p


def _init_mlp(cfg: ModelConfig, key, L) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)

    def mk(k, shape, axes, fan_in):
        w = jax.random.truncated_normal(k, -2.0, 2.0, (L,) + shape,
                                        jnp.float32) / (fan_in ** 0.5)
        return Param(w.astype(jnp.bfloat16), axes)

    return {
        "ln2": init_scalar((L, d), ("layers", "embed")),
        "wi_gate": mk(ks[0], (d, f), ("layers", "embed", "mlp"), d),
        "wi_up": mk(ks[1], (d, f), ("layers", "embed", "mlp"), d),
        "wo_mlp": mk(ks[2], (f, d), ("layers", "mlp", "embed"), f),
    }


def _init_moe(cfg: ModelConfig, key, L) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 7)

    def mk(k, shape, axes, fan_in):
        w = jax.random.truncated_normal(k, -2.0, 2.0, (L,) + shape,
                                        jnp.float32) / (fan_in ** 0.5)
        return Param(w.astype(jnp.bfloat16), axes)

    p = {
        "ln2": init_scalar((L, d), ("layers", "embed")),
        "router": mk(ks[0], (d, e), ("layers", "embed", "experts"), d),
        "eg": mk(ks[1], (e, d, f), ("layers", "experts", "embed", "mlp"), d),
        "eu": mk(ks[2], (e, d, f), ("layers", "experts", "embed", "mlp"), d),
        "ed": mk(ks[3], (e, f, d), ("layers", "experts", "mlp", "embed"), f),
    }
    if cfg.n_shared:
        fs = f * cfg.n_shared
        p["sg"] = mk(ks[4], (d, fs), ("layers", "embed", "mlp"), d)
        p["su"] = mk(ks[5], (d, fs), ("layers", "embed", "mlp"), d)
        p["sd"] = mk(ks[6], (fs, d), ("layers", "mlp", "embed"), fs)
    return p


def _init_ssm(cfg: ModelConfig, key, L) -> dict:
    d = cfg.d_model
    di, g, n, h = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    d_in = 2 * di + 2 * g * n + h           # z, x, B, C, dt
    conv_dim = di + 2 * g * n
    ks = jax.random.split(key, 4)

    def mk(k, shape, axes, fan_in):
        w = jax.random.truncated_normal(k, -2.0, 2.0, (L,) + shape,
                                        jnp.float32) / (fan_in ** 0.5)
        return Param(w.astype(jnp.bfloat16), axes)

    a_init = jnp.log(jnp.broadcast_to(
        jnp.linspace(1.0, 16.0, h, dtype=jnp.float32), (L, h)))
    return {
        "ln1": init_scalar((L, d), ("layers", "embed")),
        "in_proj": mk(ks[0], (d, d_in), ("layers", "embed", "mlp"), d),
        "conv_w": mk(ks[1], (cfg.conv_width, conv_dim),
                     ("layers", "conv", "mlp"), cfg.conv_width),
        "A_log": Param(a_init, ("layers", "heads")),
        "Dskip": init_scalar((L, h), ("layers", "heads")),
        "dt_bias": init_scalar((L, h), ("layers", "heads"), 0.0),
        "ssm_norm": init_scalar((L, di), ("layers", "mlp")),
        "out_proj": mk(ks[2], (di, d), ("layers", "mlp", "embed"), di),
    }


def _init_rec(cfg: ModelConfig, key, L) -> dict:
    d, r = cfg.d_model, cfg.rnn_width
    ks = jax.random.split(key, 6)

    def mk(k, shape, axes, fan_in):
        w = jax.random.truncated_normal(k, -2.0, 2.0, (L,) + shape,
                                        jnp.float32) / (fan_in ** 0.5)
        return Param(w.astype(jnp.bfloat16), axes)

    lam = jnp.broadcast_to(jnp.linspace(0.9, 4.0, r, dtype=jnp.float32), (L, r))
    return {
        "ln1": init_scalar((L, d), ("layers", "embed")),
        "wx": mk(ks[0], (d, r), ("layers", "embed", "mlp"), d),
        "wgate": mk(ks[1], (d, r), ("layers", "embed", "mlp"), d),
        "conv": mk(ks[2], (cfg.conv_width, r), ("layers", "conv", "mlp"),
                   cfg.conv_width),
        "w_input": mk(ks[3], (r, r), ("layers", "mlp", "heads"), r),
        "w_rec": mk(ks[4], (r, r), ("layers", "mlp", "heads"), r),
        "lam": Param(lam, ("layers", "mlp")),
        "wy": mk(ks[5], (r, d), ("layers", "mlp", "embed"), r),
    }


def init_params(cfg: ModelConfig, key) -> dict[str, Any]:
    ks = jax.random.split(key, 10)
    params: dict[str, Any] = {
        "embed": init_embed(ks[0], cfg.vocab, cfg.d_model),
        "final_norm": init_scalar((cfg.d_model,), ("embed",)),
    }
    if not cfg.tie_embed:
        params["lm_head"] = init_dense(
            ks[1], cfg.d_model, (cfg.vocab,), ("embed", "vocab"))
    if cfg.frontend:
        params["front_proj"] = init_dense(
            ks[2], cfg.frontend_dim, (cfg.d_model,), ("front", "embed"))
    L = cfg.n_layers
    if cfg.family in ("dense", "encoder", "vlm"):
        params["blocks"] = {**_init_attn(cfg, ks[3], L),
                            **_init_mlp(cfg, ks[4], L)}
    elif cfg.family == "moe":
        params["blocks"] = {**_init_attn(cfg, ks[3], L),
                            **_init_moe(cfg, ks[4], L)}
    elif cfg.family == "ssm":
        params["blocks"] = _init_ssm(cfg, ks[3], L)
    elif cfg.family == "hybrid":
        nt, rem = divmod(L, 3)
        params["blocks"] = {
            "rec1": {**_init_rec(cfg, ks[3], nt), **_init_mlp(cfg, ks[4], nt)},
            "rec2": {**_init_rec(cfg, ks[5], nt), **_init_mlp(cfg, ks[6], nt)},
            "attn": {**_init_attn(cfg, ks[7], nt), **_init_mlp(cfg, ks[8], nt)},
        }
        if rem:
            params["tail"] = {**_init_rec(cfg, ks[9], rem),
                              **_init_mlp(cfg, jax.random.fold_in(ks[9], 1), rem)}
    else:
        raise ValueError(cfg.family)
    return params


# ===========================================================================
# Forward blocks (operate on unboxed arrays)
# ===========================================================================

def _attn_fwd(cfg: ModelConfig, x, blk, sin, cos, *, window=0):
    h, k_, hd = cfg.n_heads, cfg.n_kv, cfg.hd
    x = shard(x, ("act_batch", None, None))
    xn = rms_norm(x, blk["ln1"])
    q = shard(jnp.einsum("bsd,dhe->bshe", xn, blk["wq"]),
              ("act_batch", None, "act_heads", None))
    k = shard(jnp.einsum("bsd,dke->bske", xn, blk["wk"]),
              ("act_batch", None, "act_kv", None))
    v = shard(jnp.einsum("bsd,dke->bske", xn, blk["wv"]),
              ("act_batch", None, "act_kv", None))
    if cfg.qkv_bias:
        q = q + blk["bq"]
        k = k + blk["bk"]
        v = v + blk["bv"]
    if cfg.qk_norm:
        q = rms_norm(q, blk["qnorm"])
        k = rms_norm(k, blk["knorm"])
    q = apply_rope(q, sin, cos).astype(x.dtype)
    k = apply_rope(k, sin, cos).astype(x.dtype)
    v = v.astype(x.dtype)
    att = gqa_attention(q, k, v, causal=cfg.causal, window=window,
                        chunk=cfg.attn_chunk)
    out = jnp.einsum("bshe,hed->bsd", att,
                     blk["wo"].reshape(h, hd, cfg.d_model))
    return x + out, (k, v)


def _mlp_fwd(cfg, x, blk):
    xn = rms_norm(x, blk["ln2"])
    hgate = jax.nn.silu(xn @ blk["wi_gate"]) * (xn @ blk["wi_up"])
    hgate = shard(hgate, ("act_batch", None, "act_mlp"))
    return x + hgate @ blk["wo_mlp"]


def _moe_fwd(cfg: ModelConfig, x, blk):
    from repro.dist.ctx import current_mesh
    from repro.models.moe import moe_ffn
    b, s, d = x.shape
    mesh = current_mesh()
    rows = 1
    if mesh is not None and cfg.moe_local_dispatch:
        rows = mesh.shape.get("pod", 1) * mesh.shape.get("data", 1)
    xn = rms_norm(x, blk["ln2"]).reshape(b * s, d)
    y, aux = moe_ffn(
        xn, blk["router"], blk["eg"], blk["eu"], blk["ed"],
        blk.get("sg"), blk.get("su"), blk.get("sd"),
        top_k=cfg.top_k, capacity_factor=cfg.capacity_factor,
        rows=rows if (b * s) % rows == 0 else 1)
    return x + y.reshape(b, s, d), aux


def _ssm_fwd(cfg: ModelConfig, x, blk):
    di, g, n, h = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    p = cfg.ssm_headdim
    b, s, d = x.shape
    x = shard(x, ("act_batch", None, None))
    xn = rms_norm(x, blk["ln1"])
    zxbcdt = shard(xn @ blk["in_proj"], ("act_batch", None, "act_mlp"))
    z, xin, B, C, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + g * n, 2 * di + 2 * g * n], axis=-1)
    conv_in = jnp.concatenate([xin, B, C], -1)
    conv_out = jax.nn.silu(rg.causal_conv1d(conv_in, blk["conv_w"]))
    xin, B, C = jnp.split(conv_out, [di, di + g * n], axis=-1)
    dt_a = jax.nn.softplus(dt.astype(jnp.float32) + blk["dt_bias"])
    y, _ = ssd.ssd_chunked(
        xin.reshape(b, s, h, p), dt_a, -jnp.exp(blk["A_log"]),
        B.reshape(b, s, g, n), C.reshape(b, s, g, n), blk["Dskip"],
        chunk=cfg.ssm_chunk)
    y = y.reshape(b, s, di)
    y = rms_norm(y * jax.nn.silu(z), blk["ssm_norm"])
    return x + y @ blk["out_proj"]


def _rec_fwd(cfg: ModelConfig, x, blk):
    x = shard(x, ("act_batch", None, None))
    xn = rms_norm(x, blk["ln1"])
    gate = jax.nn.gelu(xn @ blk["wgate"])
    u = rg.causal_conv1d(xn @ blk["wx"], blk["conv"])
    y, _ = rg.rglru_scan(u, blk["w_input"], blk["w_rec"], blk["lam"])
    x = x + (gate * y) @ blk["wy"]
    return _mlp_fwd(cfg, x, blk)


# ===========================================================================
# Full forward (training / prefill)
# ===========================================================================

def _scan(fn, x, blocks, cfg, extra=0.0):
    if cfg.remat == "block":
        fn = jax.checkpoint(fn)

    def body(carry, blk):
        x, aux = carry
        x, a = fn(x, blk)
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), blocks)
    return x, aux


def forward(cfg: ModelConfig, params, tokens, frontend_feats=None,
            positions=None, return_cache=False):
    """Training / prefill forward.

    tokens: (B, S_text) int32; frontend_feats: (B, S_front, F) for
    audio/vision stubs (prepended).  Returns (logits, aux_loss, caches) —
    caches is a per-layer (k, v) stack for attention families when
    ``return_cache`` (prefill), else None.
    """
    emb = params["embed"]
    x = shard(emb[tokens], ("act_batch", None, None))
    if cfg.frontend:
        front = frontend_feats @ params["front_proj"]
        x = front.astype(x.dtype) if cfg.family == "encoder" \
            else jnp.concatenate([front.astype(x.dtype), x], 1)
    b, s, d = x.shape
    pos = jnp.arange(s) if positions is None else positions
    sin, cos = rope(pos, cfg.hd, cfg.rope_theta)

    caches = None
    if cfg.family in ("dense", "encoder", "vlm", "moe"):
        def blk_fn(xx, blk):
            xx, kv = _attn_fwd(cfg, xx, blk, sin, cos)
            if cfg.family == "moe":
                xx, aux = _moe_fwd(cfg, xx, blk)
            else:
                xx = _mlp_fwd(cfg, xx, blk)
                aux = jnp.zeros((), jnp.float32)
            if return_cache:
                return xx, (aux, kv)
            return xx, (aux, None)

        if cfg.remat == "block":
            blk_fn = jax.checkpoint(blk_fn)

        def body(carry, blk):
            xx, aux = carry
            xx, (a, kv) = blk_fn(xx, blk)
            return (xx, aux + a), kv

        (x, aux), caches = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)), params["blocks"])
    elif cfg.family == "ssm":
        x, aux = _scan(lambda xx, blk: (_ssm_fwd(cfg, xx, blk),
                                        jnp.zeros((), jnp.float32)),
                       x, params["blocks"], cfg)
    elif cfg.family == "hybrid":
        def triple(xx, blks):
            b1, b2, b3 = blks
            xx = _rec_fwd(cfg, xx, b1)
            xx = _rec_fwd(cfg, xx, b2)
            xx, kv = _attn_fwd(cfg, xx, b3, sin, cos, window=cfg.window)
            xx = _mlp_fwd(cfg, xx, b3)
            return xx, (jnp.zeros((), jnp.float32), kv if return_cache else None)

        if cfg.remat == "block":
            triple = jax.checkpoint(triple)

        def body(carry, blks):
            xx, aux = carry
            xx, (a, kv) = triple(xx, blks)
            return (xx, aux + a), kv

        (x, aux), caches = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)),
            (params["blocks"]["rec1"], params["blocks"]["rec2"],
             params["blocks"]["attn"]))
        if "tail" in params:
            def tail_body(carry, blk):
                return _rec_fwd(cfg, carry, blk), None
            x, _ = jax.lax.scan(tail_body, x, params["tail"])
    else:
        raise ValueError(cfg.family)

    x = rms_norm(x, params["final_norm"])
    head = params["embed"].T if cfg.tie_embed else params["lm_head"]
    logits = shard(x @ head, ("act_batch", None, "act_vocab"))
    return logits, aux, (caches if return_cache else None)


# ===========================================================================
# Decode (single-token, stateful)
# ===========================================================================

def init_decode_state(cfg: ModelConfig, batch: int, smax: int):
    """Zero-filled decode state; shapes double as the dry-run specs."""
    L = cfg.n_layers
    hd, k_ = cfg.hd, cfg.n_kv
    bf = jnp.bfloat16
    if cfg.family in ("dense", "vlm", "moe"):
        return {"k": jnp.zeros((L, batch, smax, k_, hd), bf),
                "v": jnp.zeros((L, batch, smax, k_, hd), bf)}
    if cfg.family == "ssm":
        h, p, n = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
        cd = cfg.d_inner + 2 * cfg.ssm_groups * n
        return {"ssm": jnp.zeros((L, batch, h, p, n), jnp.float32),
                "conv": jnp.zeros((L, batch, cfg.conv_width - 1, cd), bf)}
    if cfg.family == "hybrid":
        nt, rem = divmod(L, 3)
        w = min(cfg.window, smax)
        r = cfg.rnn_width
        st = {"h1": jnp.zeros((nt, batch, r), jnp.float32),
              "h2": jnp.zeros((nt, batch, r), jnp.float32),
              "c1": jnp.zeros((nt, batch, cfg.conv_width - 1, r), bf),
              "c2": jnp.zeros((nt, batch, cfg.conv_width - 1, r), bf),
              "k": jnp.zeros((nt, batch, w, k_, hd), bf),
              "v": jnp.zeros((nt, batch, w, k_, hd), bf)}
        if rem:
            st["ht"] = jnp.zeros((rem, batch, r), jnp.float32)
            st["ct"] = jnp.zeros((rem, batch, cfg.conv_width - 1, r), bf)
        return st
    raise ValueError(f"{cfg.family} has no decode state")


def _attn_decode(cfg, x, blk, state_k, state_v, pos, sin, cos, window=0):
    h, k_, hd = cfg.n_heads, cfg.n_kv, cfg.hd
    xn = rms_norm(x, blk["ln1"])
    q = jnp.einsum("bsd,dhe->bshe", xn, blk["wq"])
    k = jnp.einsum("bsd,dke->bske", xn, blk["wk"])
    v = jnp.einsum("bsd,dke->bske", xn, blk["wv"])
    if cfg.qkv_bias:
        q, k, v = q + blk["bq"], k + blk["bk"], v + blk["bv"]
    if cfg.qk_norm:
        q = rms_norm(q, blk["qnorm"])
        k = rms_norm(k, blk["knorm"])
    q = apply_rope(q, sin, cos).astype(x.dtype)
    k = apply_rope(k, sin, cos).astype(x.dtype)
    v = v.astype(x.dtype)
    smax = state_k.shape[1]
    slot = pos % smax if window else jnp.minimum(pos, smax - 1)
    state_k = jax.lax.dynamic_update_slice(
        state_k, k, (0, slot, 0, 0))
    state_v = jax.lax.dynamic_update_slice(
        state_v, v, (0, slot, 0, 0))
    length = jnp.minimum(pos + 1, smax) if window else pos + 1
    att = decode_attention(q, state_k, state_v, length)
    out = jnp.einsum("bshe,hed->bsd", att,
                     blk["wo"].reshape(h, hd, cfg.d_model))
    return x + out, state_k, state_v


def _ssm_decode(cfg, x, blk, st_ssm, st_conv):
    di, g, n, h = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    p = cfg.ssm_headdim
    b = x.shape[0]
    xn = rms_norm(x[:, 0, :], blk["ln1"])
    zxbcdt = xn @ blk["in_proj"]
    z, xin, B, C, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + g * n, 2 * di + 2 * g * n], axis=-1)
    conv_in = jnp.concatenate([xin, B, C], -1)
    cy, st_conv = rg.conv1d_step(st_conv, conv_in, blk["conv_w"])
    cy = jax.nn.silu(cy)
    xin, B, C = jnp.split(cy, [di, di + g * n], axis=-1)
    dt_a = jax.nn.softplus(dt.astype(jnp.float32) + blk["dt_bias"])
    y, st_ssm = ssd.ssd_decode_step(
        st_ssm, xin.reshape(b, h, p), dt_a, -jnp.exp(blk["A_log"]),
        B.reshape(b, g, n), C.reshape(b, g, n), blk["Dskip"])
    y = y.reshape(b, di)
    y = rms_norm(y * jax.nn.silu(z), blk["ssm_norm"])
    return x + (y @ blk["out_proj"])[:, None, :], st_ssm, st_conv


def _rec_decode(cfg, x, blk, h_st, c_st):
    xn = rms_norm(x[:, 0, :], blk["ln1"])
    gate = jax.nn.gelu(xn @ blk["wgate"])
    u, c_st = rg.conv1d_step(c_st, xn @ blk["wx"], blk["conv"])
    y, h_st = rg.rglru_step(h_st, u, blk["w_input"], blk["w_rec"], blk["lam"])
    x = x + ((gate * y.astype(gate.dtype)) @ blk["wy"])[:, None, :]
    xn2 = rms_norm(x, blk["ln2"])
    hg = jax.nn.silu(xn2 @ blk["wi_gate"]) * (xn2 @ blk["wi_up"])
    return x + hg @ blk["wo_mlp"], h_st, c_st


def decode_step(cfg: ModelConfig, params, state, token, pos):
    """One decode step.  token: (B, 1) int32; pos: () int32 — current length.
    Returns (logits (B, 1, V), state')."""
    x = params["embed"][token]
    sin, cos = rope(pos[None] if pos.ndim == 0 else pos, cfg.hd, cfg.rope_theta)
    if cfg.family in ("dense", "vlm", "moe"):
        def body(xx, inputs):
            blk, kc, vc = inputs
            xx, kc, vc = _attn_decode(cfg, xx, blk, kc, vc, pos, sin, cos)
            if cfg.family == "moe":
                xx, _ = _moe_fwd(cfg, xx, blk)
            else:
                xx = _mlp_fwd(cfg, xx, blk)
            return xx, (kc, vc)

        x, (k2, v2) = jax.lax.scan(body, x,
                                   (params["blocks"], state["k"], state["v"]))
        state = {"k": k2, "v": v2}
    elif cfg.family == "ssm":
        def body(xx, inputs):
            blk, st, cv = inputs
            xx, st, cv = _ssm_decode(cfg, xx, blk, st, cv)
            return xx, (st, cv)

        x, (s2, c2) = jax.lax.scan(body, x, (params["blocks"], state["ssm"],
                                             state["conv"]))
        state = {"ssm": s2, "conv": c2}
    elif cfg.family == "hybrid":
        def body(xx, inputs):
            blks, h1, h2, c1, c2, kc, vc = inputs
            b1, b2, b3 = blks
            xx, h1, c1 = _rec_decode(cfg, xx, b1, h1, c1)
            xx, h2, c2 = _rec_decode(cfg, xx, b2, h2, c2)
            xx, kc, vc = _attn_decode(cfg, xx, b3, kc, vc, pos, sin, cos,
                                      window=cfg.window)
            xx = _mlp_fwd(cfg, xx, b3)
            return xx, (h1, h2, c1, c2, kc, vc)

        blks = (params["blocks"]["rec1"], params["blocks"]["rec2"],
                params["blocks"]["attn"])
        x, (h1, h2, c1, c2, k2, v2) = jax.lax.scan(
            body, x, (blks, state["h1"], state["h2"], state["c1"],
                      state["c2"], state["k"], state["v"]))
        state = dict(state, h1=h1, h2=h2, c1=c1, c2=c2, k=k2, v=v2)
        if "tail" in params:
            def tail_body(xx, inputs):
                blk, ht, ct = inputs
                xx, ht, ct = _rec_decode(cfg, xx, blk, ht, ct)
                return xx, (ht, ct)
            x, (ht, ct) = jax.lax.scan(tail_body, x,
                                       (params["tail"], state["ht"],
                                        state["ct"]))
            state = dict(state, ht=ht, ct=ct)
    else:
        raise ValueError(cfg.family)
    x = rms_norm(x, params["final_norm"])
    head = params["embed"].T if cfg.tie_embed else params["lm_head"]
    return shard(x @ head, ("act_batch", None, "act_vocab")), state
