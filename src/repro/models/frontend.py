"""Modality frontend STUBS (per assignment: ``[audio]``/``[vlm]`` entries are
backbone-only; ``input_specs()`` provides precomputed frame/patch embeddings).

* audio (hubert): a real system would run the conv feature encoder over
  16 kHz waveforms (49 Hz frames); here ``input_specs`` supplies
  (B, S, frontend_dim) frame embeddings directly.
* vision (phi-3-vision): a real system would run CLIP ViT-L/14 over image
  crops; here ``input_specs`` supplies (B, n_patches, frontend_dim) patch
  embeddings directly.

DESIGN.md §5 (dry-run policy): modality frontends are stubs by assignment —
input_specs supplies embeddings.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

__all__ = ["frontend_spec", "fake_frontend_batch"]


def frontend_spec(cfg: ModelConfig, batch: int, seq: int):
    if cfg.frontend == "audio":
        return jax.ShapeDtypeStruct((batch, seq, cfg.frontend_dim), jnp.bfloat16)
    if cfg.frontend == "vision":
        return jax.ShapeDtypeStruct((batch, cfg.n_patches, cfg.frontend_dim),
                                    jnp.bfloat16)
    return None


def fake_frontend_batch(cfg: ModelConfig, key, batch: int, seq: int):
    spec = frontend_spec(cfg, batch, seq)
    if spec is None:
        return None
    return jax.random.normal(key, spec.shape, jnp.float32).astype(spec.dtype)
