"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
a_t = exp(-c * softplus(Lambda) * sigmoid(r_t))          (c = 8)

Train/prefill uses an associative scan over time (log-depth); decode is the
O(1) recurrence.  The temporal conv1d (width 4) preceding the gate matches
the Griffin recurrent block.

DESIGN.md §1 (models layer): RG-LRU recurrent block (scan-over-time, mixed-
precision-stable).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["rglru_scan", "rglru_step", "causal_conv1d", "conv1d_step"]

_C = 8.0


def _gates(x, w_input, w_rec, lam):
    i_t = jax.nn.sigmoid(x @ w_input)
    r_t = jax.nn.sigmoid(x @ w_rec)
    log_a = -_C * jax.nn.softplus(lam)[None, None, :] * r_t
    return i_t, log_a


def rglru_scan(x, w_input, w_rec, lam, h0=None):
    """x: (b, s, d). Returns (y (b,s,d), h_final (b,d))."""
    b, s, d = x.shape
    i_t, log_a = _gates(x.astype(jnp.float32), w_input.astype(jnp.float32),
                        w_rec.astype(jnp.float32), lam)
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * (i_t * x.astype(jnp.float32))

    def comb(left, right):
        hL, aL = left
        hR, aR = right
        return hR + hL * aR, aL * aR

    h0v = jnp.zeros((b, 1, d), jnp.float32) if h0 is None \
        else h0.astype(jnp.float32)[:, None, :]
    # prepend h0 as a virtual step with a=1 handled by seeding the first input
    gated = gated.at[:, 0, :].add(h0v[:, 0, :] * a[:, 0, :])
    h, _ = jax.lax.associative_scan(comb, (gated, a), axis=1)
    return h.astype(x.dtype), h[:, -1, :]


def rglru_step(h, x_t, w_input, w_rec, lam):
    """One decode step.  h: (b, d); x_t: (b, d).  Gates in f32, like the
    prefill scan — mixed-precision drift between the two paths otherwise
    breaks the decode==prefill state-handoff contract."""
    xf = x_t.astype(jnp.float32)
    i_t = jax.nn.sigmoid(xf @ w_input.astype(jnp.float32))
    r_t = jax.nn.sigmoid(xf @ w_rec.astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(lam)[None, :] * r_t
    a = jnp.exp(log_a)
    h = a * h + jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * (i_t * xf)
    return h.astype(x_t.dtype), h


def causal_conv1d(x, w):
    """Depthwise causal conv.  x: (b, s, d); w: (k, d).

    Accumulates in f32 with ONE rounding to the input dtype so prefill and
    ``conv1d_step`` decode round identically (bf16 add-chains otherwise
    drift enough to flip argmaxes in the state-handoff tests)."""
    k = w.shape[0]
    xp = jnp.pad(x.astype(jnp.float32), ((0, 0), (k - 1, 0), (0, 0)))
    wf = w.astype(jnp.float32)
    out = sum(xp[:, i:i + x.shape[1], :] * wf[i][None, None, :]
              for i in range(k))
    return out.astype(x.dtype)


def conv1d_step(tail, x_t, w):
    """Decode conv step.  tail: (b, k-1, d) previous inputs; x_t: (b, d)."""
    window = jnp.concatenate([tail, x_t[:, None, :]], 1)     # (b, k, d)
    y = jnp.einsum("bkd,kd->bd", window.astype(jnp.float32),
                   w.astype(jnp.float32)).astype(x_t.dtype)
    return y, window[:, 1:, :]
