"""Gated MLP (SwiGLU) — the dense FFN used by every assigned transformer.

DESIGN.md §1 (models layer): SwiGLU FFN with logical-axis sharding.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["swiglu", "gelu_mlp"]


def swiglu(x, wi_gate, wi_up, wo):
    h = jax.nn.silu(x @ wi_gate) * (x @ wi_up)
    return h @ wo


def gelu_mlp(x, wi, wo):
    return jax.nn.gelu(x @ wi) @ wo
