"""The public Model API: init / train_step-ready loss / prefill / decode +
ShapeDtypeStruct input specs for the multi-pod dry-run.

DESIGN.md §1 (models layer): the public init/loss/prefill/decode API the
launchers and dry-run drive.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import transformer as tfm
from repro.models.common import axes_of, count_params, unbox
from repro.models.config import ModelConfig
from repro.models.frontend import frontend_spec

__all__ = ["Model", "cross_entropy"]


def cross_entropy(logits, targets, mask=None):
    """Token CE in f32 with logsumexp (vocab may be sharded)."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        nll = nll * mask
        return nll.sum() / jnp.maximum(mask.sum(), 1)
    return nll.mean()


class Model:
    """Thin functional wrapper binding a ModelConfig to the transformer fns."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ---- parameters ----
    def init(self, key):
        return tfm.init_params(self.cfg, key)

    def init_abstract(self):
        """Boxed param tree of ShapeDtypeStructs (no allocation) + axes."""
        shapes = jax.eval_shape(lambda: tfm.init_params(
            self.cfg, jax.random.key(0)))
        return shapes

    def param_axes(self):
        return axes_of(self.init_abstract())

    def n_params(self) -> int:
        import numpy as np
        boxed = self.init_abstract()
        return int(sum(np.prod(l.shape) for l in jax.tree.leaves(unbox(boxed))))

    # ---- training ----
    def loss_fn(self, params, batch):
        """params: UNBOXED pytree. batch: {tokens, targets, (frontend)}."""
        cfg = self.cfg
        logits, aux, _ = tfm.forward(cfg, params, batch["tokens"],
                                     batch.get("frontend"))
        if cfg.frontend and cfg.family != "encoder":
            # vlm: image-prefix positions carry no next-token loss
            logits = logits[:, cfg.n_patches:, :]
        loss = cross_entropy(logits, batch["targets"], batch.get("mask"))
        if cfg.family == "moe":
            loss = loss + 0.01 * aux
        return loss, {"ce": loss, "aux": aux}

    # ---- serving ----
    def prefill(self, params, tokens, frontend=None):
        logits, _, caches = tfm.forward(self.cfg, params, tokens, frontend,
                                        return_cache=True)
        return logits[:, -1:, :], caches

    def decode_step(self, params, state, token, pos):
        return tfm.decode_step(self.cfg, params, state, token, pos)

    def init_decode_state(self, batch: int, smax: int):
        return tfm.init_decode_state(self.cfg, batch, smax)

    def decode_state_spec(self, batch: int, smax: int):
        return jax.eval_shape(
            functools.partial(tfm.init_decode_state, self.cfg, batch, smax))

    # ---- dry-run input specs (ShapeDtypeStruct, never allocated) ----
    def input_specs(self, shape_cell: str, seq: int, global_batch: int
                    ) -> dict[str, Any]:
        cfg = self.cfg
        i32 = jnp.int32
        if shape_cell == "train":
            s_text = seq - (cfg.n_patches if cfg.frontend == "vision" else 0)
            specs = {
                "tokens": jax.ShapeDtypeStruct((global_batch, s_text), i32),
                "targets": jax.ShapeDtypeStruct(
                    (global_batch, s_text if cfg.family != "encoder" else seq),
                    i32),
            }
            fs = frontend_spec(cfg, global_batch, seq)
            if fs is not None:
                specs["frontend"] = fs
            if cfg.family == "encoder":
                specs["targets"] = jax.ShapeDtypeStruct((global_batch, seq), i32)
                specs["tokens"] = jax.ShapeDtypeStruct((global_batch, 0), i32)
            return specs
        if shape_cell == "prefill":
            s_text = seq - (cfg.n_patches if cfg.frontend == "vision" else 0)
            specs = {"tokens": jax.ShapeDtypeStruct((global_batch, s_text), i32)}
            fs = frontend_spec(cfg, global_batch, seq)
            if fs is not None:
                specs["frontend"] = fs
            if cfg.family == "encoder":
                specs["tokens"] = jax.ShapeDtypeStruct((global_batch, 0), i32)
            return specs
        if shape_cell == "decode":
            return {
                "token": jax.ShapeDtypeStruct((global_batch, 1), i32),
                "pos": jax.ShapeDtypeStruct((), i32),
                "state": self.decode_state_spec(global_batch, seq),
            }
        raise ValueError(shape_cell)
