"""Grouped-query attention: chunked-flash training/prefill path + cached
decode path.

GQA is computed by repeating KV heads up to the full query head count before
the chunked softmax — per chip this costs nothing extra once heads are
tensor-sharded (each chip materializes only its own head slice) and it keeps
the head axis shardable through the whole attention body (a (kh, g) reshape
would break 16-way sharding of 8 kv heads).

The training path is a pure-jnp blockwise-softmax ("flash") implementation —
O(S) live memory, no S x S score tensor — which doubles as the numerical
oracle for the Pallas kernel in ``repro.kernels.flash_attention`` (used on
real TPU; this module is the portable fallback and the dry-run path).

DESIGN.md §1 (models layer): GQA attention — chunked-flash prefill + cached
decode on the shared meshes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.dist.ctx import shard

__all__ = ["gqa_attention", "decode_attention"]

NEG_INF = -1e30


def _mask_block(qi, ki, qc, kc, causal, window):
    q_pos = qi * qc + jnp.arange(qc)
    k_pos = ki * kc + jnp.arange(kc)
    valid = jnp.ones((qc, kc), bool)
    if causal:
        valid &= k_pos[None, :] <= q_pos[:, None]
    if window:
        valid &= k_pos[None, :] > q_pos[:, None] - window
    return valid


def _flash_fwd_impl(q, k, v, causal, window, qc, kc):
    """Chunked forward returning (out, lse); all (b, s, h, *) f32 inputs."""
    b, s, h, d = q.shape
    n_q, n_k = s // qc, s // kc
    qr = q.reshape(b, n_q, qc, h, d).transpose(1, 0, 2, 3, 4)
    qr = shard(qr, (None, "act_batch", None, "act_heads", None))
    kr = shard(k.reshape(b, n_k, kc, h, d),
               ("act_batch", None, None, "act_heads", None))
    vr = shard(v.reshape(b, n_k, kc, h, d),
               ("act_batch", None, None, "act_heads", None))

    def per_qchunk(qi, qblk):
        def step(carry, ki):
            acc, m_run, l_run = carry
            kblk = jax.lax.dynamic_index_in_dim(kr, ki, 1, keepdims=False)
            vblk = jax.lax.dynamic_index_in_dim(vr, ki, 1, keepdims=False)
            sc = jnp.einsum("bqhd,bchd->bqhc", qblk, kblk)
            valid = _mask_block(qi, ki, qc, kc, causal, window)
            sc = jnp.where(valid[None, :, None, :], sc, NEG_INF)
            m_new = jnp.maximum(m_run, sc.max(-1))
            p = jnp.exp(sc - m_new[..., None])
            alpha = jnp.exp(m_run - m_new)
            l_new = l_run * alpha + p.sum(-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bqhc,bchd->bqhd", p.astype(vblk.dtype), vblk)
            return (acc, m_new, l_new), None

        init = (jnp.zeros((b, qc, h, d), jnp.float32),
                jnp.full((b, qc, h), NEG_INF),
                jnp.zeros((b, qc, h), jnp.float32))
        (acc, m_run, l_run), _ = jax.lax.scan(step, init, jnp.arange(n_k))
        out = acc / jnp.maximum(l_run[..., None], 1e-30)
        lse = m_run + jnp.log(jnp.maximum(l_run, 1e-30))
        return out, lse

    out, lse = jax.vmap(per_qchunk)(jnp.arange(n_q), qr)
    out = shard(out, (None, "act_batch", None, "act_heads", None))
    out = out.transpose(1, 0, 2, 3, 4).reshape(b, s, h, d)
    lse = lse.transpose(1, 0, 2, 3).reshape(b, s, h)
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash(q, k, v, causal, window, qc, kc):
    return _flash_fwd_impl(q, k, v, causal, window, qc, kc)[0]


def _flash_vjp_fwd(q, k, v, causal, window, qc, kc):
    out, lse = _flash_fwd_impl(q, k, v, causal, window, qc, kc)
    return out, (q, k, v, out, lse)


def _flash_vjp_bwd(causal, window, qc, kc, res, do):
    """FlashAttention backward: recompute scores per (q, k) chunk pair from
    O(S) residuals (q, k, v, out, lse) — no S x S tensor is ever SAVED
    between forward and backward (beyond-paper §Perf optimization)."""
    q, k, v, out, lse = res
    b, s, h, d = q.shape
    n_q = s // qc
    do = do.astype(jnp.float32)
    delta = jnp.sum(do * out, axis=-1)                      # (b, s, h)

    def qstep(carry, qi):
        dk_acc, dv_acc = carry
        sl = (qi * qc, 0, 0)
        qblk = jax.lax.dynamic_slice(q, (0, qi * qc, 0, 0), (b, qc, h, d))
        doblk = jax.lax.dynamic_slice(do, (0, qi * qc, 0, 0), (b, qc, h, d))
        lseblk = jax.lax.dynamic_slice(lse, (0, qi * qc, 0), (b, qc, h))
        dblk = jax.lax.dynamic_slice(delta, (0, qi * qc, 0), (b, qc, h))
        sc = jnp.einsum("bqhd,bshd->bqhs", qblk, k)         # (b, qc, h, S)
        q_pos = qi * qc + jnp.arange(qc)
        k_pos = jnp.arange(s)
        valid = jnp.ones((qc, s), bool)
        if causal:
            valid &= k_pos[None, :] <= q_pos[:, None]
        if window:
            valid &= k_pos[None, :] > q_pos[:, None] - window
        sc = jnp.where(valid[None, :, None, :], sc, NEG_INF)
        p = jnp.exp(sc - lseblk[..., None])                 # softmax rows
        dv_acc = dv_acc + jnp.einsum("bqhs,bqhd->bshd", p, doblk)
        dp = jnp.einsum("bqhd,bshd->bqhs", doblk, v)
        ds = p * (dp - dblk[..., None])
        dq_blk = jnp.einsum("bqhs,bshd->bqhd", ds, k)
        dk_acc = dk_acc + jnp.einsum("bqhs,bqhd->bshd", ds, qblk)
        return (dk_acc, dv_acc), dq_blk

    zeros = jnp.zeros((b, s, h, d), jnp.float32)
    (dk, dv), dq_chunks = jax.lax.scan(qstep, (zeros, zeros),
                                       jnp.arange(n_q))
    dq = dq_chunks.transpose(1, 0, 2, 3, 4).reshape(b, s, h, d)
    return dq, dk, dv


_flash.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


@functools.partial(jax.jit, static_argnames=("causal", "window", "chunk"))
def gqa_attention(q, k, v, *, causal=True, window=0, chunk=1024):
    """q: (B, S, H, D); k/v: (B, S, K, D) with H % K == 0."""
    b, s, h, d = q.shape
    kh = k.shape[2]
    if kh != h:
        k = jnp.repeat(k, h // kh, axis=2)
        v = jnp.repeat(v, h // kh, axis=2)
    k = shard(k, ("act_batch", None, "act_heads", None))
    v = shard(v, ("act_batch", None, "act_heads", None))
    scale = d ** -0.5
    qc = min(chunk, s)
    kc = min(chunk, s)
    out = _flash((q.astype(jnp.float32) * scale), k.astype(jnp.float32),
                 v.astype(jnp.float32), causal, window, qc, kc)
    return out.astype(q.dtype)


def decode_attention(q, k_cache, v_cache, length):
    """One-token decode: q (B, 1, H, D); caches (B, Smax, K, D); attend over
    positions < ``length``.  Written as plain reductions so XLA SPMD can
    shard the cache's sequence axis (softmax max/sum lower to all-reduces);
    ``repro.dist.decode_attn`` provides the one-pass shard_map variant."""
    b, _, h, d = q.shape
    kh = k_cache.shape[2]
    g = h // kh
    smax = k_cache.shape[1]
    scale = d ** -0.5
    qg = q.reshape(b, kh, g, d).astype(jnp.float32) * scale
    sc = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache.astype(jnp.float32))
    sc = shard(sc, ("act_batch", None, None, "act_cache_seq"))
    pos = jnp.arange(smax)
    sc = jnp.where(pos[None, None, None, :] < length, sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, d).astype(q.dtype)
