"""Benchmark harness — one function per paper table/figure (§5), writing
CSV blocks to stdout and ``results/benchmarks/*.csv``.

Scaled for a single-core CPU container: 512 lanes, 8192-us windows, 1M-key
universe; the qualitative claims (collapse/scaling/ordering) and calibrated
ratios are the targets — see EXPERIMENTS.md §Paper-validation for the
side-by-side versus the paper's numbers.

    PYTHONPATH=src python -m benchmarks.run [--only fig11,fig20] [--fast]
"""
from __future__ import annotations

import os

# the ycsb_json sharded runs need >= 4 host devices, pinned BEFORE jax init
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=4").strip()

import argparse
import dataclasses
import time

import numpy as np

import json

import jax

from repro.core import runner
from repro.core.credits import credit_init
from repro.core.engine import populate, store_init
from repro.core.sim import SimParams, make_streams, run_sim
from repro.core.types import EngineConfig, IOMetrics, OpKind, SyncMode
from repro.dist import store as dstore
from repro.launch.mesh import make_local_mesh
from repro.stores import PointerArray, RaceHash, SmartART
from repro.workloads.ycsb import (WORKLOADS, YCSB, generate_window_stream,
                                  generate_ycsb_stream)

from benchmarks.provenance import provenance

OUT = "results/benchmarks"
MODES = [SyncMode.OSYNC, SyncMode.SPIN, SyncMode.MCS, SyncMode.CIDER]
N_KEYS = 1_000_000
BASE = dict(n_lanes=512, ticks=8192, max_ops=1024)


def _emit(name: str, header: str, rows: list[str]):
    os.makedirs(OUT, exist_ok=True)
    path = os.path.join(OUT, f"{name}.csv")
    with open(path, "w") as f:
        f.write(header + "\n" + "\n".join(rows) + "\n")
    print(f"\n== {name} ==\n{header}")
    for r in rows:
        print(r)


def _sweep(p: SimParams, workload: str, counts, modes=MODES, theta=None,
           n_keys=N_KEYS):
    streams = make_streams(p, WORKLOADS[workload], n_keys, theta=theta)
    return {(m, nc): run_sim(p, m, streams, nc) for m in modes for nc in counts}


def fig11_12_throughput_latency(fast=False):
    """Figs 11+12: pointer array, 3 workloads x 4 schemes vs clients."""
    counts = [48, 512] if fast else [16, 48, 128, 256, 512]
    p = SimParams(**BASE)
    for wl in ["write-intensive", "read-intensive", "write-only"]:
        res = _sweep(p, wl, counts)
        rows = [f"{nc}," + ",".join(
            f"{res[(m, nc)].throughput_mops:.3f}" for m in MODES) +
            "," + ",".join(f"{res[(m, nc)].p99_us:.0f}" for m in MODES)
            for nc in counts]
        _emit(f"fig11_{wl}", "clients," + ",".join(f"thr_{m.name}" for m in MODES)
              + "," + ",".join(f"p99_{m.name}" for m in MODES), rows)


def fig13_skew(fast=False):
    """Fig 5/13: throughput vs Zipf theta at 512 clients."""
    thetas = [0.0, 0.8, 0.99, 1.2] if fast else [0.0, 0.5, 0.8, 0.9, 0.99, 1.1, 1.2]
    p = SimParams(**BASE)
    rows = []
    for th in thetas:
        res = _sweep(p, "write-intensive", [512], theta=th)
        rows.append(f"{th}," + ",".join(
            f"{res[(m, 512)].throughput_mops:.3f}" for m in MODES))
    _emit("fig13_skew", "theta," + ",".join(m.name for m in MODES), rows)


def fig14_accuracy(fast=False):
    """Fig 14: contention-aware identification accuracy at 512 clients."""
    p = SimParams(**BASE)
    streams = make_streams(p, WORKLOADS["write-intensive"], N_KEYS)
    ideal = run_sim(p, SyncMode.OSYNC, streams, 512).ideal_pess_ratio
    c = run_sim(p, SyncMode.CIDER, streams, 512)
    comb_of_pess = c.wc_rate_global / max(c.pess_ratio, 1e-9)
    _emit("fig14_accuracy",
          "ideal_pess_ratio,cider_pess_ratio,combined_frac_of_pess",
          [f"{ideal:.4f},{c.pess_ratio:.4f},{comb_of_pess:.3f}"])


def fig15_params(fast=False):
    """Fig 15: INITIAL_CREDIT / HOTNESS_THRESHOLD sensitivity (512 clients)."""
    rows = []
    for ic in ([8, 36] if fast else [2, 8, 36, 128]):
        p = SimParams(**BASE, initial_credit=ic)
        streams = make_streams(p, WORKLOADS["write-intensive"], N_KEYS)
        r = run_sim(p, SyncMode.CIDER, streams, 512)
        rows.append(f"initial_credit,{ic},{r.throughput_mops:.3f}")
    for ht in ([2] if fast else [1, 2, 4]):
        p = SimParams(**BASE, hotness_threshold=ht)
        streams = make_streams(p, WORKLOADS["write-intensive"], N_KEYS)
        r = run_sim(p, SyncMode.CIDER, streams, 512)
        rows.append(f"hotness_threshold,{ht},{r.throughput_mops:.3f}")
    _emit("fig15_params", "param,value,throughput_mops", rows)


def fig16_19_race_smart(fast=False):
    """Figs 16-19: end-to-end on RACE (2 bucket reads) and SMART (radix with
    client path cache) index I/O profiles."""
    counts = [48, 512] if fast else [48, 128, 512]
    for name, idx_kw in [("race", dict(index_reads=2, index_bytes=128)),
                         ("smart", dict(index_reads=1, index_bytes=64))]:
        p = SimParams(**BASE, **idx_kw)
        res = _sweep(p, "write-intensive", counts)
        rows = [f"{nc}," + ",".join(
            f"{res[(m, nc)].throughput_mops:.3f}" for m in MODES) +
            "," + ",".join(f"{res[(m, nc)].p99_us:.0f}" for m in MODES)
            for nc in counts]
        _emit(f"fig16_{name}", "clients," +
              ",".join(f"thr_{m.name}" for m in MODES) + "," +
              ",".join(f"p99_{m.name}" for m in MODES), rows)


def fig20_factor(fast=False):
    """Fig 20: factor analysis (local WC disabled for O-SYNC/ShiftLock)."""
    variants = [
        ("OSYNC_noWC", SimParams(**BASE, local_wc=False), SyncMode.OSYNC),
        ("ShiftLock_noWC", SimParams(**BASE, local_wc=False), SyncMode.MCS),
        ("CIDER_woWC", SimParams(**BASE, wc_off=True), SyncMode.CIDER),
        ("CIDER_woCAS", SimParams(**BASE, cas_off=True), SyncMode.CIDER),
        ("CIDER", SimParams(**BASE), SyncMode.CIDER),
    ]
    rows = []
    for name, p, mode in variants:
        streams = make_streams(p, WORKLOADS["write-intensive"], N_KEYS)
        r = run_sim(p, mode, streams, 512)
        rows.append(f"{name},{r.throughput_mops:.3f},{r.p50_us:.0f},"
                    f"{r.p99_us:.0f}")
    _emit("fig20_factor", "variant,throughput_mops,p50_us,p99_us", rows)


def fig21_wc_efficiency(fast=False):
    """Fig 21: WC rate + batch size: local (MCS+WC) vs global (CIDER woCAS)
    vs CIDER."""
    rows = []
    for name, p, mode in [
            ("local_wc", SimParams(**BASE), SyncMode.MCS),
            ("global_wc", SimParams(**BASE, cas_off=True), SyncMode.CIDER),
            ("cider", SimParams(**BASE), SyncMode.CIDER)]:
        streams = make_streams(p, WORKLOADS["write-intensive"], N_KEYS)
        r = run_sim(p, mode, streams, 512)
        rows.append(f"{name},{r.wc_rate:.3f},{r.avg_batch:.2f},"
                    f"{r.throughput_mops:.3f}")
    _emit("fig21_wc_efficiency", "mechanism,wc_rate,avg_batch,throughput", rows)


def fig23_array_size(fast=False):
    """Fig 23 (appendix): pointer-array size sweep at 512 clients."""
    sizes = [64, 65536, 1_000_000] if fast else [1, 64, 4096, 65536, 1_000_000]
    p = SimParams(**BASE)
    rows = []
    for n in sizes:
        res = _sweep(p, "write-intensive", [512], n_keys=n)
        rows.append(f"{n}," + ",".join(
            f"{res[(m, 512)].throughput_mops:.3f}" for m in MODES))
    _emit("fig23_array_size", "array_size," + ",".join(m.name for m in MODES),
          rows)


def fig24_value_size(fast=False):
    """Fig 24 (appendix): value-size sweep (IOPS-bound => flat)."""
    rows = []
    for vb in [8, 64, 256]:
        p = SimParams(**BASE, value_bytes=vb)
        streams = make_streams(p, WORKLOADS["write-intensive"], N_KEYS)
        for mode in ([SyncMode.OSYNC, SyncMode.CIDER] if True else MODES):
            r = run_sim(p, mode, streams, 512)
            rows.append(f"{vb},{mode.name},{r.throughput_mops:.3f}")
    _emit("fig24_value_size", "value_bytes,mode,throughput_mops", rows)


def table_engine_io(fast=False):
    """Exact per-window I/O bill from the dataplane engine (closed-form
    metering): steady-state window after the contention-aware credits warm
    up over 6 consecutive windows (CIDER's first window IS optimistic)."""
    spec = WORKLOADS["write-intensive"]
    rows = []
    for mode in MODES:
        pa = PointerArray.create(4096, mode=mode).populate(
            np.arange(4096), np.arange(4096))
        ops = generate_window_stream(spec, 6, 4096, 4096, 64)
        stream = runner.make_stream(ops.kinds, ops.keys % 4096, ops.values,
                                    n_cns=16)
        pa, res, ios = pa.apply_stream(stream, io_per_window=True)
        d = runner.io_window(ios, -1).as_dict()     # steady-state window
        rows.append(f"pointer_array,{mode.name},{d['mn_iops']},{d['writes']},"
                    f"{d['cas']},{d['retries']},{d['combined']},{d['mn_bytes']}")
    for mode in MODES:
        sa = SmartART.create(key_bits=12, mode=mode).populate(
            np.arange(4096), np.arange(4096))
        ops = generate_window_stream(spec, 6, 4096, 4096, 64)
        sa, res, ios = sa.apply_stream(ops.kinds, ops.keys % 4096, ops.values,
                                       n_cns=16, io_per_window=True)
        d = runner.io_window(ios, -1).as_dict()
        rows.append(f"smart_art,{mode.name},{d['mn_iops']},{d['writes']},"
                    f"{d['cas']},{d['retries']},{d['combined']},{d['mn_bytes']}")
    _emit("table_engine_io",
          "store,mode,mn_iops,writes,cas,retries,combined,mn_bytes", rows)


FULL_BASELINE = "BENCH_engine.json"


def bench_engine_json(fast=False, path=None):
    """Machine-readable engine benchmark — the perf trajectory file CI and
    later PRs diff against.  Per SyncMode it reports BOTH

    * device wall-clock of ONE fused ``run_windows`` scan over all windows
      (``wall_s`` / ``throughput_mops``) — a dispatch-free regression signal;
    * ``modeled_mops`` — throughput under the MN-IOPS cost model
      (``runner.modeled_throughput``), the paper's §2.3/§5 bottleneck metric,
      computed from the exact verb bill summed over all windows;
    * ``modeled_p50_us`` / ``modeled_p99_us`` — the paper's second axis:
      per-op modeled latency percentiles (``runner.modeled_latency``) from
      each op's verb chain + wait-queue rank + MN NIC queueing under the
      same ``SimParams`` cost model.

    ``--fast`` writes ``BENCH_engine.fast.json`` and refuses to overwrite the
    committed full-size baseline.
    """
    if path is None:
        path = "BENCH_engine.fast.json" if fast else FULL_BASELINE
    elif fast and os.path.abspath(path) == os.path.abspath(FULL_BASELINE):
        raise SystemExit(
            f"--fast must not overwrite the committed full-size baseline "
            f"{FULL_BASELINE}; pick another path (default: "
            f"BENCH_engine.fast.json)")
    n_slots, b = (4096, 1024) if fast else (65_536, 4096)
    windows = 4 if fast else 16
    p = SimParams()                                 # testbed cost model
    spec = WORKLOADS["write-intensive"]
    ops = generate_window_stream(spec, windows, b, n_slots, b)
    stream = runner.make_stream(ops.kinds, ops.keys % n_slots, ops.values,
                                n_cns=16)
    out = {
        "config": {"n_slots": n_slots, "batch": b, "windows": windows,
                   "workload": spec.name, "theta": spec.theta, "n_cns": 16,
                   "fast": fast, "runner": "repro.core.runner.run_windows",
                   "provenance": provenance("auto"),
                   "generated_by": "python -m benchmarks.run --only engine_json"
                                   + (" --fast" if fast else "")},
        "metrics": {
            "io_counters": "exact RDMA-verb bill SUMMED over all windows",
            "wall_s": "host-timed device wall-clock of one fused "
                      "run_windows scan executing every window",
            "throughput_mops": "windows*batch / wall_s / 1e6 — device "
                               "wall-clock throughput, gated by "
                               "check_regression.py wall floors whenever "
                               "the run's backend provenance matches the "
                               "committed baseline's (docs/METRICS.md)",
            "modeled_mops": "ops / max(mn_iops/mn_cap, mn_bytes/mn_bw) us — "
                            "MN-NIC-bound throughput, the paper's metric "
                            "(PAPER.md §2.3, §5)",
            "modeled_p50_us/p99_us": "per-op modeled latency percentiles: "
                                     "critical-path RTTs + MN NIC queueing "
                                     "under SimParams (runner."
                                     "modeled_latency, DESIGN.md §7)",
            "mn_cap_per_us": p.mn_cap, "mn_bw_bytes_per_us": p.mn_bw,
        },
    }

    def _make_store():
        return PointerArray.create(n_slots, mode=mode).populate(
            np.arange(n_slots), np.arange(n_slots))

    for mode in MODES:
        _, wres, _ = _make_store().apply_stream(stream)   # warm the jit cache
        jax.block_until_ready(wres.ok)
        pa = _make_store()          # fresh buffers: apply_stream donates
        t0 = time.perf_counter()
        pa, res, io = pa.apply_stream(stream)
        jax.block_until_ready((res.ok, io.reads))
        dt = time.perf_counter() - t0
        d = io.as_dict()
        d["throughput_mops"] = round(windows * b / dt / 1e6, 4)
        d["wall_s"] = round(dt, 4)
        d.update(runner.modeled_throughput(io, p, n_ops=windows * b))
        lat = runner.modeled_latency(pa.cfg, ops.kinds, res, p)
        d.update({f"modeled_{k}": v
                  for k, v in runner.latency_stats(lat).as_dict().items()})
        out[mode.name] = d
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"\n== engine_json -> {path} ==")
    for m in MODES:
        d = out[m.name]
        print(f"{m.name:6s} modeled={d['modeled_mops']:8.3f} Mops/s "
              f"p50={d['modeled_p50_us']:7.1f}us "
              f"p99={d['modeled_p99_us']:8.1f}us "
              f"wall={d['throughput_mops']:8.3f} Mops/s "
              f"mn_iops={d['mn_iops']:8d} combined={d['combined']:6d}")
    return out


KERNELS_PATH = "BENCH_kernels.fast.json"


def bench_kernels_json(fast=True, path=None):
    """Kernel-dispatch seam smoke (DESIGN.md §10) -> ``BENCH_kernels.fast.json``.

    Runs the fast-size engine benchmark once per kernel backend — the jnp
    reference and the forced Pallas kernels (interpret mode off-TPU, the
    compiled kernels on TPU) — and **asserts** the two verb bills and the
    full per-window Results are bit-equal per SyncMode before writing both
    wall-clocks + provenance.  Always fast-sized regardless of ``--fast``:
    this is CI's bit-identity gate on the dispatch seam, not a perf
    trajectory (that is ``BENCH_engine*.json``); the artifact is uploaded so
    a failing run shows *which* counter diverged.
    """
    path = path or KERNELS_PATH
    n_slots, b, windows = 4096, 1024, 4
    spec = WORKLOADS["write-intensive"]
    ops = generate_window_stream(spec, windows, b, n_slots, b)
    stream = runner.make_stream(ops.kinds, ops.keys % n_slots, ops.values,
                                n_cns=16)
    backends = ("jnp", "pallas")
    out = {
        "config": {"n_slots": n_slots, "batch": b, "windows": windows,
                   "workload": spec.name, "n_cns": 16,
                   "backends": {be: provenance(be) for be in backends},
                   "generated_by":
                       "python -m benchmarks.run --only kernels_json"},
        "metrics": {
            "equality": "per SyncMode, the full verb bill AND every "
                        "per-window Results leaf are asserted bit-equal "
                        "between the jnp reference and the Pallas kernel "
                        "path (DESIGN.md §10)",
            "wall_s": "host-timed fused run_windows scan per backend "
                      "(interpreted Pallas is expected to be slow on CPU "
                      "— equality is the gate here, not speed)",
        },
    }
    for mode in MODES:
        rec, trees = {}, {}
        for be in backends:
            def _mk():
                return PointerArray.create(n_slots, mode=mode,
                                           kernel_backend=be).populate(
                    np.arange(n_slots), np.arange(n_slots))
            _, wres, _ = _mk().apply_stream(stream)      # warm the jit cache
            jax.block_until_ready(wres.ok)
            pa = _mk()
            t0 = time.perf_counter()
            pa, res, io = pa.apply_stream(stream)
            jax.block_until_ready((res.ok, io.reads))
            dt = time.perf_counter() - t0
            trees[be] = (res, io)
            d = io.as_dict()
            d["wall_s"] = round(dt, 4)
            rec[be] = d
        ref_leaves = jax.tree.leaves(trees["jnp"])
        for be in backends[1:]:
            for lx, ly in zip(ref_leaves, jax.tree.leaves(trees[be])):
                assert np.array_equal(np.asarray(lx), np.asarray(ly)), \
                    f"kernels_json/{mode.name}: {be} diverged from jnp"
        out[mode.name] = rec
        print(f"{mode.name:6s} bit-equal across {backends}; wall "
              + "  ".join(f"{be}={rec[be]['wall_s']:.3f}s"
                          for be in backends), flush=True)
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"== kernels_json -> {path} ==")
    return out


YCSB_BASELINE = "BENCH_ycsb.json"
YCSB_N_SHARDS = 4
# thin CNs (64) keep lanes-per-CN near the paper's testbed so baseline local
# WC can't absorb the hot queues (see benchmarks/scenarios.py); n_slots leaves
# headroom above the populated universe for D/E's fresh-key insert frontier
YCSB_FULL = dict(windows=16, batch=2048, n_keys=4096, n_slots=8192,
                 n_clients=64, n_cns=64, credit_table=4096, scan_max=16,
                 seed=7)
YCSB_FAST = dict(windows=8, batch=512, n_keys=1024, n_slots=2048,
                 n_clients=64, n_cns=64, credit_table=1024, scan_max=16,
                 seed=7)


def bench_ycsb_json(fast=False, path=None):
    """The full YCSB core suite (A-F) x SyncMode x {single, 4-way sharded}
    -> ``BENCH_ycsb.json`` — the paper's headline benchmark ("up to 6.6x
    under YCSB") as a committed, machine-readable artifact.

    Per cell: the exact verb bill, MN-IOPS-modeled throughput, and modeled
    latency percentiles (docs/METRICS.md documents every field).  E runs
    real ``OpKind.SCAN`` range reads through the reader-probe engine path
    (DESIGN.md §9); the sharded runs are asserted **bit-equal** to the
    single-device verb bill — including the cross-shard scan sub-runs —
    so the committed file doubles as a regression artifact for the
    partition-split traversal.  ``--fast`` writes ``BENCH_ycsb.fast.json``
    (gitignored; gated by ``check_regression.py``) and refuses to touch
    the committed baseline.

    The matrix drives the engine directly with the radix store's exact
    configuration: under SmartART's in-key-order leaf map, slot == key and
    ``index_read_iops == 1``, so this IS the radix store's bill (and the
    sharded topology has no store-level wrapper anyway).  The store-layer
    API — SmartART scan streams, PointerArray/RaceHash rejection — is
    exercised in ``tests/test_scan.py``.
    """
    if path is None:
        path = "BENCH_ycsb.fast.json" if fast else YCSB_BASELINE
    elif fast and os.path.abspath(path) == os.path.abspath(YCSB_BASELINE):
        raise SystemExit(
            f"--fast must not overwrite the committed full-size baseline "
            f"{YCSB_BASELINE}; pick another path (default: "
            f"BENCH_ycsb.fast.json)")
    c = YCSB_FAST if fast else YCSB_FULL
    p = SimParams()
    heap = c["n_slots"] + c["windows"] * c["batch"]
    heap += -heap % YCSB_N_SHARDS
    out = {
        "config": {**c, "heap_slots": heap, "n_shards": YCSB_N_SHARDS,
                   "fast": fast, "provenance": provenance("auto"),
                   "runner": "repro.core.runner.run_windows / "
                             "repro.dist.store.run_windows_sharded",
                   "generated_by": "python -m benchmarks.run --only ycsb_json"
                                   + (" --fast" if fast else "")},
        "metrics": {
            "modeled_mops": "ops / max(mn_iops/mn_cap, mn_bytes/mn_bw) us — "
                            "MN-NIC-bound throughput (PAPER.md §2.3, §5)",
            "modeled_p50_us/p99_us": "per-op modeled latency percentiles "
                                     "(runner.modeled_latency, DESIGN.md "
                                     "§7/§9)",
            "rows": "total SCAN rows returned (workload E; see "
                    "docs/METRICS.md)",
            "equality": "per workload and mode, every sharded4 verb counter "
                        "(incl. the SCAN leaf traversal) is asserted "
                        "bit-equal to the single-device bill",
            "mn_cap_per_us": p.mn_cap, "mn_bw_bytes_per_us": p.mn_bw,
        },
        "workloads": {},
    }
    bill_keys = [f.name for f in dataclasses.fields(IOMetrics)] + [
        "mn_iops", "rows", "modeled_mops", "modeled_p99_us"]
    for name, spec in YCSB.items():
        ops = generate_ycsb_stream(spec, c["windows"], c["batch"],
                                   c["n_keys"], c["n_clients"], seed=c["seed"])
        stream = runner.make_stream(ops.kinds, ops.keys, ops.values,
                                    n_cns=c["n_cns"])
        counts = np.where(ops.kinds == OpKind.SCAN, ops.values, 0)
        n_ops = int((ops.kinds != OpKind.NOP).sum())
        upd = ops.kinds == OpKind.UPDATE
        out["workloads"][name] = {}
        # compile the reader-probe pass only where SCAN lanes exist (E):
        # with no scans the pass bills nothing, so scan_max=0 is bit-identical
        # on A-D/F while skipping the b*(1+scan_max)-lane second linearization
        wl_scan_max = c["scan_max"] if spec.scan > 0 else 0
        for topo in ("single", f"sharded{YCSB_N_SHARDS}"):
            recs = {}
            for mode in MODES:
                cfg = EngineConfig(n_slots=c["n_slots"], heap_slots=heap,
                                   mode=mode, scan_max=wl_scan_max)
                credits = credit_init(c["credit_table"])
                pk = np.arange(c["n_keys"])
                if topo == "single":
                    st = populate(cfg, store_init(cfg), pk, pk)
                    _, _, res, io = runner.run_windows(cfg, st, credits,
                                                       stream)
                else:
                    mesh = make_local_mesh(data=YCSB_N_SHARDS)
                    st = dstore.sharded_populate(
                        cfg, YCSB_N_SHARDS,
                        dstore.sharded_store_init(cfg, YCSB_N_SHARDS), pk, pk)
                    _, _, res, io = dstore.run_windows_sharded(
                        cfg, mesh, st, credits, stream)
                d = io.as_dict()
                d.update(runner.modeled_throughput(io, p, n_ops=n_ops))
                lat = runner.modeled_latency(cfg, ops.kinds, res, p,
                                             scan_counts=counts)
                d.update({f"modeled_{k}": v for k, v in
                          runner.latency_stats(lat).as_dict().items()})
                d["rows"] = int(np.asarray(res.rows).sum())
                d["pess_ratio"] = round(
                    float((np.asarray(res.pessimistic) & upd).sum()
                          / max(int(upd.sum()), 1)), 4)
                recs[mode.name] = d
            out["workloads"][name][topo] = recs
        # the dist.store contract, extended to SCAN: the sharded traversal
        # bill (leaf reads, per-mode sync verbs, rows) IS the single bill
        single = out["workloads"][name]["single"]
        shard = out["workloads"][name][f"sharded{YCSB_N_SHARDS}"]
        for mode in MODES:
            for k in bill_keys:
                assert single[mode.name][k] == shard[mode.name][k], \
                    f"ycsb/{name}/{mode.name}: sharded {k} != single"
        print(f"{name}: " + "  ".join(
            f"{m.name}={single[m.name]['modeled_mops']:7.3f}"
            for m in MODES), flush=True)
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"== ycsb_json -> {path} ==")
    return out


FIGS = {
    "fig11": fig11_12_throughput_latency,
    "engine_json": bench_engine_json,
    "kernels_json": bench_kernels_json,
    "ycsb_json": bench_ycsb_json,
    "fig13": fig13_skew,
    "fig14": fig14_accuracy,
    "fig15": fig15_params,
    "fig16": fig16_19_race_smart,
    "fig20": fig20_factor,
    "fig21": fig21_wc_efficiency,
    "fig23": fig23_array_size,
    "fig24": fig24_value_size,
    "engine_io": table_engine_io,
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(FIGS)
    unknown = [n for n in names if n not in FIGS]
    if unknown:
        raise SystemExit(f"unknown figure(s) {unknown}; choose from {list(FIGS)}")
    t0 = time.time()
    for name in names:
        t1 = time.time()
        FIGS[name](fast=args.fast)
        print(f"[{name} done in {time.time() - t1:.0f}s]", flush=True)
    print(f"\nall benchmarks done in {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
