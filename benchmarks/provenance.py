"""Machine provenance for the committed BENCH_*.json config blocks.

Wall-clock numbers (``wall_s`` / ``throughput_mops``) are only comparable
between runs on the same platform and kernel path, so every benchmark JSON
records where it was generated: the JAX backend, device count, and how the
engine's kernel-dispatch seam (``EngineConfig.kernel_backend``, DESIGN.md
§10) resolved — which implementation ran and whether the Pallas kernels ran
interpreted.  ``check_regression.py`` gates wall-clock floors only when the
current backend matches the committed baseline's; the modeled (verb-bill)
metrics are bit-deterministic and need no such guard.
"""
from __future__ import annotations

import jax

from repro.analysis import analysis_provenance
from repro.core.combine import resolve_backend


def provenance(kernel_backend: str = "auto") -> dict:
    impl, interpret = resolve_backend(kernel_backend)
    return {
        "jax_backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "kernel_backend": kernel_backend,
        "kernel_impl": impl,
        "kernel_interpret": interpret,
        # which static-analysis gates (DESIGN.md §11) the generating tree
        # was subject to — numbers from a tree whose invariant auditor
        # didn't include a given pass aren't evidence the invariant held
        "analysis": analysis_provenance(),
    }
