"""Machine provenance for the committed BENCH_*.json config blocks.

Wall-clock numbers (``wall_s`` / ``throughput_mops``) are only comparable
between runs on the same platform and kernel path, so every benchmark JSON
records where it was generated: the JAX backend, device count, and how the
engine's kernel-dispatch seam (``EngineConfig.kernel_backend``, DESIGN.md
§10) resolved — which implementation ran and whether the Pallas kernels ran
interpreted.  ``check_regression.py`` gates wall-clock floors only when the
current backend matches the committed baseline's; the modeled (verb-bill)
metrics are bit-deterministic and need no such guard.
"""
from __future__ import annotations

import os
import re

import jax

from repro.analysis import analysis_provenance
from repro.core.combine import resolve_backend


def requested_device_count() -> int | None:
    """The ``--xla_force_host_platform_device_count`` override, if any.

    ``device_count`` alone conflates two very different provenance changes:
    a *different machine* (real accelerator count) and a *different simulated
    mesh* (the XLA host-platform override the bench matrix sweeps).  Wall
    floors must skip on either, but the skip message — and a human reading
    the committed JSON — should be able to tell which one happened, so the
    requested override is recorded alongside the live count.
    """
    m = re.search(r"xla_force_host_platform_device_count=(\d+)",
                  os.environ.get("XLA_FLAGS", ""))
    return int(m.group(1)) if m else None


def provenance(kernel_backend: str = "auto") -> dict:
    impl, interpret = resolve_backend(kernel_backend)
    return {
        "jax_backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "requested_device_count": requested_device_count(),
        "kernel_backend": kernel_backend,
        "kernel_impl": impl,
        "kernel_interpret": interpret,
        # which static-analysis gates (DESIGN.md §11) the generating tree
        # was subject to — numbers from a tree whose invariant auditor
        # didn't include a given pass aren't evidence the invariant held
        "analysis": analysis_provenance(),
    }
