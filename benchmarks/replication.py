"""Replication benchmark matrix -> ``BENCH_replication.json``.

Runs the engine benchmark recipe (``benchmarks/run.py bench_engine_json``:
same workload, sizes, seeds, stream) under SNAPSHOT client-centric
replication (FUSEE; DESIGN.md §13) across R in {1, 2, 3} x the 4 SyncModes
x {single, sharded4}, plus an MN-crash failover cell, and records per cell
the exact verb bill, MN-NIC-modeled throughput, and modeled latency
percentiles.  Three properties are *asserted* by the harness, so the
committed file doubles as a regression artifact:

* **R=1 bit-identity** — the ``n_replicas=1`` column is produced by the
  byte-identical program ``BENCH_engine.json`` ran (the replica fan-out is
  a Python-level branch), so its rows must reproduce the engine benchmark
  to the digit (cross-checked against the engine JSON by
  ``check_regression.check_replication``);
* **xR conservation** — every R>1 single-device cell must decompose into
  per-replica bills (``core.types.per_replica_bill``): write-class verbs
  xR, reads x1, ``mn_bytes = ro + R*wr``.  The decomposition is embedded
  in the cell (``per_replica``);
* **failover bit-equality** — the MN-crash cell (one of R=3 replicas dies
  mid-stream) runs ``recovery.run_recovery_replicated`` and is asserted
  bit-equal, per window and per field, to a plain segmented reference that
  swaps ``EngineConfig.n_replicas`` at the crash window — replica death
  costs only the control-plane ``recovery_io``, never a data-plane verb.
  The pre-crash window prefix is additionally asserted bit-equal to the
  crash-free R=3 run's prefix.

The headline the grid exists to show: replication multiplies the write
fan-out on a *fixed* MN fleet, so every mode's modeled Mops/s drops with
R — but CIDER's global write combining collapses W writes into one
replicated combined write, so its *lead* over OSYNC/SPIN/MCS grows with R
(gated per R by ``check_regression``; verdict recorded in DESIGN.md §13).

    PYTHONPATH=src python -m benchmarks.replication [--fast]

``--fast`` writes the gitignored ``BENCH_replication.fast.json`` (CI calls
this via ``make bench-replication-smoke``); the committed full-size
baseline is regenerated without ``--fast``.
"""
from __future__ import annotations

import os

# the sharded4 runs need >= 4 host devices, pinned BEFORE jax init
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=4").strip()

import argparse
import dataclasses
import json
import time

import numpy as np

import jax

from repro.core import runner
from repro.core.credits import credit_init
from repro.core.engine import populate, store_init, store_view
from repro.core.simnet import SimParams
from repro.core.types import (EngineConfig, IOMetrics, SyncMode,
                              per_replica_bill)
from repro.dist import store as dstore
from repro.launch.mesh import make_local_mesh
from repro.recovery import mn_crash, run_recovery_replicated, slice_stream
from repro.stores import PointerArray
from repro.workloads.ycsb import WORKLOADS, generate_window_stream

from benchmarks.provenance import provenance

MODES = [SyncMode.OSYNC, SyncMode.SPIN, SyncMode.MCS, SyncMode.CIDER]
REPLICAS = (1, 2, 3)
N_SHARDS = 4
CRASH_R = 3                  # the MN-crash cell: R=3, replica 2 dies ...
CRASH_DEAD = (2,)            # ... at windows // 2, survivors (0, 1)
FULL_BASELINE = "BENCH_replication.json"
# exactly bench_engine_json's recipe — R=1 must reproduce BENCH_engine.json
FULL = dict(n_slots=65_536, batch=4096, windows=16)
FAST = dict(n_slots=4096, batch=1024, windows=4)


def _sum_io(io: IOMetrics) -> IOMetrics:
    return jax.tree.map(lambda x: np.asarray(x).sum(), io)


def _cell(cfg: EngineConfig, ops, res, io_w: IOMetrics, p: SimParams,
          n_ops: int) -> dict:
    io = _sum_io(io_w)
    d = io.as_dict()
    d.update(runner.modeled_throughput(io, p, n_ops=n_ops))
    lat = runner.modeled_latency(cfg, ops.kinds, res, p)
    d.update({f"modeled_{k}": v
              for k, v in runner.latency_stats(lat).as_dict().items()})
    return d


def _assert_window_prefix_equal(a: IOMetrics, b: IOMetrics, upto: int,
                                what: str) -> None:
    for f in dataclasses.fields(IOMetrics):
        x = np.asarray(getattr(a, f.name))[:upto]
        y = np.asarray(getattr(b, f.name))[:upto]
        assert np.array_equal(x, y), \
            f"{what}: pre-crash IOMetrics.{f.name} prefix diverged"


def _mn_crash_cell(cfg: EngineConfig, c: dict, ops, stream_fn, p: SimParams,
                   r3_io_w: IOMetrics) -> dict:
    """R=3 -> replica 2 dies at windows//2: orchestrated failover run,
    asserted bit-equal to the plain segmented n_replicas-swap reference."""
    w = c["windows"]
    wc = w // 2
    mn = mn_crash(w, CRASH_R, dead_replicas=CRASH_DEAD, at_window=wc)
    pk = np.arange(cfg.n_slots)

    run = run_recovery_replicated(
        cfg, populate(cfg, store_init(cfg), pk, pk), credit_init(4096),
        stream_fn(), mn)

    # drop-mask reference: same segments, cfg swap, no promotion step
    st = populate(cfg, store_init(cfg), pk, pk)
    cr = credit_init(4096)
    stream = stream_fn()
    ress, ios = [], []
    prev_alive = None
    for lo, hi, surv in mn.segments():
        seg = slice_stream(stream, lo, hi)
        st, cr, res, io = runner.run_windows(
            dataclasses.replace(cfg, n_replicas=len(surv)), st, cr, seg,
            io_per_window=True, prev_alive=prev_alive)
        prev_alive = seg.alive[-1]
        ress.append(res)
        ios.append(io)
    cat = lambda *xs: np.concatenate([np.asarray(x) for x in xs],  # noqa: E731
                                     axis=0)
    ref_io = jax.tree.map(cat, *ios)
    ref_res = jax.tree.map(cat, *ress)
    for f in dataclasses.fields(IOMetrics):
        a, b = np.asarray(getattr(run.io, f.name)), \
            np.asarray(getattr(ref_io, f.name))
        assert np.array_equal(a, b), \
            f"mn_crash/{cfg.mode.name}: failover IOMetrics.{f.name} " \
            f"diverged from the segmented n_replicas-swap reference"
    for f in dataclasses.fields(ref_res):
        a = np.asarray(getattr(run.results, f.name))
        b = np.asarray(getattr(ref_res, f.name))
        assert np.array_equal(a, b), \
            f"mn_crash/{cfg.mode.name}: failover Results.{f.name} diverged"
    e1, v1 = store_view(run.state)
    e2, v2 = store_view(st)
    np.testing.assert_array_equal(np.asarray(e1), np.asarray(e2))
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
    # the crash-free R=3 run shares the pre-crash prefix bit-for-bit
    _assert_window_prefix_equal(run.io, r3_io_w, wc,
                                f"mn_crash/{cfg.mode.name}")

    io = _sum_io(run.io)
    d = io.as_dict()
    d.update(runner.modeled_throughput(io, p, n_ops=w * c["batch"]))
    d["asserted_equal"] = True
    d["recovery_io"] = run.recovery_io[0]
    d["windows"] = {"mn_iops": [int(np.asarray(
        jax.tree.map(lambda x, i=i: x[i], run.io).mn_iops))
        for i in range(w)]}
    return d


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--path", default=None)
    args = ap.parse_args()
    path = args.path or ("BENCH_replication.fast.json" if args.fast
                         else FULL_BASELINE)
    if args.fast and os.path.abspath(path) == os.path.abspath(FULL_BASELINE):
        raise SystemExit(
            f"--fast must not overwrite the committed full-size baseline "
            f"{FULL_BASELINE}; pick another path")
    c = FAST if args.fast else FULL
    n_slots, b, windows = c["n_slots"], c["batch"], c["windows"]
    spec = WORKLOADS["write-intensive"]
    ops = generate_window_stream(spec, windows, b, n_slots, b)

    def stream_fn():
        return runner.make_stream(ops.kinds, ops.keys % n_slots, ops.values,
                                  n_cns=16)

    out = {
        "config": {**c, "workload": spec.name, "theta": spec.theta,
                   "n_cns": 16, "n_shards": N_SHARDS,
                   "replicas": list(REPLICAS), "replica_rtt":
                   SimParams().replica_rtt, "fast": args.fast,
                   "mn_crash": {"n_replicas": CRASH_R,
                                "dead_replicas": list(CRASH_DEAD),
                                "crash_window": windows // 2},
                   "provenance": provenance("auto"),
                   "runner": "repro.core.runner.run_windows / "
                             "repro.dist.store.run_windows_sharded / "
                             "repro.recovery.run_recovery_replicated",
                   "generated_by": "python -m benchmarks.replication"
                                   + (" --fast" if args.fast else "")},
        "metrics": {
            "io_counters": "exact RDMA-verb bill SUMMED over all windows; "
                           "write-class verbs (writes/cas/faa/retries/"
                           "repair_cas) carry the xR SNAPSHOT fan-out, "
                           "reads bill to one replica (DESIGN.md §13)",
            "modeled_mops": "ops / max(mn_iops/mn_cap, mn_bytes/mn_bw) us "
                            "on the FIXED aggregate MN fleet — replication "
                            "consumes shared NIC budget, so Mops/s drops "
                            "with R while CIDER's combining lead grows",
            "per_replica": "R>1 single cells: per-replica-MN bill "
                           "decomposition (core.types.per_replica_bill); "
                           "entry 0 is the primary (all reads + observable "
                           "counters), entries 1..R-1 the write-only "
                           "secondaries; summing reproduces the cell bill",
            "equality": "per R and mode, every sharded4 verb counter is "
                        "asserted bit-equal to the single-device bill; the "
                        "R=1 rows are asserted equal to the engine "
                        "benchmark recipe by construction and cross-checked "
                        "against BENCH_engine*.json by check_regression",
            "mn_crash": "R=3 with replica 2 dying at windows//2 through "
                        "run_recovery_replicated, asserted bit-equal to the "
                        "segmented n_replicas-swap reference (promotion is "
                        "control-plane only: recovery_io, no data verbs)",
        },
        "replicas": {},
        "mn_crash": {"modes": {}},
    }
    bill_keys = [f.name for f in dataclasses.fields(IOMetrics)] + ["mn_iops"]
    t0 = time.time()
    io_single: dict[tuple[int, SyncMode], IOMetrics] = {}
    io_single_w: dict[tuple[int, SyncMode], IOMetrics] = {}
    for r in REPLICAS:
        out["replicas"][str(r)] = {"single": {}, f"sharded{N_SHARDS}": {}}
        p = dataclasses.replace(SimParams(), n_replicas=r)
        for mode in MODES:
            t1 = time.time()
            pa = PointerArray.create(n_slots, mode=mode,
                                     n_replicas=r).populate(
                np.arange(n_slots), np.arange(n_slots))
            cfg = pa.cfg
            pa, res, io_w = pa.apply_stream(stream_fn(), io_per_window=True)
            io_single[(r, mode)] = _sum_io(io_w)
            io_single_w[(r, mode)] = io_w
            d = _cell(cfg, ops, res, io_w, p, windows * b)
            if r > 1:
                d["per_replica"] = per_replica_bill(
                    io_single[(1, mode)], io_single[(r, mode)], r)
            out["replicas"][str(r)]["single"][mode.name] = d

            pk = np.arange(n_slots)
            sst = dstore.sharded_populate(
                cfg, N_SHARDS, dstore.sharded_store_init(cfg, N_SHARDS),
                pk, pk)
            mesh = make_local_mesh(data=N_SHARDS)
            _, _, sres, sio_w = dstore.run_windows_sharded(
                cfg, mesh, sst, credit_init(4096), stream_fn(),
                io_per_window=True)
            sd = _cell(cfg, ops, sres, sio_w, p, windows * b)
            for k in bill_keys + ["modeled_mops", "modeled_p99_us"]:
                assert sd[k] == d[k], \
                    f"R{r}/{mode.name}: sharded {k} != single"
            out["replicas"][str(r)][f"sharded{N_SHARDS}"][mode.name] = sd
            print(f"[R{r}/{mode.name}: modeled={d['modeled_mops']:8.3f} "
                  f"mn_iops={d['mn_iops']:8d} cas={d['cas']:6d} "
                  f"({time.time() - t1:.0f}s)]", flush=True)
    for mode in MODES:
        t1 = time.time()
        cfg = dataclasses.replace(
            PointerArray.create(n_slots, mode=mode).cfg, n_replicas=CRASH_R)
        p = dataclasses.replace(SimParams(), n_replicas=CRASH_R)
        out["mn_crash"]["modes"][mode.name] = _mn_crash_cell(
            cfg, c, ops, stream_fn, p, io_single_w[(CRASH_R, mode)])
        d = out["mn_crash"]["modes"][mode.name]
        print(f"[mn_crash/{mode.name}: modeled={d['modeled_mops']:8.3f} "
              f"rearm={d['recovery_io']['repair_rearm_cas']} "
              f"({time.time() - t1:.0f}s)]", flush=True)
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"\n== replication -> {path} ({time.time() - t0:.0f}s) ==")
    for r in REPLICAS:
        row = out["replicas"][str(r)]["single"]
        cider = row["CIDER"]["modeled_mops"]
        best_rival = max(row[m.name]["modeled_mops"]
                         for m in MODES if m != SyncMode.CIDER)
        print(f"R={r}  " + "  ".join(
            f"{m.name}: {row[m.name]['modeled_mops']:8.3f}" for m in MODES)
            + f"   CIDER lead x{cider / best_rival:.2f}")


if __name__ == "__main__":
    main()
