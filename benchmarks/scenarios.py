"""Dynamic-contention scenario matrix -> ``BENCH_scenarios.json``.

Runs every ``repro.workloads.dynamic`` scenario under every ``SyncMode`` on
both topologies (single device, 4-way sharded CPU ``data`` mesh) through the
fused traced runner, and records

* per-window trajectories — ``pess_ratio``, ``credit_mass``, ``wc_rate``,
  ``modeled_mops``, ``p99_us`` — making CIDER's AIMD adaptation (§4.3)
  visible as data;
* overall MN-IOPS-modeled throughput and modeled latency percentiles
  (``runner.modeled_throughput`` / ``modeled_latency``), the paper's two
  evaluation axes.

The sharded runs are asserted bit-equal to the single-device bill (the
``dist.store`` equivalence contract), so the committed file doubles as an
end-to-end regression artifact for the 4-way path.

    PYTHONPATH=src python -m benchmarks.scenarios [--fast] [--only churn]

``--fast`` writes the gitignored ``BENCH_scenarios.fast.json`` (CI calls
this via ``make bench-scenarios-smoke``); the committed full-size baseline
is regenerated without ``--fast``.
"""
from __future__ import annotations

import os

# the 4-way sharded runs need >= 4 host devices, pinned BEFORE jax init
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=4").strip()

import argparse
import dataclasses
import json
import time

import numpy as np

from repro.core import runner
from repro.core.credits import credit_init
from repro.core.engine import populate, store_init
from repro.core.simnet import SimParams
from repro.core.types import EngineConfig, IOMetrics, OpKind, SyncMode
from repro.dist import store as dstore
from repro.launch.mesh import make_local_mesh
from repro.workloads.dynamic import SCENARIOS

from benchmarks.provenance import provenance

MODES = [SyncMode.OSYNC, SyncMode.SPIN, SyncMode.MCS, SyncMode.CIDER]
N_SHARDS = 4
FULL_BASELINE = "BENCH_scenarios.json"
# n_cns=64 keeps lanes-per-CN near the paper's testbed (4 clients per CN,
# §5.1): with fat CNs, baseline local WC absorbs most of the hot-key queue
# and understates the contention the paper measures
FULL = dict(windows=32, batch=2048, n_keys=4096, n_clients=64, n_cns=64,
            credit_table=4096, seed=3)
# fast keeps the full config's contention density (batch/n_keys ratio), not
# just its shape — thinner contention would flip the mode ordering CI gates on
FAST = dict(windows=12, batch=256, n_keys=512, n_clients=64, n_cns=64,
            credit_table=1024, seed=3)


def _cfg(mode: SyncMode, c: dict) -> EngineConfig:
    # heap must hold the populate load plus one commit per written key per
    # window (worst case W*B) — undersizing silently drops commits
    heap = c["n_keys"] + c["windows"] * c["batch"]
    heap += -heap % N_SHARDS
    return EngineConfig(n_slots=c["n_keys"], heap_slots=heap, mode=mode)


def _round(x) -> list:
    return [round(float(v), 4) for v in np.asarray(x)]


def _run_one(sc, mode: SyncMode, topo: str, c: dict, ops, stream,
             p: SimParams) -> dict:
    cfg = _cfg(mode, c)
    pk = sc.populate_keys(c["n_keys"])
    credits = credit_init(c["credit_table"])
    if topo == "single":
        st = populate(cfg, store_init(cfg), pk, pk)
        _, _, res, ios, mass = runner.run_windows_traced(cfg, st, credits,
                                                         stream)
    else:
        mesh = make_local_mesh(data=N_SHARDS)
        st = dstore.sharded_populate(
            cfg, N_SHARDS, dstore.sharded_store_init(cfg, N_SHARDS), pk, pk)
        _, _, res, ios, mass = dstore.run_windows_sharded_traced(
            cfg, mesh, st, credits, stream)

    kinds = np.asarray(ops.kinds)
    valid = kinds != OpKind.NOP
    upd = (kinds == OpKind.UPDATE) & valid
    writes_w = np.maximum(upd.sum(-1), 1)
    pess_w = (np.asarray(res.pessimistic) & upd).sum(-1)
    comb_w = (np.asarray(res.combined) & valid).sum(-1)
    lat = runner.modeled_latency(cfg, kinds, res, p, valid=valid)
    n_w = valid.sum(-1)
    ios_np = {f.name: np.asarray(getattr(ios, f.name))
              for f in dataclasses.fields(IOMetrics)}
    io_sum = IOMetrics(**{k: v.sum() for k, v in ios_np.items()})
    # per-window throughput via the same owned binding-constraint rule as
    # the overall number, so the trajectory can't diverge from the gated
    # metric if the cost model evolves
    mops_w = [runner.modeled_throughput(runner.io_window(ios, w), p,
                                        n_ops=int(n_w[w]))["modeled_mops"]
              for w in range(len(n_w))]
    overall = runner.modeled_throughput(io_sum, p, n_ops=int(n_w.sum()))
    overall.update(runner.latency_stats(lat).as_dict())
    overall["pess_ratio"] = round(float(pess_w.sum() / writes_w.sum()), 4)
    overall["wc_rate"] = round(float(comb_w.sum() / writes_w.sum()), 4)
    overall["mn_iops"] = int(np.asarray(io_sum.mn_iops))
    overall["retries"] = int(np.asarray(io_sum.retries))
    overall["windows"] = {
        "pess_ratio": _round(pess_w / writes_w),
        "credit_mass": [int(v) for v in np.asarray(mass)],
        "wc_rate": _round(comb_w / writes_w),
        "modeled_mops": _round(mops_w),
        "p99_us": _round(np.nanpercentile(lat, 99, axis=-1)),
    }
    return overall


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", default="",
                    help="comma-separated scenario subset")
    ap.add_argument("--path", default=None)
    args = ap.parse_args()
    path = args.path or ("BENCH_scenarios.fast.json" if args.fast
                         else FULL_BASELINE)
    if args.fast and os.path.abspath(path) == os.path.abspath(FULL_BASELINE):
        raise SystemExit(
            f"--fast must not overwrite the committed full-size baseline "
            f"{FULL_BASELINE}; pick another path")
    names = args.only.split(",") if args.only else list(SCENARIOS)
    unknown = [n for n in names if n not in SCENARIOS]
    if unknown:
        raise SystemExit(f"unknown scenario(s) {unknown}; "
                         f"choose from {list(SCENARIOS)}")
    c = FAST if args.fast else FULL
    p = SimParams()
    out = {
        "config": {**c, "n_shards": N_SHARDS, "fast": args.fast,
                   "provenance": provenance("auto"),
                   "runner": "repro.core.runner.run_windows_traced / "
                             "repro.dist.store.run_windows_sharded_traced",
                   "generated_by": "python -m benchmarks.scenarios"
                                   + (" --fast" if args.fast else "")},
        "metrics": {
            "modeled_mops": "ops / max(mn_iops/mn_cap, mn_bytes/mn_bw) us — "
                            "MN-NIC-bound throughput (PAPER.md §2.3, §5)",
            "p50_us/p99_us": "modeled per-op latency percentiles: critical-"
                             "path RTTs + MN NIC queueing under SimParams "
                             "(runner.modeled_latency, DESIGN.md §7)",
            "windows": "per-window trajectories; credit_mass is the total "
                       "credit table mass AFTER each window (§4.3 AIMD)",
            "mn_cap_per_us": p.mn_cap, "mn_bw_bytes_per_us": p.mn_bw,
        },
        "scenarios": {},
    }
    t0 = time.time()
    for name in names:
        sc = SCENARIOS[name]
        ops = sc.generate(c["windows"], c["batch"], c["n_keys"],
                          c["n_clients"], seed=c["seed"])
        stream = runner.make_stream(ops.kinds, ops.keys, ops.values,
                                    n_cns=c["n_cns"])
        out["scenarios"][name] = {}
        for topo in ("single", f"sharded{N_SHARDS}"):
            recs = {}
            for mode in MODES:
                t1 = time.time()
                recs[mode.name] = _run_one(sc, mode, topo, c, ops, stream, p)
                print(f"[{name}/{topo}/{mode.name}: "
                      f"modeled={recs[mode.name]['modeled_mops']:.3f} Mops/s "
                      f"p99={recs[mode.name]['p99_us']:.1f}us "
                      f"({time.time() - t1:.0f}s)]", flush=True)
            out["scenarios"][name][topo] = recs
        # dist.store equivalence contract: the sharded bill IS the
        # single-device bill
        single, shard = (out["scenarios"][name]["single"],
                         out["scenarios"][name][f"sharded{N_SHARDS}"])
        for mode in MODES:
            for k in ("modeled_mops", "mn_iops", "pess_ratio", "p99_us"):
                assert single[mode.name][k] == shard[mode.name][k], \
                    f"{name}/{mode.name}: sharded {k} diverged from single"
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"\n== scenarios -> {path} ({time.time() - t0:.0f}s) ==")
    for name in names:
        row = out["scenarios"][name]["single"]
        print(f"{name:14s} " + "  ".join(
            f"{m.name}={row[m.name]['modeled_mops']:7.3f}" for m in MODES))


if __name__ == "__main__":
    main()
