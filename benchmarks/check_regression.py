"""CI perf-regression gate over the ``--fast`` benchmark JSONs.

Before this gate, CI merely uploaded ``BENCH_engine.fast.json`` as an
artifact — a change that re-inverted the benchmark (the exact failure PR 2
fixed) would merge green.  Now CI fails when either

* CIDER's ``modeled_mops`` drops more than ``--tolerance`` (default 10%)
  below the committed baseline (``benchmarks/baselines.json``), in the
  engine benchmark, any YCSB core workload (A-F, both topologies), any
  dynamic-contention scenario, or any recovery scenario, or
* CIDER stops *leading* OSYNC/MCS/SPIN on ``modeled_mops`` anywhere — the
  paper's headline ordering (§5).  Read/insert-only YCSB workloads (C, D)
  bill identically under every mode, so *ties* pass; falling strictly
  behind fails, or
* CIDER loses a *recovery* lead: its orphan-repair verb bill
  (``repair_cas``) or post-crash modeled p99 exceeds MCS's or SPIN's in
  any recovery scenario (OSYNC is lock-free and strands nothing — it is
  not a recovery rival, it pays on every non-crash window instead), or
* device wall-clock collapses: any mode's ``throughput_mops`` in the fast
  engine benchmark falls more than ``--wall-tolerance`` (default 50%)
  below the committed ``_wall_engine`` floor.  Wall-clock is only
  comparable on the platform that produced the floor, so this check is
  SKIPPED (loudly) when the run's backend provenance — JAX backend,
  resolved kernel implementation, interpret mode — differs from the
  baseline's (docs/METRICS.md).

``modeled_mops`` is derived from the exact metered verb bill of seeded
streams, so it is bit-deterministic across machines — those baselines are
exact values with a tight tolerance band; the wall floors are the one
platform-gated exception, with a correspondingly loose band.

    PYTHONPATH=src python -m benchmarks.check_regression
    PYTHONPATH=src python -m benchmarks.check_regression --update-baseline

Run ``make bench-smoke bench-ycsb-smoke bench-scenarios-smoke
bench-recovery-smoke`` first (CI does); use ``--update-baseline`` after an
intentional perf change to rewrite ``benchmarks/baselines.json`` from the
current fast JSONs.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

MODES = ["OSYNC", "SPIN", "MCS", "CIDER"]
BASELINES = ["OSYNC", "SPIN", "MCS"]
HERE = os.path.dirname(os.path.abspath(__file__))
DEFAULT_BASELINE = os.path.join(HERE, "baselines.json")


def _load(path: str, what: str) -> dict:
    if not os.path.exists(path):
        raise SystemExit(
            f"missing {what} {path!r} — run `make bench-smoke "
            f"bench-ycsb-smoke bench-scenarios-smoke bench-recovery-smoke` "
            f"first")
    with open(path) as f:
        return json.load(f)


def _collect(engine: dict, scenarios: dict, recovery: dict,
             ycsb: dict) -> dict:
    """{check_name: {mode: modeled_mops}} for every gated benchmark."""
    out = {"engine": {m: engine[m]["modeled_mops"] for m in MODES}}
    for name, topos in ycsb["workloads"].items():
        for topo, recs in topos.items():
            out[f"ycsb/{name}/{topo}"] = {
                m: recs[m]["modeled_mops"] for m in MODES}
    for name, topos in scenarios["scenarios"].items():
        for topo, recs in topos.items():
            out[f"scenario/{name}/{topo}"] = {
                m: recs[m]["modeled_mops"] for m in MODES}
    for name, sc in recovery["scenarios"].items():
        out[f"recovery/{name}"] = {
            m: sc["modes"][m]["modeled_mops"] for m in MODES}
    return out


WALL_PROV_KEYS = ("jax_backend", "kernel_impl", "kernel_interpret")


def check_wall(engine: dict, baseline: dict, tolerance: float) -> list[str]:
    """Wall-clock floors on the fast engine benchmark (DESIGN.md §10.2).

    Gates every mode's ``throughput_mops`` against the committed
    ``_wall_engine`` floor — but only when the run's backend provenance
    matches the floor's: a floor recorded on one platform says nothing
    about another, so a mismatch skips the check (loudly) instead of
    failing or silently passing."""
    want = baseline.get("_wall_engine")
    if want is None:
        return ["_wall_engine: no committed wall-clock floor — run "
                "--update-baseline"]
    prov = engine.get("config", {}).get("provenance", {})
    base_prov = want.get("provenance", {})
    if any(prov.get(k) != base_prov.get(k) for k in WALL_PROV_KEYS):
        print("wall floors SKIPPED: backend provenance "
              + str({k: prov.get(k) for k in WALL_PROV_KEYS})
              + " != baseline "
              + str({k: base_prov.get(k) for k in WALL_PROV_KEYS}))
        return []
    failures = []
    for mode, floor in want["throughput_mops"].items():
        got = engine[mode]["throughput_mops"]
        if got < floor * (1.0 - tolerance):
            failures.append(
                f"wall/engine/{mode}: throughput_mops {got:.4f} fell "
                f"{(1 - got / floor) * 100:.0f}% below the committed floor "
                f"{floor:.4f} (wall tolerance {tolerance:.0%})")
    return failures


def check_recovery(recovery: dict) -> list[str]:
    """CIDER must keep its recovery-overhead lead: fewer orphan-repair verbs
    and a lower post-crash tail than the locking rivals, per scenario."""
    failures = []
    for name, sc in recovery["scenarios"].items():
        modes = sc["modes"]
        for metric in ("repair_cas", "p99_post_crash_us"):
            cider = modes["CIDER"][metric]
            for rival in ("MCS", "SPIN"):
                if cider > modes[rival][metric]:
                    failures.append(
                        f"recovery/{name}: CIDER lost its {metric} lead over "
                        f"{rival} ({cider} > {modes[rival][metric]})")
    return failures


def check(actual: dict, baseline: dict, tolerance: float) -> list[str]:
    failures = []
    # a baselined benchmark that disappears from the JSONs is a gate bypass,
    # not a pass — fail loudly
    for name in baseline:
        if not name.startswith("_") and name not in actual:
            failures.append(
                f"{name}: committed baseline has no matching benchmark in "
                f"the fast JSONs — benchmark removed or harness regressed")
    for name, modes in actual.items():
        cider = modes["CIDER"]
        for rival in BASELINES:
            if cider < modes[rival]:
                failures.append(
                    f"{name}: CIDER no longer leads {rival} on modeled_mops "
                    f"({cider:.4f} < {modes[rival]:.4f})")
        want = baseline.get(name, {}).get("CIDER")
        if want is None:
            failures.append(f"{name}: no committed baseline for CIDER — "
                            f"run --update-baseline")
        elif cider < want * (1.0 - tolerance):
            failures.append(
                f"{name}: CIDER modeled_mops regressed "
                f"{(1 - cider / want) * 100:.1f}% "
                f"({cider:.4f} < {want:.4f} - {tolerance:.0%})")
    return failures


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", default="BENCH_engine.fast.json")
    ap.add_argument("--scenarios", default="BENCH_scenarios.fast.json")
    ap.add_argument("--recovery", default="BENCH_recovery.fast.json")
    ap.add_argument("--ycsb", default="BENCH_ycsb.fast.json")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--tolerance", type=float, default=0.10,
                    help="allowed fractional drop of CIDER modeled_mops")
    ap.add_argument("--wall-tolerance", type=float, default=0.50,
                    help="allowed fractional drop of engine throughput_mops "
                         "below the committed wall floor (same-backend runs "
                         "only; wall-clock is noisy, so the band is loose)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline file from the current JSONs")
    args = ap.parse_args()

    engine = _load(args.engine, "engine benchmark")
    scenarios = _load(args.scenarios, "scenario benchmark")
    recovery = _load(args.recovery, "recovery benchmark")
    ycsb = _load(args.ycsb, "ycsb suite benchmark")
    actual = _collect(engine, scenarios, recovery, ycsb)

    if args.update_baseline:
        payload = {
            "_comment": "CIDER modeled_mops floors for the --fast benchmark "
                        "configs; exact-verb-bill metrics, deterministic "
                        "given the generator seeds.  Regenerate with "
                        "`python -m benchmarks.check_regression "
                        "--update-baseline` after an intentional change.  "
                        "_wall_engine holds the device wall-clock floors, "
                        "gated only on runs whose backend provenance "
                        "matches (docs/METRICS.md).",
            "_wall_engine": {
                "provenance": engine.get("config", {}).get("provenance", {}),
                "throughput_mops": {
                    m: engine[m]["throughput_mops"] for m in MODES},
            },
            **{name: {"CIDER": modes["CIDER"]}
               for name, modes in actual.items()},
        }
        with open(args.baseline, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"baseline rewritten -> {args.baseline} "
              f"({len(actual)} checks)")
        return

    baseline = _load(args.baseline, "committed baseline")
    failures = check(actual, baseline, args.tolerance)
    failures += check_recovery(recovery)
    failures += check_wall(engine, baseline, args.wall_tolerance)
    if failures:
        print(f"PERF REGRESSION GATE: {len(failures)} failure(s)")
        for msg in failures:
            print(f"  FAIL {msg}")
        sys.exit(1)
    print(f"perf gate OK: {len(actual)} checks, CIDER leads everywhere and "
          f"is within {args.tolerance:.0%} of baseline")
    for name, modes in sorted(actual.items()):
        print(f"  {name}: CIDER={modes['CIDER']:.4f} "
              f"(baseline {baseline[name]['CIDER']:.4f})")


if __name__ == "__main__":
    main()
