"""CI perf-regression gate over the ``--fast`` benchmark JSONs.

Before this gate, CI merely uploaded ``BENCH_engine.fast.json`` as an
artifact — a change that re-inverted the benchmark (the exact failure PR 2
fixed) would merge green.  Now CI fails when either

* CIDER's ``modeled_mops`` drops more than ``--tolerance`` (default 10%)
  below the committed baseline (``benchmarks/baselines.json``), in the
  engine benchmark, any YCSB core workload (A-F, both topologies), any
  dynamic-contention scenario, or any recovery scenario, or
* CIDER stops *leading* OSYNC/MCS/SPIN on ``modeled_mops`` anywhere — the
  paper's headline ordering (§5).  Read/insert-only YCSB workloads (C, D)
  bill identically under every mode, so *ties* pass; falling strictly
  behind fails, or
* CIDER loses a *recovery* lead: its orphan-repair verb bill
  (``repair_cas``) or post-crash modeled p99 exceeds MCS's or SPIN's in
  any recovery scenario (OSYNC is lock-free and strands nothing — it is
  not a recovery rival, it pays on every non-crash window instead), or
* device wall-clock collapses: any mode's ``throughput_mops`` in the fast
  engine benchmark falls more than ``--wall-tolerance`` (default 50%)
  below the committed ``_wall_engine`` floor.  Wall-clock is only
  comparable on the platform that produced the floor, so this check is
  SKIPPED (loudly) when the run's backend provenance — JAX backend,
  resolved kernel implementation, interpret mode, requested simulated
  device count — differs from the baseline's (docs/METRICS.md), or
* the scale benchmark regresses: CIDER's weak-scaling efficiency falls
  below a committed per-mesh floor, CIDER stops leading steady-state
  ``modeled_mops`` at any reported mesh, or CIDER loses the open-loop p99
  tail lead at the top offered load (``check_scale``, docs/METRICS.md), or
* the replication benchmark breaks its contract (``check_replication``,
  docs/METRICS.md): the R=1 rows stop reproducing the engine benchmark to
  the digit (the replica fan-out must stay a byte-identical no-op at R=1),
  any R>1 cell violates the xR write-fan-out conservation law (write-class
  verbs xR, reads x1, ``mn_bytes = ro + R*wr`` — the check that catches a
  replicated-CAS cost omission), or the MN-crash failover cell loses its
  asserted bit-equality.  CIDER's per-R lead and modeled_mops floors ride
  the generic ``check`` via the ``replication/R*/...`` rows.

``--summary`` additionally writes a markdown gate table (check x metric,
floor vs actual, pass/fail) to ``$GITHUB_STEP_SUMMARY`` (stdout when unset)
and emits a ``::error`` workflow annotation naming every failed floor.

``modeled_mops`` is derived from the exact metered verb bill of seeded
streams, so it is bit-deterministic across machines — those baselines are
exact values with a tight tolerance band; the wall floors are the one
platform-gated exception, with a correspondingly loose band.

    PYTHONPATH=src python -m benchmarks.check_regression
    PYTHONPATH=src python -m benchmarks.check_regression --update-baseline

Run ``make bench-smoke bench-ycsb-smoke bench-scenarios-smoke
bench-recovery-smoke bench-scale-smoke bench-replication-smoke`` first (CI
does); use ``--update-baseline`` after an
intentional perf change to rewrite ``benchmarks/baselines.json`` from the
current fast JSONs.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

MODES = ["OSYNC", "SPIN", "MCS", "CIDER"]
BASELINES = ["OSYNC", "SPIN", "MCS"]
HERE = os.path.dirname(os.path.abspath(__file__))
DEFAULT_BASELINE = os.path.join(HERE, "baselines.json")


def _load(path: str, what: str) -> dict:
    if not os.path.exists(path):
        raise SystemExit(
            f"missing {what} {path!r} — run `make bench-smoke "
            f"bench-ycsb-smoke bench-scenarios-smoke bench-recovery-smoke "
            f"bench-scale-smoke bench-replication-smoke` first")
    with open(path) as f:
        return json.load(f)


def _collect(engine: dict, scenarios: dict, recovery: dict,
             ycsb: dict, replication: dict | None = None) -> dict:
    """{check_name: {mode: modeled_mops}} for every gated benchmark."""
    out = {"engine": {m: engine[m]["modeled_mops"] for m in MODES}}
    for name, topos in ycsb["workloads"].items():
        for topo, recs in topos.items():
            out[f"ycsb/{name}/{topo}"] = {
                m: recs[m]["modeled_mops"] for m in MODES}
    for name, topos in scenarios["scenarios"].items():
        for topo, recs in topos.items():
            out[f"scenario/{name}/{topo}"] = {
                m: recs[m]["modeled_mops"] for m in MODES}
    for name, sc in recovery["scenarios"].items():
        out[f"recovery/{name}"] = {
            m: sc["modes"][m]["modeled_mops"] for m in MODES}
    if replication is not None:
        for r, topos in replication["replicas"].items():
            for topo, recs in topos.items():
                out[f"replication/R{r}/{topo}"] = {
                    m: recs[m]["modeled_mops"] for m in MODES}
        out["replication/mn_crash"] = {
            m: replication["mn_crash"]["modes"][m]["modeled_mops"]
            for m in MODES}
    return out


# requested_device_count distinguishes "different machine" from "different
# simulated mesh" (the XLA host-device override the CI bench matrix sweeps) —
# wall floors are incomparable across either, but the skip message names which
WALL_PROV_KEYS = ("jax_backend", "kernel_impl", "kernel_interpret",
                  "requested_device_count")


def check_wall(engine: dict, baseline: dict, tolerance: float) -> list[str]:
    """Wall-clock floors on the fast engine benchmark (DESIGN.md §10.2).

    Gates every mode's ``throughput_mops`` against the committed
    ``_wall_engine`` floor — but only when the run's backend provenance
    matches the floor's: a floor recorded on one platform says nothing
    about another, so a mismatch skips the check (loudly) instead of
    failing or silently passing."""
    want = baseline.get("_wall_engine")
    if want is None:
        return ["_wall_engine: no committed wall-clock floor — run "
                "--update-baseline"]
    prov = engine.get("config", {}).get("provenance", {})
    base_prov = want.get("provenance", {})
    mismatched = [k for k in WALL_PROV_KEYS
                  if prov.get(k) != base_prov.get(k)]
    if mismatched:
        why = ("different simulated mesh"
               if mismatched == ["requested_device_count"]
               else "different machine/backend")
        print(f"wall floors SKIPPED ({why}): provenance "
              + str({k: prov.get(k) for k in mismatched})
              + " != baseline "
              + str({k: base_prov.get(k) for k in mismatched}))
        return []
    failures = []
    for mode, floor in want["throughput_mops"].items():
        got = engine[mode]["throughput_mops"]
        if got < floor * (1.0 - tolerance):
            failures.append(
                f"wall/engine/{mode}: throughput_mops {got:.4f} fell "
                f"{(1 - got / floor) * 100:.0f}% below the committed floor "
                f"{floor:.4f} (wall tolerance {tolerance:.0%})")
    return failures


def check_recovery(recovery: dict) -> list[str]:
    """CIDER must keep its recovery-overhead lead: fewer orphan-repair verbs
    and a lower post-crash tail than the locking rivals, per scenario."""
    failures = []
    for name, sc in recovery["scenarios"].items():
        modes = sc["modes"]
        for metric in ("repair_cas", "p99_post_crash_us"):
            cider = modes["CIDER"][metric]
            for rival in ("MCS", "SPIN"):
                if cider > modes[rival][metric]:
                    failures.append(
                        f"recovery/{name}: CIDER lost its {metric} lead over "
                        f"{rival} ({cider} > {modes[rival][metric]})")
    return failures


def check_scale(scale: dict, baseline: dict, tolerance: float) -> list[str]:
    """Weak-scaling + open-loop floors over ``BENCH_scale*.json``.

    Three gates (docs/METRICS.md):

    * CIDER's weak-scaling efficiency at every *baselined* mesh must stay
      within ``tolerance`` of the committed floor — a gated mesh missing
      from the JSON is a gate bypass and fails loudly;
    * CIDER must lead every rival on steady-state ``modeled_mops`` at every
      mesh the JSON reports (ties pass, falling strictly behind fails);
    * CIDER must keep the open-loop tail lead: its p99 at the highest
      offered load must not exceed any rival's (the hockey-stick curves
      share one arrival draw and one window clock, so this is exact).
    """
    want = baseline.get("_scale")
    if want is None:
        return ["_scale: no committed weak-scaling floors — run "
                "--update-baseline"]
    failures = []
    eff = scale.get("efficiency", {}).get("CIDER", {})
    for mesh, floor in want["efficiency_CIDER"].items():
        got = eff.get(mesh)
        if got is None:
            failures.append(
                f"scale/efficiency/mesh{mesh}: gated mesh missing from the "
                f"scale JSON — benchmark shrank or harness regressed")
        elif got < floor * (1.0 - tolerance):
            failures.append(
                f"scale/efficiency/mesh{mesh}: CIDER weak-scaling efficiency "
                f"collapsed {(1 - got / floor) * 100:.1f}% "
                f"({got:.4f} < {floor:.4f} - {tolerance:.0%})")
    for mesh, modes in scale.get("weak_scaling", {}).items():
        cider = modes["CIDER"]["modeled_mops"]
        for rival in BASELINES:
            if cider < modes[rival]["modeled_mops"]:
                failures.append(
                    f"scale/mesh{mesh}: CIDER no longer leads {rival} on "
                    f"modeled_mops ({cider:.4f} < "
                    f"{modes[rival]['modeled_mops']:.4f})")
    curves = scale.get("open_loop", {}).get("curves", {})
    if curves.get("CIDER"):
        cider_p99 = curves["CIDER"][-1]["p99_us"]
        for rival in BASELINES:
            rival_p99 = curves[rival][-1]["p99_us"]
            if cider_p99 > rival_p99:
                failures.append(
                    f"scale/open_loop: CIDER lost its p99 tail lead over "
                    f"{rival} at the top offered load "
                    f"({cider_p99} > {rival_p99})")
    return failures


# the replication contract's field split (core.types.per_replica_bill,
# DESIGN.md §13): write-class verbs fan out xR, reads and the observable-only
# counters bill once; the R=1 rows must be digit-exact against the engine JSON
REPL_WRITE_FIELDS = ("writes", "cas", "faa", "retries", "repair_cas")
REPL_READ_FIELDS = ("reads", "cn_msgs", "combined", "executed",
                    "orphan_windows")
REPL_EXACT_KEYS = REPL_WRITE_FIELDS + REPL_READ_FIELDS + (
    "mn_bytes", "mn_iops", "modeled_mops", "modeled_p50_us", "modeled_p99_us")


def check_replication(replication: dict, engine: dict) -> list[str]:
    """Replication-contract floors over ``BENCH_replication*.json``.

    Three gates (docs/METRICS.md):

    * **R=1 exact match** — the replica fan-out is a Python-level branch
      that must compile the byte-identical R=1 program, so every R=1
      single-device row must equal the engine benchmark's row to the digit
      (both JSONs run the same recipe at the same ``--fast`` size);
    * **xR conservation** — each R>1 single cell's write-class verbs must
      be exactly R x the R=1 cell's, reads/observables x1, and the byte
      bill must decompose as ``ro + R*wr``.  An engine change that forgets
      to replicate a write-class verb (e.g. drops the CAS fan-out) breaks
      the multiplier and fails here;
    * **failover equality witness** — the MN-crash cell must carry the
      harness's ``asserted_equal`` flag (the bit-equality against the
      segmented n_replicas-swap reference ran and passed).
    """
    failures = []
    repl_fast = replication.get("config", {}).get("fast")
    eng_fast = engine.get("config", {}).get("fast")
    if repl_fast != eng_fast:
        return [f"replication: size mismatch with the engine JSON "
                f"(fast={repl_fast} vs {eng_fast}) — the R=1 exact-match "
                f"gate needs both benchmarks at the same size"]
    rows1 = replication["replicas"]["1"]["single"]
    for mode in MODES:
        for k in REPL_EXACT_KEYS:
            if rows1[mode][k] != engine[mode][k]:
                failures.append(
                    f"replication/R1/{mode}: {k} {rows1[mode][k]} != engine "
                    f"benchmark's {engine[mode][k]} — n_replicas=1 is no "
                    f"longer a byte-identical no-op")
    for r_str, topos in replication["replicas"].items():
        r = int(r_str)
        if r == 1:
            continue
        for mode in MODES:
            one, tot = rows1[mode], topos["single"][mode]
            for f in REPL_WRITE_FIELDS:
                if tot[f] != r * one[f]:
                    failures.append(
                        f"replication/R{r}/{mode}: write verb '{f}' "
                        f"violates the x{r} fan-out ({tot[f]} != "
                        f"{r} * {one[f]}) — a replica's bill went missing")
            for f in REPL_READ_FIELDS:
                if tot[f] != one[f]:
                    failures.append(
                        f"replication/R{r}/{mode}: read/observable field "
                        f"'{f}' changed under replication ({tot[f]} != "
                        f"{one[f]}); reads bill to one replica")
            wr, rem = divmod(tot["mn_bytes"] - one["mn_bytes"], r - 1)
            if rem or wr < 0 or wr > one["mn_bytes"]:
                failures.append(
                    f"replication/R{r}/{mode}: byte bill "
                    f"{one['mn_bytes']} -> {tot['mn_bytes']} is not "
                    f"ro + {r}*wr")
    for mode, cell in replication["mn_crash"]["modes"].items():
        if not cell.get("asserted_equal"):
            failures.append(
                f"replication/mn_crash/{mode}: failover bit-equality "
                f"witness missing — the harness no longer asserts the "
                f"segmented n_replicas-swap reference")
    return failures


def check(actual: dict, baseline: dict, tolerance: float) -> list[str]:
    failures = []
    # a baselined benchmark that disappears from the JSONs is a gate bypass,
    # not a pass — fail loudly
    for name in baseline:
        if not name.startswith("_") and name not in actual:
            failures.append(
                f"{name}: committed baseline has no matching benchmark in "
                f"the fast JSONs — benchmark removed or harness regressed")
    for name, modes in actual.items():
        cider = modes["CIDER"]
        for rival in BASELINES:
            if cider < modes[rival]:
                failures.append(
                    f"{name}: CIDER no longer leads {rival} on modeled_mops "
                    f"({cider:.4f} < {modes[rival]:.4f})")
        want = baseline.get(name, {}).get("CIDER")
        if want is None:
            failures.append(f"{name}: no committed baseline for CIDER — "
                            f"run --update-baseline")
        elif cider < want * (1.0 - tolerance):
            failures.append(
                f"{name}: CIDER modeled_mops regressed "
                f"{(1 - cider / want) * 100:.1f}% "
                f"({cider:.4f} < {want:.4f} - {tolerance:.0%})")
    return failures


def summary_rows(actual: dict, baseline: dict, engine: dict, scale: dict,
                 recovery: dict, tolerance: float, wall_tolerance: float,
                 replication: dict | None = None) -> list[tuple]:
    """(check, metric, floor, actual, status) per gate — the exit code comes
    from the check_* functions; these rows re-state the same comparisons for
    the markdown gate table."""

    def num(x):
        return round(x, 4) if isinstance(x, float) else x

    rows = []
    for name, modes in sorted(actual.items()):
        got = modes["CIDER"]
        floor = baseline.get(name, {}).get("CIDER")
        ok = floor is not None and got >= floor * (1.0 - tolerance)
        rows.append((name, "CIDER modeled_mops", num(floor), num(got),
                     "PASS" if ok else "FAIL"))
        best_rival = max(modes[r] for r in BASELINES)
        rows.append((name, "CIDER lead", f">= {num(best_rival)}", num(got),
                     "PASS" if got >= best_rival else "FAIL"))
    want = baseline.get("_wall_engine")
    if want:
        prov = engine.get("config", {}).get("provenance", {})
        base_prov = want.get("provenance", {})
        skip = any(prov.get(k) != base_prov.get(k) for k in WALL_PROV_KEYS)
        for mode, floor in want["throughput_mops"].items():
            got = engine[mode]["throughput_mops"]
            status = ("SKIP" if skip else
                      "PASS" if got >= floor * (1.0 - wall_tolerance)
                      else "FAIL")
            rows.append((f"wall/engine/{mode}", "throughput_mops",
                         num(floor), num(got), status))
    for name, sc in sorted(recovery.get("scenarios", {}).items()):
        modes = sc["modes"]
        for metric in ("repair_cas", "p99_post_crash_us"):
            floor = min(modes[r][metric] for r in ("MCS", "SPIN"))
            got = modes["CIDER"][metric]
            rows.append((f"recovery/{name}", f"CIDER {metric}",
                         f"<= {num(floor)}", num(got),
                         "PASS" if got <= floor else "FAIL"))
    sc_want = baseline.get("_scale", {})
    eff = scale.get("efficiency", {}).get("CIDER", {})
    for mesh, floor in sorted(sc_want.get("efficiency_CIDER", {}).items(),
                              key=lambda kv: int(kv[0])):
        got = eff.get(mesh)
        ok = got is not None and got >= floor * (1.0 - tolerance)
        rows.append((f"scale/mesh{mesh}", "CIDER weak-scaling efficiency",
                     num(floor), num(got) if got is not None else "MISSING",
                     "PASS" if ok else "FAIL"))
    for mesh, modes in sorted(scale.get("weak_scaling", {}).items(),
                              key=lambda kv: int(kv[0])):
        got = modes["CIDER"]["modeled_mops"]
        best_rival = max(modes[r]["modeled_mops"] for r in BASELINES)
        rows.append((f"scale/mesh{mesh}", "CIDER lead",
                     f">= {num(best_rival)}", num(got),
                     "PASS" if got >= best_rival else "FAIL"))
    curves = scale.get("open_loop", {}).get("curves", {})
    if curves.get("CIDER"):
        got = curves["CIDER"][-1]["p99_us"]
        floor = min(curves[r][-1]["p99_us"] for r in BASELINES)
        rows.append(("scale/open_loop", "CIDER p99 @ top load",
                     f"<= {num(floor)}", num(got),
                     "PASS" if got <= floor else "FAIL"))
    if replication is not None:
        repl_fails = check_replication(replication, engine)
        exact = not any("/R1/" in f or "size mismatch" in f
                        for f in repl_fails)
        rows.append(("replication/R1", "bit-identity vs engine",
                     "== engine JSON", "match" if exact else "DIVERGED",
                     "PASS" if exact else "FAIL"))
        for r_str in sorted(replication.get("replicas", {}), key=int):
            if r_str == "1":
                continue
            ok = not any(f"/R{r_str}/" in f for f in repl_fails)
            rows.append((f"replication/R{r_str}", "xR write conservation",
                         f"write verbs x{r_str}, reads x1",
                         "holds" if ok else "VIOLATED",
                         "PASS" if ok else "FAIL"))
        ok = not any("mn_crash" in f for f in repl_fails)
        rows.append(("replication/mn_crash", "failover bit-equality",
                     "asserted_equal", "witnessed" if ok else "MISSING",
                     "PASS" if ok else "FAIL"))
    return rows


def write_summary(rows: list[tuple], failures: list[str]):
    """Markdown gate table -> $GITHUB_STEP_SUMMARY (stdout fallback), plus
    one ``::error`` workflow annotation naming each failed floor."""
    verdict = "FAIL" if failures else "PASS"
    lines = [
        "## Perf regression gate: " + verdict,
        "",
        f"{len(rows)} gated checks, {len(failures)} failure(s)",
        "",
        "| check | metric | floor | actual | status |",
        "|---|---|---|---|---|",
    ]
    for name, metric, floor, got, status in rows:
        mark = {"PASS": "✅", "FAIL": "❌", "SKIP": "⏭️"}.get(status, "")
        lines.append(f"| {name} | {metric} | {floor} | {got} "
                     f"| {mark} {status} |")
    md = "\n".join(lines) + "\n"
    path = os.environ.get("GITHUB_STEP_SUMMARY")
    if path:
        with open(path, "a") as f:
            f.write(md)
        print(f"gate table appended to GITHUB_STEP_SUMMARY "
              f"({len(rows)} rows)")
    else:
        print(md)
    for msg in failures:
        print(f"::error title=perf regression gate::{msg}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", default="BENCH_engine.fast.json")
    ap.add_argument("--scenarios", default="BENCH_scenarios.fast.json")
    ap.add_argument("--recovery", default="BENCH_recovery.fast.json")
    ap.add_argument("--ycsb", default="BENCH_ycsb.fast.json")
    ap.add_argument("--scale", default="BENCH_scale.fast.json")
    ap.add_argument("--replication", default="BENCH_replication.fast.json")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--summary", action="store_true",
                    help="write the markdown gate table to "
                         "$GITHUB_STEP_SUMMARY (stdout when unset) and emit "
                         "::error annotations naming each failed floor")
    ap.add_argument("--tolerance", type=float, default=0.10,
                    help="allowed fractional drop of CIDER modeled_mops")
    ap.add_argument("--wall-tolerance", type=float, default=0.50,
                    help="allowed fractional drop of engine throughput_mops "
                         "below the committed wall floor (same-backend runs "
                         "only; wall-clock is noisy, so the band is loose)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline file from the current JSONs")
    args = ap.parse_args()

    engine = _load(args.engine, "engine benchmark")
    scenarios = _load(args.scenarios, "scenario benchmark")
    recovery = _load(args.recovery, "recovery benchmark")
    ycsb = _load(args.ycsb, "ycsb suite benchmark")
    scale = _load(args.scale, "scale benchmark")
    replication = _load(args.replication, "replication benchmark")
    actual = _collect(engine, scenarios, recovery, ycsb, replication)

    if args.update_baseline:
        payload = {
            "_comment": "CIDER modeled_mops floors for the --fast benchmark "
                        "configs; exact-verb-bill metrics, deterministic "
                        "given the generator seeds.  Regenerate with "
                        "`python -m benchmarks.check_regression "
                        "--update-baseline` after an intentional change.  "
                        "_wall_engine holds the device wall-clock floors, "
                        "gated only on runs whose backend provenance "
                        "matches (docs/METRICS.md).",
            "_wall_engine": {
                "provenance": engine.get("config", {}).get("provenance", {}),
                "throughput_mops": {
                    m: engine[m]["throughput_mops"] for m in MODES},
            },
            "_scale": {
                "gated_meshes": scale["config"]["gated_meshes"],
                "efficiency_CIDER": scale["efficiency"]["CIDER"],
            },
            **{name: {"CIDER": modes["CIDER"]}
               for name, modes in actual.items()},
        }
        with open(args.baseline, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"baseline rewritten -> {args.baseline} "
              f"({len(actual)} checks)")
        return

    baseline = _load(args.baseline, "committed baseline")
    failures = check(actual, baseline, args.tolerance)
    failures += check_recovery(recovery)
    failures += check_wall(engine, baseline, args.wall_tolerance)
    failures += check_scale(scale, baseline, args.tolerance)
    failures += check_replication(replication, engine)
    if args.summary:
        write_summary(summary_rows(actual, baseline, engine, scale, recovery,
                                   args.tolerance, args.wall_tolerance,
                                   replication=replication),
                      failures)
    if failures:
        print(f"PERF REGRESSION GATE: {len(failures)} failure(s)")
        for msg in failures:
            print(f"  FAIL {msg}")
        sys.exit(1)
    print(f"perf gate OK: {len(actual)} checks, CIDER leads everywhere and "
          f"is within {args.tolerance:.0%} of baseline")
    for name, modes in sorted(actual.items()):
        print(f"  {name}: CIDER={modes['CIDER']:.4f} "
              f"(baseline {baseline[name]['CIDER']:.4f})")


if __name__ == "__main__":
    main()
