"""Crash-recovery benchmark matrix -> ``BENCH_recovery.json``.

Runs every ``repro.workloads.recovery`` scenario under every ``SyncMode``
through the liveness-aware fused runner and records the recovery bill the
paper's §4.6 epoch protocol implies but never measures:

* ``repair_cas`` — orphan-repair verbs (stale-epoch READ + break CAS, plus
  SPIN's lease-expiry polls): the recovery I/O differentiator.  CIDER's
  combined queues strand ONE lock per queue; MCS strands the whole chain
  of dead nodes; SPIN waiters burn MN CAS polls for the entire lease.
* ``p99_post_crash_us`` — modeled tail latency of the windows from the
  first crash on (lease waits charged to the blocked queues).
* ``windows_to_repair`` / ``orphan_slot_windows`` / ``stranded_final`` —
  the repair timeline (``repro.recovery.time_to_repair``).

Streams run ``warm`` windows before the measured region so the gate
compares steady-state behavior (CIDER's §4.3 credits need two hot windows
to warm up; crashes land mid-steady-state, as on a real fleet); all
metrics below are over the measured windows.

For ``crash_storm`` the harness additionally executes the 4-way *shard
failover* path (shards die at the crash window, survivors re-own their
slot partitions via ``dist.store.failover_reown``) and asserts, for every
mode, that the post-failover per-window bill and results are bit-equal to
the single-device run with the same CN drop mask — shard death costs only
the reported control-plane ``recovery_io``, never a data-plane verb.

    PYTHONPATH=src python -m benchmarks.recovery [--fast] [--only crash_storm]

``--fast`` writes the gitignored ``BENCH_recovery.fast.json`` (CI calls
this via ``make bench-recovery-smoke``); the committed full-size baseline
is regenerated without ``--fast``.
"""
from __future__ import annotations

import os

# the 4-way failover runs need >= 4 host devices, pinned BEFORE jax init
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=4").strip()

import argparse
import dataclasses
import json
import time

import numpy as np

import jax

from repro.core import runner
from repro.core.credits import credit_init
from repro.core.engine import populate, store_init, store_view
from repro.core.simnet import SimParams
from repro.core.types import EngineConfig, IOMetrics, SyncMode
from repro.dist import store as dstore
from repro.recovery import (FailoverEvent, run_recovery, run_recovery_sharded,
                            time_to_repair)
from repro.workloads.recovery import RECOVERY_SCENARIOS

from benchmarks.provenance import provenance

MODES = [SyncMode.OSYNC, SyncMode.SPIN, SyncMode.MCS, SyncMode.CIDER]
N_SHARDS = 4
SURVIVORS = (0, 2)       # shards 1 and 3 die with the CN storm
FULL_BASELINE = "BENCH_recovery.json"
# same thin-CN shape as benchmarks/scenarios.py; `warm` windows precede the
# measured region so CIDER's credits are steady when the crash hits
FULL = dict(windows=24, warm=8, batch=2048, n_keys=4096, n_clients=64,
            n_cns=64, credit_table=4096, seed=7)
FAST = dict(windows=12, warm=4, batch=256, n_keys=512, n_clients=64,
            n_cns=64, credit_table=1024, seed=7)
# scenario-specific membership-event overrides, phased past the warm region
def _overrides(name: str, c: dict) -> dict:
    warm, meas = c["warm"], c["windows"]
    if name == "crash_storm":
        return {"crash_window": warm + meas // 3}
    if name == "rolling_restart":
        return {"start": warm + 1}
    if name == "elastic_scale":
        return {"join_window": warm + meas // 3,
                "leave_window": warm + 2 * meas // 3}
    return {}


def _cfg(mode: SyncMode, c: dict) -> EngineConfig:
    total = c["warm"] + c["windows"]
    heap = c["n_keys"] + total * c["batch"]
    heap += -heap % N_SHARDS
    return EngineConfig(n_slots=c["n_keys"], heap_slots=heap, mode=mode)


def _round(x) -> list:
    return [round(float(v), 4) for v in np.asarray(x)]


def _io_slice(io: IOMetrics, lo: int) -> IOMetrics:
    return jax.tree.map(lambda x: np.asarray(x)[lo:], io)


def _metrics(cfg: EngineConfig, c: dict, ops, run, crash_w: int | None,
             p: SimParams) -> dict:
    warm = c["warm"]
    kinds = np.asarray(ops.kinds)
    io_m = _io_slice(run.io, warm)
    io_sum = IOMetrics(**{f.name: getattr(io_m, f.name).sum()
                          for f in dataclasses.fields(IOMetrics)})
    valid_m = run.valid[warm:]
    lat = runner.modeled_latency(cfg, kinds, run.results, p, valid=run.valid)
    lat_m = lat[warm:]
    n_w = valid_m.sum(-1)
    out = runner.modeled_throughput(io_sum, p, n_ops=int(n_w.sum()))
    out.update(runner.latency_stats(lat_m).as_dict())
    ttr = time_to_repair(run.io, crash_w)
    out.update(ttr)
    out["mn_iops"] = int(np.asarray(io_sum.mn_iops))
    out["recovery_overhead"] = round(
        int(io_sum.repair_cas) / max(int(np.asarray(io_sum.mn_iops)), 1), 6)
    post = lat[crash_w:] if crash_w is not None else lat_m
    out["p99_post_crash_us"] = round(float(np.nanpercentile(post, 99)), 2)
    mops_w = [runner.modeled_throughput(
        jax.tree.map(lambda x, w=w: x[w], io_m), p,
        n_ops=int(n_w[w]))["modeled_mops"] for w in range(len(n_w))]
    out["windows"] = {
        "repair_cas": [int(v) for v in getattr(io_m, "repair_cas")],
        "orphan_windows": [int(v) for v in getattr(io_m, "orphan_windows")],
        "modeled_mops": _round(mops_w),
        "p99_us": _round(np.nanpercentile(lat_m, 99, axis=-1)),
    }
    return out


def _assert_failover_equal(cfg: EngineConfig, name: str, mode: SyncMode,
                           single, sharded) -> None:
    for f in dataclasses.fields(IOMetrics):
        a = np.asarray(getattr(single.io, f.name))
        b = np.asarray(getattr(sharded.io, f.name))
        assert (a == b).all(), \
            f"{name}/{mode.name}: failover IOMetrics.{f.name} diverged " \
            f"from the single-device drop-mask run"
    for f in dataclasses.fields(single.results):
        a = np.asarray(getattr(single.results, f.name))
        b = np.asarray(getattr(sharded.results, f.name))
        assert (a == b).all(), \
            f"{name}/{mode.name}: failover Results.{f.name} diverged"
    ex1, v1 = store_view(single.state)
    ex2, v2 = dstore.sharded_store_view(cfg, len(SURVIVORS), sharded.state)
    np.testing.assert_array_equal(np.asarray(ex1), np.asarray(ex2))
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", default="",
                    help="comma-separated scenario subset")
    ap.add_argument("--path", default=None)
    args = ap.parse_args()
    path = args.path or ("BENCH_recovery.fast.json" if args.fast
                         else FULL_BASELINE)
    if args.fast and os.path.abspath(path) == os.path.abspath(FULL_BASELINE):
        raise SystemExit(
            f"--fast must not overwrite the committed full-size baseline "
            f"{FULL_BASELINE}; pick another path")
    names = args.only.split(",") if args.only else list(RECOVERY_SCENARIOS)
    unknown = [n for n in names if n not in RECOVERY_SCENARIOS]
    if unknown:
        raise SystemExit(f"unknown scenario(s) {unknown}; "
                         f"choose from {list(RECOVERY_SCENARIOS)}")
    c = FAST if args.fast else FULL
    total = c["warm"] + c["windows"]
    p = SimParams()
    out = {
        "config": {**c, "n_shards": N_SHARDS, "survivors": list(SURVIVORS),
                   "fast": args.fast, "lease_us": p.lease_us,
                   "provenance": provenance("auto"),
                   "runner": "repro.recovery.run_recovery / "
                             "run_recovery_sharded",
                   "generated_by": "python -m benchmarks.recovery"
                                   + (" --fast" if args.fast else "")},
        "metrics": {
            "repair_cas": "orphan-repair verbs over the measured windows: "
                          "stale-epoch READ + break CAS per stranded lock "
                          "node, plus SPIN lease polls (engine step 5b)",
            "p99_post_crash_us": "modeled p99 of windows >= the first crash "
                                 "(lease waits charged to blocked queues; "
                                 "OSYNC is lock-free and strands nothing — "
                                 "the paper's §2.2 tradeoff runs the other "
                                 "way on every non-crash window)",
            "windows_to_repair": "windows from the first crash until the "
                                 "last repair activity",
            "recovery_overhead": "repair_cas / mn_iops (measured windows)",
            "modeled_mops": "MN-NIC-bound throughput over the measured "
                            "(post-warm) windows",
        },
        "scenarios": {},
    }
    t0 = time.time()
    for name in names:
        sc = RECOVERY_SCENARIOS[name]
        ops, sched = sc.generate(total, c["batch"], c["n_keys"],
                                 c["n_clients"], c["n_cns"], seed=c["seed"],
                                 **_overrides(name, c))
        crash_w = sched.first_crash_window()
        pk = sc.populate_keys(c["n_keys"])
        recs: dict = {}
        for mode in MODES:
            cfg = _cfg(mode, c)
            t1 = time.time()
            stream = runner.make_stream(ops.kinds, ops.keys, ops.values,
                                        n_cns=c["n_cns"], alive=sched.alive)
            st = populate(cfg, store_init(cfg), pk, pk)
            run1 = run_recovery(cfg, st, credit_init(c["credit_table"]),
                                stream)
            recs[mode.name] = _metrics(cfg, c, ops, run1, crash_w, p)
            if name == "crash_storm":
                # shard failover rides the same storm: shards die with the
                # CNs, survivors re-own, and the bill must not move
                stream2 = runner.make_stream(ops.kinds, ops.keys, ops.values,
                                             n_cns=c["n_cns"],
                                             alive=sched.alive)
                sst = dstore.sharded_populate(
                    cfg, N_SHARDS, dstore.sharded_store_init(cfg, N_SHARDS),
                    pk, pk)
                run2 = run_recovery_sharded(
                    cfg, N_SHARDS, sst, credit_init(c["credit_table"]),
                    stream2, failovers=[FailoverEvent(crash_w, SURVIVORS)])
                _assert_failover_equal(cfg, name, mode, run1, run2)
                recs[mode.name]["failover"] = {
                    "asserted_equal": True, **run2.recovery_io[0]}
            r = recs[mode.name]
            print(f"[{name}/{mode.name}: modeled={r['modeled_mops']:.3f} "
                  f"repair_cas={r['repair_cas']} "
                  f"p99_post={r['p99_post_crash_us']:.0f}us "
                  f"ttr={r['windows_to_repair']}w "
                  f"({time.time() - t1:.0f}s)]", flush=True)
        out["scenarios"][name] = {"crash_window": crash_w,
                                  "description": sc.description,
                                  "modes": recs}
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"\n== recovery -> {path} ({time.time() - t0:.0f}s) ==")
    for name in names:
        row = out["scenarios"][name]["modes"]
        print(f"{name:16s} " + "  ".join(
            f"{m.name}: {row[m.name]['modeled_mops']:.3f}Mops "
            f"rep={row[m.name]['repair_cas']}" for m in MODES))


if __name__ == "__main__":
    main()
