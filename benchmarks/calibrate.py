"""Calibrate SimParams against the paper's headline ratios.

Targets (pointer array, write-intensive, Zipf 0.99):
  * O-SYNC collapse: peak near ~48-64 clients, >=2.7x drop by 512 (Fig 1/2)
  * CIDER / O-SYNC  @512 ~= 6.7x  (Fig 11a)
  * CIDER / ShiftLock @512 ~= 2.0x (Fig 11a)
  * CIDER p99 ~4.2x lower than O-SYNC (Fig 12a)
"""
import itertools

from repro.core.sim import SimParams, make_streams, run_sim
from repro.core.types import SyncMode
from repro.workloads.ycsb import WORKLOADS

N_KEYS = 1_000_000


def main():
    grid = itertools.product(
        [24, 32, 48],        # mn_cap
        [3, 6],              # addr_atomic_cap
    )
    print("cap,addr,osync48,osync512,mcs512,cider512,collapse,cider/osync,cider/mcs,p99_ratio,pess,retries_o,retries_c")
    for cap, addr in grid:
        p = SimParams(n_lanes=1024, ticks=12288, max_ops=2048,
                      mn_cap=cap, addr_atomic_cap=addr)
        streams = make_streams(p, WORKLOADS["write-intensive"], N_KEYS)
        r = {}
        for mode in [SyncMode.OSYNC, SyncMode.MCS, SyncMode.CIDER]:
            for nc in ([48, 512] if mode == SyncMode.OSYNC else [512]):
                r[(mode, nc)] = run_sim(p, mode, streams, nc)
        o48 = r[(SyncMode.OSYNC, 48)].throughput_mops
        o512 = r[(SyncMode.OSYNC, 512)].throughput_mops
        m512 = r[(SyncMode.MCS, 512)].throughput_mops
        c512 = r[(SyncMode.CIDER, 512)].throughput_mops
        c = r[(SyncMode.CIDER, 512)]
        p99r = r[(SyncMode.OSYNC, 512)].p99_us / max(c.p99_us, 1)
        print(f"{cap},{addr},{o48:.2f},{o512:.2f},{m512:.2f},{c512:.2f},"
              f"{o48/max(o512,1e-9):.2f},{c512/max(o512,1e-9):.2f},"
              f"{c512/max(m512,1e-9):.2f},{p99r:.2f},{c.pess_ratio:.3f},"
              f"{r[(SyncMode.OSYNC,512)].retries},{c.retries}", flush=True)


if __name__ == "__main__":
    main()
