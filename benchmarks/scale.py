"""Weak-scaling + open-loop scale benchmark -> ``BENCH_scale.json``.

Two production-shape axes the closed-loop, fixed-mesh suites cannot see
(docs/METRICS.md documents every field; DESIGN.md §12 the window contract):

* **Weak scaling** — a fixed per-shard unit problem (slots, lanes, CNs)
  replicated over mesh sizes N ∈ {1, 4, 8, 16} (``--fast``: {1, 2, 4}),
  keys Zipf-distributed over the *global* universe so the hot head
  concentrates on shard 0 — DINOMO's load-imbalance regime.  Each mesh runs
  the sharded fused scan with ``per_shard_io=True``; the mesh's modeled
  throughput is bound by the HOTTEST shard's NIC service time (parallel MN
  NICs serve their partitions concurrently), and weak-scaling efficiency is
  ``mops_N / (N * mops_1)``.  CIDER's combined queues flatten the hot
  shard's verb bill, which is exactly why its efficiency curve must stay
  above the committed floor while the spin/CAS rivals sag.

* **Open-loop arrivals** — per-CN Poisson (and one bursty MMPP cell)
  offered-load sweeps through ``repro.workloads.openloop`` on a fixed mesh:
  latency vs offered load (the hockey stick), where queueing delay is
  backlog windows x the calibrated window length + the in-window modeled
  completion time.  All modes share one arrival draw per load point, and
  one clock: the window length is provisioned as the slowest mode's
  full-window service time, so the curves are comparable.

Both sections are exact-verb-bill modeled metrics — bit-deterministic given
the seeds, with tight regression floors (``check_regression.py --scale``).
Two bit-identity contracts are asserted on every run: the sharded bill
equals the single-device bill on the same problem, and a dense re-pack of
the partially-filled open-loop windows (valid lanes to the front, explicit
CN plane carried) leaves the bill and the store bit-identical.

    PYTHONPATH=src python -m benchmarks.scale [--fast]
"""
from __future__ import annotations

import os

# the full run scales to a 16-way simulated mesh; pinned BEFORE jax init.
# CI's bench matrix presets 4 or 8 — respected, with the gated fast meshes
# {1, 2, 4} chosen to fit the smallest leg so every leg gates identically.
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=16").strip()

import argparse
import json

import numpy as np

import jax

from repro.core import runner
from repro.core.credits import credit_init
from repro.core.engine import populate, store_init
from repro.core.simnet import SimParams
from repro.core.types import EngineConfig, SyncMode
from repro.dist import store as dstore
from repro.launch.mesh import make_local_mesh
from repro.workloads.openloop import (OpenLoopSpec, dense_repack,
                                      generate_openloop_stream,
                                      open_loop_latency)
from repro.workloads.ycsb import WORKLOADS, generate_window_stream

from benchmarks.provenance import provenance

MODES = [SyncMode.OSYNC, SyncMode.SPIN, SyncMode.MCS, SyncMode.CIDER]
FULL_BASELINE = "BENCH_scale.json"

# per-shard unit problem (weak scaling replicates it N times); the full unit
# puts 131072 slots on every shard, so the 16-way mesh carries a 2.09M-key
# populated store — the donated-buffer scan must stay resident, which is what
# the packed per-slot metadata word (engine.pack_meta) buys.
FULL = dict(meshes=[1, 4, 8, 16], slots1=131_072, lanes1=512, cns1=64,
            windows=12, warmup=4, theta=0.99, seed=11, ol_mesh=8,
            ol_windows=16, rhos=[0.5, 0.7, 0.85, 0.95, 1.05], mmpp_rho=0.85)
FAST = dict(meshes=[1, 2, 4], slots1=4096, lanes1=256, cns1=32,
            windows=8, warmup=4, theta=0.99, seed=11, ol_mesh=2,
            ol_windows=8, rhos=[0.6, 0.9, 1.05], mmpp_rho=0.9)

# the committed full-size artifact must demonstrate CIDER holding at least
# this weak-scaling efficiency at the largest mesh (acceptance floor; the
# CI gate floors in baselines.json are the exact measured values)
CIDER_EFF_FLOOR = 0.25


def _window_ticks(io, p: SimParams) -> np.ndarray:
    """(W,) modeled service ticks per window: shards' NICs serve their
    partitions concurrently WITHIN a window (take the hottest), windows are
    synchronization barriers (sum over them at the call site)."""
    iops = np.asarray(io.mn_iops, np.float64)
    byts = np.asarray(io.mn_bytes, np.float64)
    return np.maximum(iops / p.mn_cap, byts / p.mn_bw).max(-1)


def _assert_bill_equal(a, b, what: str):
    for f in a.__dataclass_fields__:
        x, y = np.asarray(getattr(a, f)), np.asarray(getattr(b, f))
        assert np.array_equal(x, y), f"{what}: IOMetrics.{f} diverged"


def _weak_scaling(c: dict, p: SimParams, spec) -> tuple[dict, dict]:
    """mesh -> mode -> record; plus the efficiency table."""
    avail = jax.device_count()
    meshes = [n for n in c["meshes"] if n <= avail]
    if meshes != c["meshes"]:
        print(f"NOTE: only {avail} devices — meshes clamped to {meshes} "
              f"(gated meshes missing from the JSON fail the gate loudly)")
    weak: dict[str, dict] = {}
    for n in meshes:
        n_slots = c["slots1"] * n
        b = c["lanes1"] * n
        n_cns = c["cns1"] * n
        heap = n_slots + c["windows"] * b
        heap += -heap % n
        ops = generate_window_stream(spec, c["windows"], b, n_slots, n_cns,
                                     seed=c["seed"], theta=c["theta"])
        stream = runner.make_stream(ops.kinds, ops.keys % n_slots, ops.values,
                                    n_cns=n_cns)
        mesh = make_local_mesh(data=n)
        pk = np.arange(n_slots)
        n_ops = c["windows"] * b
        weak[str(n)] = {}
        wu = c["warmup"]
        n_steady = (c["windows"] - wu) * b
        for mode in MODES:
            cfg = EngineConfig(n_slots=n_slots, heap_slots=heap, mode=mode)
            st = dstore.sharded_populate(
                cfg, n, dstore.sharded_store_init(cfg, n), pk, pk)
            _, _, res, io = dstore.run_windows_sharded(
                cfg, mesh, st, credit_init(n_slots), stream,
                per_shard_io=True, io_per_window=True)
            # steady state after the AIMD credits warm up (the engine-table
            # bench gates the same regime): mops over the post-warmup windows
            win_ticks = _window_ticks(io, p)
            ticks = float(win_ticks[wu:].sum())
            lat = runner.modeled_latency(cfg, ops.kinds, res, p)[wu:]
            iops = np.asarray(io.mn_iops)[wu:]
            rec = {
                "modeled_mops": round(n_steady / ticks, 4),
                "modeled_ticks_us": round(ticks, 2),
                "modeled_mops_with_warmup": round(
                    n_ops / float(win_ticks.sum()), 4),
                "shard_mn_iops": [int(x) for x in iops.sum(0)],
                "hot_shard_imbalance": round(
                    float(iops.sum(0).max() / max(iops.sum(0).mean(), 1e-9)),
                    3),
                "mn_iops": int(iops.sum()),
                "mn_bytes": int(np.asarray(io.mn_bytes)[wu:].sum()),
                "combined": int(np.asarray(io.combined)[wu:].sum()),
                "modeled_p99_us": runner.latency_stats(lat).p99_us,
            }
            weak[str(n)][mode.name] = rec
            if n == meshes[0] and n == 1:
                # mesh bit-identity: the sharded per-shard bill must sum to
                # the single-device engine's bill on the identical problem
                st1 = populate(cfg, store_init(cfg), pk, pk)
                _, _, _, io1 = runner.run_windows(cfg, st1,
                                                  credit_init(n_slots),
                                                  stream, io_per_window=True)
                summed = jax.tree.map(lambda x: np.asarray(x).sum(-1), io)
                _assert_bill_equal(summed, io1, f"scale/mesh1/{mode.name}")
        print(f"mesh {n:2d}: " + "  ".join(
            f"{m.name}={weak[str(n)][m.name]['modeled_mops']:9.3f}"
            for m in MODES), flush=True)
    eff = {m.name: {} for m in MODES}
    base = weak.get("1", {})
    for n_str, modes in weak.items():
        n = int(n_str)
        for m in MODES:
            if n > 1 and m.name in base:
                eff[m.name][n_str] = round(
                    modes[m.name]["modeled_mops"]
                    / (n * base[m.name]["modeled_mops"]), 4)
    return weak, eff


def _open_loop(c: dict, p: SimParams, spec, window_us: float) -> dict:
    n = c["ol_mesh"]
    if n > jax.device_count():
        print(f"NOTE: open-loop mesh {n} > {jax.device_count()} devices — "
              f"section skipped")
        return {}
    n_slots = c["slots1"] * n
    n_cns = c["cns1"] * n
    lanes = c["lanes1"] // c["cns1"]
    heap = n_slots + c["ol_windows"] * n_cns * lanes
    heap += -heap % n
    mesh = make_local_mesh(data=n)
    pk = np.arange(n_slots)

    def run_mode(mode, ol):
        cfg = EngineConfig(n_slots=n_slots, heap_slots=heap, mode=mode)
        st = dstore.sharded_populate(
            cfg, n, dstore.sharded_store_init(cfg, n), pk, pk)
        stream = runner.make_stream(ol.kinds, ol.keys % n_slots, ol.values,
                                    n_cns=n_cns, lanes_per_cn=lanes,
                                    valid=ol.valid, cn=ol.cn)
        st, cr, res, io = dstore.run_windows_sharded(
            cfg, mesh, st, credit_init(n_slots), stream)
        lat = runner.modeled_latency(cfg, ol.kinds, res, p, valid=ol.valid)
        total = open_loop_latency(ol, lat, window_us)
        stats = runner.latency_stats(total)
        return cfg, st, io, {
            "rho": None,  # filled by caller
            "p50_us": stats.p50_us, "p99_us": stats.p99_us,
            "offered": ol.offered, "delivered": ol.delivered,
            "mean_delay_windows": round(
                float(ol.delay_windows[ol.valid].mean()), 3)
            if ol.delivered else 0.0,
        }

    out = {"mesh": n, "window_us": round(window_us, 2),
           "rhos": c["rhos"], "curves": {m.name: [] for m in MODES},
           "mmpp": {}}
    for rho in c["rhos"]:
        # one arrival draw per load point, shared by all four modes
        ol = generate_openloop_stream(OpenLoopSpec(
            n_cns=n_cns, lanes_per_cn=lanes, windows=c["ol_windows"],
            rho=rho, n_keys=n_slots, mix=spec, theta=c["theta"],
            seed=c["seed"] + int(rho * 100)))
        for mode in MODES:
            _, _, _, rec = run_mode(mode, ol)
            rec["rho"] = rho
            out["curves"][mode.name].append(rec)
        row = out["curves"]
        print(f"rho {rho:4.2f}: " + "  ".join(
            f"{m.name} p99={row[m.name][-1]['p99_us']:9.1f}us"
            for m in MODES), flush=True)

    # bursty MMPP cell at one load point, same mean rate as its Poisson twin
    olm = generate_openloop_stream(OpenLoopSpec(
        n_cns=n_cns, lanes_per_cn=lanes, windows=c["ol_windows"],
        rho=c["mmpp_rho"], n_keys=n_slots, mix=spec, theta=c["theta"],
        arrival="mmpp", seed=c["seed"] + 5000))
    for mode in MODES:
        _, _, _, rec = run_mode(mode, olm)
        rec["rho"] = c["mmpp_rho"]
        rec["burst_windows_frac"] = round(float(olm.phases.mean()), 3)
        out["mmpp"][mode.name] = rec

    # dense-repack bit-identity (DESIGN.md §12): pack valid lanes to the
    # front carrying the CN plane — bill and store must not move at all
    ol = generate_openloop_stream(OpenLoopSpec(
        n_cns=n_cns, lanes_per_cn=lanes, windows=c["ol_windows"],
        rho=0.8, n_keys=n_slots, mix=spec, theta=c["theta"],
        seed=c["seed"] + 9000))
    rp = dense_repack(ol)
    cfg, st_a, io_a, _ = run_mode(SyncMode.CIDER, ol)
    _, st_b, io_b, _ = run_mode(SyncMode.CIDER, rp)
    _assert_bill_equal(io_a, io_b, "scale/open_loop/dense_repack")
    ex_a, v_a = dstore.sharded_store_view(cfg, n, st_a)
    ex_b, v_b = dstore.sharded_store_view(cfg, n, st_b)
    assert (np.asarray(ex_a) == np.asarray(ex_b)).all() and \
        (np.asarray(v_a) == np.asarray(v_b)).all(), \
        "scale/open_loop/dense_repack: store view diverged"

    # sharded-vs-single bit-identity on a partially-filled stream: invalid
    # lanes bill zero verbs on both paths
    st1 = populate(cfg, store_init(cfg), pk, pk)
    stream = runner.make_stream(ol.kinds, ol.keys % n_slots, ol.values,
                                n_cns=n_cns, lanes_per_cn=lanes,
                                valid=ol.valid, cn=ol.cn)
    _, _, _, io1 = runner.run_windows(cfg, st1, credit_init(n_slots), stream)
    _assert_bill_equal(io_a, io1, "scale/open_loop/sharded_vs_single")
    out["equality"] = ("dense_repack and sharded-vs-single verb bills "
                       "asserted bit-equal on the CIDER cell")
    print("open-loop equality asserts OK", flush=True)
    return out


def bench_scale_json(fast=False, path=None):
    if path is None:
        path = "BENCH_scale.fast.json" if fast else FULL_BASELINE
    elif fast and os.path.abspath(path) == os.path.abspath(FULL_BASELINE):
        raise SystemExit(
            f"--fast must not overwrite the committed full-size baseline "
            f"{FULL_BASELINE}; pick another path (default: "
            f"BENCH_scale.fast.json)")
    c = FAST if fast else FULL
    p = SimParams()
    spec = WORKLOADS["write-intensive"]

    weak, eff = _weak_scaling(c, p, spec)

    # one clock for every open-loop curve: the window length is provisioned
    # as the SLOWEST mode's full-occupancy window service time at the
    # open-loop mesh (calibrated from the weak-scaling run above)
    ol_key = str(c["ol_mesh"])
    ol = {}
    if ol_key in weak:
        window_us = max(weak[ol_key][m.name]["modeled_ticks_us"]
                        for m in MODES) / (c["windows"] - c["warmup"])
        ol = _open_loop(c, p, spec, window_us)

    out = {
        "config": {**{k: v for k, v in c.items()},
                   "workload": spec.name, "fast": fast,
                   "gated_meshes": c["meshes"],
                   "n_slots_max": c["slots1"] * c["meshes"][-1],
                   "provenance": provenance("auto"),
                   "runner": "repro.dist.store.run_windows_sharded"
                             "(per_shard_io=True)",
                   "generated_by": "python -m benchmarks.scale"
                                   + (" --fast" if fast else "")},
        "metrics": {
            "modeled_mops": "n_ops / max-over-shards(mn_iops_s/mn_cap, "
                            "mn_bytes_s/mn_bw) us — the mesh is bound by "
                            "its hottest shard's NIC (docs/METRICS.md)",
            "efficiency": "mops_N / (N * mops_1) per mode — weak-scaling "
                          "efficiency of the replicated unit problem",
            "hot_shard_imbalance": "hottest shard's mn_iops / mean — the "
                                   "Zipf-head concentration CIDER's "
                                   "combining flattens",
            "open_loop": "p50/p99 of delay_windows*window_us + in-window "
                         "modeled latency vs offered load rho "
                         "(DESIGN.md §12); one arrival draw per rho shared "
                         "by all modes",
            "mn_cap_per_us": p.mn_cap, "mn_bw_bytes_per_us": p.mn_bw,
        },
        "weak_scaling": weak,
        "efficiency": eff,
        "open_loop": ol,
    }

    if not fast:
        top = str(c["meshes"][-1])
        got = eff.get("CIDER", {}).get(top)
        assert got is not None and got >= CIDER_EFF_FLOOR, \
            (f"committed artifact floor: CIDER weak-scaling efficiency at "
             f"mesh {top} is {got}, below {CIDER_EFF_FLOOR}")

    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"== scale -> {path} ==")
    for m in MODES:
        print(f"{m.name:6s} efficiency: " + "  ".join(
            f"N={n}:{e:.3f}" for n, e in eff[m.name].items()))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--path", default=None)
    args = ap.parse_args()
    bench_scale_json(fast=args.fast, path=args.path)


if __name__ == "__main__":
    main()
