"""End-to-end driver: train a (reduced) model for a few hundred steps and
verify the loss drops — exercises data pipeline, AdamW, checkpointing and
restart.

    PYTHONPATH=src python examples/train_lm.py [--arch qwen3-0.6b] [--steps 200]
"""
import argparse
import sys

from repro.launch.train import main as train_main

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="qwen3-0.6b")
ap.add_argument("--steps", type=int, default=200)
args = ap.parse_args()

losses = train_main(["--arch", args.arch, "--smoke",
                     "--steps", str(args.steps), "--batch", "8",
                     "--seq", "64", "--ckpt", "/tmp/repro_ckpt",
                     "--ckpt_every", "100"])
assert losses[-1] < losses[0], "loss did not decrease"
print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f} over {args.steps} steps: OK")
