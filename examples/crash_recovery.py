"""Watch a crash storm strand locks — and the repair asymmetry that breaks
them (§4.6).

A quarter of the compute nodes fail-stop mid-run
(`repro.workloads.recovery.crash_storm`): their in-flight ops are dropped
at the window boundary and their queued pessimistic writes strand orphaned
locks, which the next surviving waiter detects via the stale-epoch read and
breaks with a repair CAS after the lease expires.  Per window and per mode,
this prints the repair-verb bill and the modeled p99 — CIDER's combined
queues strand ONE lock per queue so the tail barely moves, MCS strands the
whole chain of dead nodes, and SPIN survivors burn MN CAS polls for the
entire lease.  A 2-shard failover of the same storm
(`repro.recovery.run_recovery_sharded`) shows the re-own is free on the
data plane: its bill is asserted bit-equal to the single-device run.

    PYTHONPATH=src python examples/crash_recovery.py
"""
import os

# the 2-shard failover needs >= 2 host devices, pinned BEFORE jax init
# (pin 4, matching the benchmark harnesses, so jit caches are shareable)
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=4").strip()

import dataclasses

import numpy as np

from repro.core import runner
from repro.core.credits import credit_init
from repro.core.engine import populate, store_init
from repro.core.simnet import SimParams
from repro.core.types import EngineConfig, IOMetrics, SyncMode
from repro.recovery import (FailoverEvent, run_recovery, run_recovery_sharded,
                            time_to_repair)
from repro.workloads.recovery import crash_storm

W, B, N_KEYS, N_CNS, CRASH = 16, 512, 1024, 64, 8

ops, sched = crash_storm(W, B, N_KEYS, n_clients=N_CNS, n_cns=N_CNS,
                         seed=3, crash_window=CRASH)
keys0 = np.arange(N_KEYS)
p = SimParams()
print(f"{N_CNS - int(sched.n_alive()[-1])}/{N_CNS} CNs die at window {CRASH} "
      f"(lease {p.lease_us} us)\n")

runs = {}
for mode in (SyncMode.SPIN, SyncMode.MCS, SyncMode.CIDER):
    cfg = EngineConfig(n_slots=N_KEYS, heap_slots=N_KEYS + W * B, mode=mode)
    stream = runner.make_stream(ops.kinds, ops.keys, ops.values, n_cns=N_CNS,
                                alive=sched.alive)
    store = populate(cfg, store_init(cfg), keys0, keys0)
    run = run_recovery(cfg, store, credit_init(4096), stream)
    lat = runner.modeled_latency(cfg, ops.kinds, run.results, p,
                                 valid=run.valid)
    runs[mode] = (cfg, run, lat)

print(f"{'win':>4s} " + "".join(f"{m.name + ' rep/p99':>18s}"
                                for m in runs) + "   (crash at window "
      f"{CRASH})")
for w in range(W):
    row = f"{w:4d} "
    for mode, (cfg, run, lat) in runs.items():
        rep = int(np.asarray(run.io.repair_cas)[w])
        row += f"{rep:8d} {np.nanpercentile(lat[w], 99):8.0f} "
    print(row + (" <-- crash" if w == CRASH else ""))

print("\nmode     repair_cas  windows_to_repair  post-crash p99 (us)")
for mode, (cfg, run, lat) in runs.items():
    t = time_to_repair(run.io, CRASH)
    print(f"{mode.name:8s} {t['repair_cas']:10d} {t['windows_to_repair']:18d} "
          f"{np.nanpercentile(lat[CRASH:], 99):10.0f}")

# --- the same storm with a shard failover: re-own is data-plane free -------
mode = SyncMode.CIDER
cfg, single, _ = runs[mode]
stream = runner.make_stream(ops.kinds, ops.keys, ops.values, n_cns=N_CNS,
                            alive=sched.alive)
from repro.dist import store as dstore  # noqa: E402

sst = dstore.sharded_populate(cfg, 2, dstore.sharded_store_init(cfg, 2),
                              keys0, keys0)
sharded = run_recovery_sharded(cfg, 2, sst, credit_init(4096), stream,
                               failovers=[FailoverEvent(CRASH, (0,))])
for f in dataclasses.fields(IOMetrics):
    assert (np.asarray(getattr(single.io, f.name))
            == np.asarray(getattr(sharded.io, f.name))).all(), f.name
rio = sharded.recovery_io[0]
print(f"\nshard failover at window {CRASH}: shard {rio['dead_shards']} died, "
      f"survivor re-owned its partition with {rio['reown_reads']} replica "
      f"reads — data-plane bill bit-equal to the single-device run.")
