"""Reproduce the paper's headline micro-benchmark figure (Fig 11a):
pointer-array throughput vs client count for all four schemes.

    PYTHONPATH=src python examples/cider_sim_figures.py
"""
from repro.core.sim import SimParams, make_streams, run_sim
from repro.core.types import SyncMode
from repro.workloads.ycsb import WORKLOADS

p = SimParams(n_lanes=512, ticks=8192, max_ops=1024)
streams = make_streams(p, WORKLOADS["write-intensive"], n_keys=1_000_000)
print("clients," + ",".join(m.name for m in SyncMode))
for nc in [16, 48, 128, 256, 512]:
    row = [str(nc)]
    for mode in SyncMode:
        r = run_sim(p, mode, streams, nc)
        row.append(f"{r.throughput_mops:.2f}")
    print(",".join(row), flush=True)
