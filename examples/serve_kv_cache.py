"""Serving example: continuous batching with the CIDER-managed prefix-cache
page table; batched requests sharing a system prompt get prefix hits.

    PYTHONPATH=src python examples/serve_kv_cache.py
"""
from repro.launch.serve import main as serve_main

stats = serve_main(["--arch", "qwen3-0.6b", "--smoke", "--requests", "12",
                    "--slots", "4", "--max-new", "6", "--prompt-len", "32",
                    "--shared-prefix", "16"])
assert stats["completed"] == 12
assert stats["prefix_hits"] > 0, "expected shared-prefix cache hits"
print("serving example OK")
