"""Watch CIDER adapt: a hotspot shift, window by window.

Generates a dynamic-contention stream whose hot set jumps to disjoint keys
mid-run (`repro.workloads.dynamic.hotspot_shift`), executes every window in
one fused traced scan, and prints the per-window trajectory: the pessimistic
ratio climbing while the hotspot is hot, collapsing the instant it moves
(stale credits don't cover the new keys), then recovering within a few
windows as the AIMD credits re-warm — with the modeled latency tail staying
flat thanks to global write combining.

    PYTHONPATH=src python examples/dynamic_contention.py
"""
import numpy as np

from repro.core import runner
from repro.core.credits import credit_init
from repro.core.engine import populate, store_init
from repro.core.simnet import SimParams
from repro.core.types import EngineConfig, OpKind, SyncMode
from repro.workloads.dynamic import hotspot_shift

W, B, N_KEYS, N_CNS, SHIFT = 16, 512, 1024, 64, 8

ops = hotspot_shift(W, B, N_KEYS, n_clients=N_CNS, seed=3,
                    shift_window=SHIFT)
stream = runner.make_stream(ops.kinds, ops.keys, ops.values, n_cns=N_CNS)
cfg = EngineConfig(n_slots=N_KEYS, heap_slots=N_KEYS + W * B,
                   mode=SyncMode.CIDER)
keys0 = np.arange(N_KEYS)
store = populate(cfg, store_init(cfg), keys0, keys0)
store, credits, res, ios, mass = runner.run_windows_traced(
    cfg, store, credit_init(4096), stream)

p = SimParams()
lat = runner.modeled_latency(cfg, ops.kinds, res, p)
upd = ops.kinds == OpKind.UPDATE
pess = np.asarray(res.pessimistic)
comb = np.asarray(res.combined)

print(f"{'win':>4s} {'pess%':>6s} {'wc%':>6s} {'credits':>8s} "
      f"{'p99 us':>7s}  (hotspot shifts at window {SHIFT})")
for w in range(W):
    nw = max(int(upd[w].sum()), 1)
    marker = " <-- shift" if w == SHIFT else ""
    print(f"{w:4d} {100 * (pess[w] & upd[w]).sum() / nw:6.1f} "
          f"{100 * comb[w].sum() / nw:6.1f} {int(np.asarray(mass)[w]):8d} "
          f"{np.nanpercentile(lat[w], 99):7.1f}{marker}")
