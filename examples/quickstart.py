"""Quickstart: the paper's headline benchmark in one table.

Runs the full YCSB core suite (A-F — including E's SCAN range reads over
the radix leaf runs, DESIGN.md §9) under each synchronization scheme and
prints MN-IOPS-modeled throughput per cell: the "up to 6.6x under the
YCSB benchmark" claim, reproduced at demo scale.  Expect CIDER ahead on
the contended mixes (A, B, F), ahead on E (its cold scans are lock-free),
and tied on the read/insert-only mixes (C, D bill identically in every
mode).  Field semantics: docs/METRICS.md; committed full-size matrix:
BENCH_ycsb.json.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import runner
from repro.core.credits import credit_init
from repro.core.engine import populate, store_init
from repro.core.simnet import SimParams
from repro.core.types import EngineConfig, OpKind, SyncMode
from repro.workloads.ycsb import YCSB, generate_ycsb_stream

W, B, N_KEYS, N_SLOTS, N_CNS, SCAN_MAX = 6, 512, 1024, 2048, 64, 16
MODES = [SyncMode.OSYNC, SyncMode.SPIN, SyncMode.MCS, SyncMode.CIDER]

p = SimParams()
print(f"modeled Mops/s (MN-NIC-bound)   "
      f"{'  '.join(f'{m.name:>7s}' for m in MODES)}")
for name, spec in YCSB.items():
    ops = generate_ycsb_stream(spec, W, B, N_KEYS, n_clients=64, seed=7)
    stream = runner.make_stream(ops.kinds, ops.keys, ops.values, n_cns=N_CNS)
    n_ops = int((ops.kinds != OpKind.NOP).sum())
    cells = []
    for mode in MODES:
        # probe pass compiled only for E (scan_max=0 is bit-identical
        # when the stream has no SCAN lanes, and much cheaper to trace)
        cfg = EngineConfig(n_slots=N_SLOTS, heap_slots=N_SLOTS + W * B,
                           mode=mode,
                           scan_max=SCAN_MAX if spec.scan > 0 else 0)
        store = populate(cfg, store_init(cfg), np.arange(N_KEYS),
                         np.arange(N_KEYS))
        # all W windows run in ONE fused scan (credits warm up on-device)
        _, _, res, io = runner.run_windows(cfg, store, credit_init(1024),
                                           stream)
        cells.append(runner.modeled_throughput(io, p, n_ops)["modeled_mops"])
    best = max(cells)
    row = "  ".join(f"{c:7.3f}" + ("*" if c == best else " ") for c in cells)
    label = {"A": "A 50r/50u", "B": "B 95r/5u", "C": "C 100r",
             "D": "D 95r/5i latest", "E": "E 95scan/5i",
             "F": "F 50r/50rmw"}[name]
    print(f"{label:30s}  {row}")
print("\n(*) column winner; C and D bill identically in every mode by "
      "construction.\nFull-size committed matrix: BENCH_ycsb.json; field "
      "reference: docs/METRICS.md.")
