"""Quickstart: the CIDER store in 30 lines.

Creates a pointer-array KV store, runs contended write-intensive windows
under each synchronization scheme (a few, so CIDER's contention-aware
credits warm up), and prints the steady-state I/O bill — the paper's whole
point in one table (O-SYNC pays O(n^2) retries; CIDER combines hot writes).

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import runner
from repro.core.types import SyncMode
from repro.stores import PointerArray
from repro.workloads.ycsb import WORKLOADS, generate_window_stream

N_KEYS, N_OPS, N_CNS, WINDOWS = 4096, 4096, 16, 5

print(f"{'scheme':8s} {'MN IOPs':>9s} {'writes':>7s} {'CAS':>7s} "
      f"{'retries':>8s} {'combined':>9s} {'wire KB':>8s}")
for mode in SyncMode:
    store = PointerArray.create(N_KEYS, mode=mode).populate(
        np.arange(N_KEYS), np.arange(N_KEYS))
    # all WINDOWS windows run in ONE fused scan (credits warm up on-device)
    ops = generate_window_stream(WORKLOADS["write-intensive"], WINDOWS, N_OPS,
                                 N_KEYS, n_clients=64)
    stream = runner.make_stream(ops.kinds, ops.keys % N_KEYS, ops.values,
                                n_cns=N_CNS)
    store, res, ios = store.apply_stream(stream, io_per_window=True)
    d = runner.io_window(ios, -1).as_dict()   # the steady-state window
    print(f"{mode.name:8s} {d['mn_iops']:9d} {d['writes']:7d} {d['cas']:7d} "
          f"{d['retries']:8d} {d['combined']:9d} {d['mn_bytes']/1024:8.1f}")
