"""CLI driver for the repro.analysis invariant auditor (DESIGN.md §11).

Runs the three static/model passes — ``jaxpr_check`` (jaxpr/HLO invariant
audit), ``bill_lint`` (verb-bill conservation), ``race_check`` (exhaustive
protocol model checking) — prints every violation, writes a machine-readable
report, and exits non-zero if anything failed.  This is the ``make analyze``
CI gate.

Usage:
    python tools/analyze.py [--pass jaxpr_check,bill_lint,race_check]
                            [--report ANALYZE_REPORT.json] [--full]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from pathlib import Path

# The jaxpr pass audits the 4-way sharded collective contract, which needs
# multiple devices — force an 8-way host platform BEFORE jax initializes
# (mirrors tests/conftest.py; a no-op when XLA_FLAGS is already set).
os.environ.setdefault(
    "XLA_FLAGS", "--xla_force_host_platform_device_count=8")
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.analysis import ANALYSIS_VERSION, PASSES  # noqa: E402


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--pass", dest="passes", default=",".join(PASSES),
                    help="comma-separated subset of passes to run "
                         f"(default: {','.join(PASSES)})")
    ap.add_argument("--report", default="ANALYZE_REPORT.json",
                    help="machine-readable report path ('' to skip)")
    ap.add_argument("--full", action="store_true",
                    help="race_check: widen the 3-client scenario space "
                         "beyond the CI-calibrated quick set")
    args = ap.parse_args(argv)

    selected = [p.strip() for p in args.passes.split(",") if p.strip()]
    unknown = sorted(set(selected) - set(PASSES))
    if unknown:
        ap.error(f"unknown pass(es) {unknown}; choose from {list(PASSES)}")

    report: dict = {"version": ANALYSIS_VERSION, "passes": {}}
    total = 0
    for name in selected:
        mod = __import__(f"repro.analysis.{name}", fromlist=["run"])
        notes: list[str] = []
        t0 = time.time()
        if name == "race_check":
            viols = mod.run(notes, quick=not args.full)
        else:
            viols = mod.run(notes)
        dt = time.time() - t0
        total += len(viols)
        report["passes"][name] = {
            "violations": [{"target": v.target, "message": v.message}
                           for v in viols],
            "notes": notes,
            "seconds": round(dt, 2),
        }
        status = "OK" if not viols else f"{len(viols)} VIOLATION(S)"
        print(f"[analyze] {name}: {status} ({dt:.1f}s)")
        for n in notes:
            print(f"  note: {n}")
        for v in viols:
            print(f"  {v}")
    report["ok"] = total == 0

    if args.report:
        Path(args.report).write_text(json.dumps(report, indent=2) + "\n")
        print(f"[analyze] report -> {args.report}")
    if total:
        print(f"[analyze] FAILED: {total} violation(s)")
        return 1
    print("[analyze] all passes clean")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
