"""Documentation gate (CI `docs` job; `make docs-check`).  Stdlib only.

Three checks, all hard failures:

1. **Markdown links** — every relative link target in README.md, DESIGN.md,
   ROADMAP.md, and docs/*.md must exist on disk (anchors stripped; external
   schemes skipped).
2. **DESIGN.md section references** — every ``DESIGN.md §N`` / ``§N.M``
   citation in source docstrings/comments (src/, tests/, benchmarks/,
   examples/, docs/) must name a section heading that actually exists in
   DESIGN.md.  Stale citations rot fastest exactly where they are trusted
   most.
3. **Module-docstring audit** — every public module under src/repro/ must
   open with a docstring that cites its DESIGN.md section (the audit
   contract of DESIGN.md; presence of the docstring itself is additionally
   linted by ruff's pydocstyle D rules, scoped to src/repro in ruff.toml).

    python tools/check_docs.py
"""
from __future__ import annotations

import ast
import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

MD_FILES = ["README.md", "DESIGN.md", "ROADMAP.md"]
MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SECTION_REF = re.compile(r"DESIGN\.md\s+§(\d+(?:\.\d+)?)")
HEADING = re.compile(r"^#{2,3}\s+(\d+(?:\.\d+)?)[.\s]", re.MULTILINE)
SRC_DIRS = ["src", "tests", "benchmarks", "examples", "docs"]

# modules exempt from the docstring DESIGN-reference audit: generated or
# vendored leaf configs whose contract is fully covered by their package
AUDIT_EXEMPT: set[str] = set()


def _md_paths() -> list[str]:
    out = [os.path.join(ROOT, f) for f in MD_FILES]
    docs = os.path.join(ROOT, "docs")
    if os.path.isdir(docs):
        out += [os.path.join(docs, f) for f in sorted(os.listdir(docs))
                if f.endswith(".md")]
    return [p for p in out if os.path.exists(p)]


def check_markdown_links() -> list[str]:
    failures = []
    for path in _md_paths():
        text = open(path, encoding="utf-8").read()
        for target in MD_LINK.findall(text):
            if re.match(r"^[a-z]+://", target) or target.startswith("#") \
                    or target.startswith("mailto:"):
                continue
            rel = target.split("#", 1)[0]
            resolved = os.path.normpath(os.path.join(os.path.dirname(path),
                                                     rel))
            if not os.path.exists(resolved):
                failures.append(
                    f"{os.path.relpath(path, ROOT)}: broken link -> {target}")
    return failures


def _design_sections() -> set[str]:
    text = open(os.path.join(ROOT, "DESIGN.md"), encoding="utf-8").read()
    sections = set(HEADING.findall(text))
    # §N.M implies §N; a citation of §N is satisfied by the top heading
    sections |= {s.split(".")[0] for s in sections}
    return sections


def _source_files() -> list[str]:
    out = []
    for d in SRC_DIRS:
        for dirpath, _, files in os.walk(os.path.join(ROOT, d)):
            if "__pycache__" in dirpath:
                continue
            out += [os.path.join(dirpath, f) for f in files
                    if f.endswith((".py", ".md"))]
    return sorted(out)


def check_design_references() -> list[str]:
    sections = _design_sections()
    failures = []
    for path in _source_files():
        text = open(path, encoding="utf-8", errors="replace").read()
        for ref in SECTION_REF.findall(text):
            if ref not in sections:
                failures.append(
                    f"{os.path.relpath(path, ROOT)}: cites DESIGN.md §{ref}, "
                    f"which does not exist (sections: "
                    f"{', '.join(sorted(sections, key=lambda s: [int(x) for x in s.split('.')]))})")
    return failures


def check_module_docstrings() -> list[str]:
    failures = []
    src = os.path.join(ROOT, "src", "repro")
    for dirpath, _, files in os.walk(src):
        if "__pycache__" in dirpath:
            continue
        for f in sorted(files):
            if not f.endswith(".py"):
                continue
            path = os.path.join(dirpath, f)
            rel = os.path.relpath(path, ROOT)
            if rel in AUDIT_EXEMPT:
                continue
            tree = ast.parse(open(path, encoding="utf-8").read())
            doc = ast.get_docstring(tree)
            body = [n for n in tree.body
                    if not isinstance(n, (ast.Import, ast.ImportFrom,
                                          ast.Expr))]
            if doc is None:
                if not tree.body:
                    continue                     # empty stub __init__
                failures.append(f"{rel}: missing module docstring")
            elif "DESIGN.md" not in doc and body:
                failures.append(
                    f"{rel}: module docstring does not cite its DESIGN.md "
                    f"section (audit contract: every public module states "
                    f"its section + one-line contract)")
    return failures


def main() -> int:
    failures = (check_markdown_links() + check_design_references()
                + check_module_docstrings())
    if failures:
        print(f"DOCS GATE: {len(failures)} failure(s)")
        for msg in failures:
            print(f"  FAIL {msg}")
        return 1
    print("docs gate OK: links resolve, every cited DESIGN.md § exists, "
          "every src/repro module states its section")
    return 0


if __name__ == "__main__":
    sys.exit(main())
