"""Kernel-dispatch seam bit-identity (DESIGN.md §10): the Pallas kernels
(``kernel_backend="pallas"``, interpret mode on CPU) and the jnp reference
(``"jnp"``) must produce bit-identical engine output — StoreState, CreditState,
Results, IOMetrics — for all four SyncModes, through ``apply_batch``, the fused
``run_windows`` scan, and the 4-way ``run_windows_sharded`` mesh path.  SCAN
lanes are included so the fused reader-probe kernel (kernels/scan_probe/) is on
the hot path, not just wc_combine."""
from __future__ import annotations

import dataclasses

import numpy as np
import pytest

import jax

from repro.core import runner
from repro.core.credits import credit_init
from repro.core.engine import apply_batch, populate, store_init, store_view
from repro.core.types import EngineConfig, OpBatch, OpKind, SyncMode
from repro.dist import store as dstore
from repro.launch.mesh import make_local_mesh

MODES = [SyncMode.OSYNC, SyncMode.SPIN, SyncMode.MCS, SyncMode.CIDER]
W, B, N_SLOTS, HEAP, N_CNS = 3, 128, 64, 1024, 4
SCAN_MAX = 4


def _cfg(mode, backend, **kw):
    return EngineConfig(n_slots=N_SLOTS, heap_slots=HEAP, mode=mode,
                        scan_max=SCAN_MAX, kernel_backend=backend, **kw)


def _ops(seed=0):
    """(W, B) op arrays: every kind incl. SCAN, plus a strided cross-CN hot
    key so CIDER's pessimistic global-WC path actually runs."""
    rng = np.random.default_rng(seed)
    kinds = rng.choice(
        [OpKind.SEARCH, OpKind.INSERT, OpKind.UPDATE, OpKind.DELETE,
         OpKind.SCAN],
        size=(W, B), p=(0.25, 0.15, 0.3, 0.15, 0.15)).astype(np.int32)
    keys = rng.integers(0, N_SLOTS, (W, B)).astype(np.int32)
    values = rng.integers(0, 10_000, (W, B)).astype(np.int32)
    # SCAN counts ride `values`; keep them inside [1, scan_max]
    values = np.where(kinds == OpKind.SCAN,
                      rng.integers(1, SCAN_MAX + 1, (W, B)), values)
    keys[:, ::4] = 5
    kinds[:, ::4] = OpKind.UPDATE
    return kinds, keys, values


def _init(cfg):
    rng = np.random.default_rng(1)
    pop_keys = rng.choice(N_SLOTS, size=N_SLOTS // 2, replace=False)
    pop_vals = rng.integers(0, 10_000, pop_keys.shape[0])
    return (populate(cfg, store_init(cfg), pop_keys, pop_vals),
            credit_init(256), pop_keys, pop_vals)


def _assert_trees_equal(t1, t2, label):
    l1, l2 = jax.tree.leaves(t1), jax.tree.leaves(t2)
    assert len(l1) == len(l2)
    for a, b in zip(l1, l2):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b), err_msg=label)


@pytest.mark.parametrize("mode", MODES)
def test_apply_batch_backend_identity(mode):
    kinds, keys, values = _ops()
    out = {}
    for backend in ("jnp", "pallas"):
        cfg = _cfg(mode, backend)
        state, credits, _, _ = _init(cfg)
        ress, ios = [], []
        for w in range(W):
            batch = OpBatch.make(kinds[w], keys[w], values[w], n_cns=N_CNS)
            state, credits, res, io = apply_batch(cfg, state, credits, batch)
            ress.append(res)
            ios.append(io)
        out[backend] = (state, credits, ress, ios)
    _assert_trees_equal(out["jnp"], out["pallas"], f"apply_batch {mode.name}")


@pytest.mark.parametrize("mode", MODES)
def test_run_windows_backend_identity(mode):
    kinds, keys, values = _ops(seed=2)
    stream = runner.make_stream(kinds, keys, values, n_cns=N_CNS)
    out = {}
    for backend in ("jnp", "pallas"):
        cfg = _cfg(mode, backend)
        state, credits, _, _ = _init(cfg)
        out[backend] = runner.run_windows(cfg, state, credits, stream,
                                          io_per_window=True)
    _assert_trees_equal(out["jnp"], out["pallas"], f"run_windows {mode.name}")


@pytest.mark.parametrize("mode", [SyncMode.OSYNC, SyncMode.CIDER])
def test_run_windows_sharded_backend_identity(mode):
    mesh = make_local_mesh(data=4)   # conftest pins 8 host devices
    kinds, keys, values = _ops(seed=3)
    stream = runner.make_stream(kinds, keys, values, n_cns=N_CNS)
    out = {}
    for backend in ("jnp", "pallas"):
        cfg = _cfg(mode, backend)
        _, _, pop_keys, pop_vals = _init(cfg)
        sst = dstore.sharded_populate(
            cfg, 4, dstore.sharded_store_init(cfg, 4), pop_keys, pop_vals)
        st, cr, res, io = dstore.run_windows_sharded(
            cfg, mesh, sst, credit_init(256), stream, io_per_window=True)
        view = dstore.sharded_store_view(cfg, 4, st)
        out[backend] = (st, cr, res, io, view)
    _assert_trees_equal(out["jnp"], out["pallas"], f"sharded {mode.name}")


def test_auto_resolves_off_tpu():
    """"auto" off-TPU must mean the jnp reference (no interpret overhead on
    the CI hot path) — identical results to an explicit "jnp" config."""
    from repro.core.combine import resolve_backend
    impl, interpret = resolve_backend("auto")
    if jax.default_backend() != "tpu":
        assert impl == "jnp"
    impl_p, interpret_p = resolve_backend("pallas")
    assert impl_p == "pallas"
    if jax.default_backend() != "tpu":
        assert interpret_p


def test_bad_backend_rejected():
    from repro.core.combine import resolve_backend
    with pytest.raises(ValueError):
        resolve_backend("cuda-graphs")


def test_store_view_matches_across_backends():
    cfg_j = _cfg(SyncMode.CIDER, "jnp")
    cfg_p = _cfg(SyncMode.CIDER, "pallas")
    kinds, keys, values = _ops(seed=4)
    outs = []
    for cfg in (cfg_j, cfg_p):
        state, credits, _, _ = _init(cfg)
        for w in range(W):
            batch = OpBatch.make(kinds[w], keys[w], values[w], n_cns=N_CNS)
            state, credits, _, _ = apply_batch(cfg, state, credits, batch)
        outs.append(store_view(state))
    for a, b in zip(outs[0], outs[1]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
