"""SCAN end-to-end contracts (DESIGN.md §9).

The range op must (a) observe exactly the per-slot state at its batch
position — the serialization contract now includes reader ranks — (b) be
bit-identical across all four SyncModes and between the single-device and
4-way sharded runners (runs split at partition boundaries, rows psum-
reassembled), and (c) bill the documented per-mode traversal verbs.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import pytest

from repro.core import runner
from repro.core.credits import credit_init
from repro.core.engine import apply_batch, populate, store_init, store_view
from repro.core.oracle import OracleStore
from repro.core.types import (EngineConfig, IOMetrics, OpBatch, OpKind,
                              SyncMode, UnsupportedOpError)
from repro.dist import store as dstore
from repro.launch.mesh import make_local_mesh
from repro.stores import PointerArray, RaceHash, SmartART

MODES = [SyncMode.OSYNC, SyncMode.SPIN, SyncMode.MCS, SyncMode.CIDER]
W, B, N_SLOTS, N_CNS, SCAN_MAX = 4, 128, 64, 8, 8


def _scan_ops(seed=0):
    """(W, B) mixed stream: ~30% SCANs (length in ``values``), a strided
    cross-CN hot key so CIDER goes pessimistic, and scans crossing the
    4-way shard boundaries (slots 16/32/48 of 64)."""
    rng = np.random.default_rng(seed)
    kinds = rng.choice(
        [OpKind.SEARCH, OpKind.INSERT, OpKind.UPDATE, OpKind.DELETE,
         OpKind.SCAN],
        size=(W, B), p=(.15, .1, .3, .15, .3)).astype(np.int32)
    keys = rng.integers(0, N_SLOTS, (W, B)).astype(np.int32)
    values = rng.integers(0, 10_000, (W, B)).astype(np.int32)
    scan = kinds == OpKind.SCAN
    values[scan] = rng.integers(1, SCAN_MAX + 1, scan.sum())
    keys[:, ::4] = 5
    kinds[:, ::4] = OpKind.UPDATE
    # pin a few boundary-crossing scans per window
    keys[:, 1] = 14
    kinds[:, 1] = OpKind.SCAN
    values[:, 1] = SCAN_MAX
    return kinds, keys, values


def _init(cfg):
    rng = np.random.default_rng(1)
    pop_keys = rng.choice(N_SLOTS, size=N_SLOTS // 2, replace=False)
    pop_vals = rng.integers(0, 10_000, pop_keys.shape[0])
    return (populate(cfg, store_init(cfg), pop_keys, pop_vals),
            credit_init(256), pop_keys, pop_vals)


@pytest.mark.parametrize("mode", MODES)
def test_scan_matches_oracle_per_window(mode):
    """Rows/ok against the sequential oracle: a SCAN at batch position p
    sees writes at positions < p and not those at positions > p."""
    kinds, keys, values = _scan_ops()
    cfg = EngineConfig(n_slots=N_SLOTS, heap_slots=2048, mode=mode,
                       scan_max=SCAN_MAX)
    st, cr, pop_keys, pop_vals = _init(cfg)
    oracle = OracleStore()
    oracle.populate(pop_keys, pop_vals)
    for w in range(W):
        batch = OpBatch.make(kinds[w], keys[w], values[w], n_cns=N_CNS)
        st, cr, res, io = apply_batch(cfg, st, cr, batch)
        ok_o, val_o = oracle.apply(kinds[w], keys[w], values[w],
                                   scan_max=SCAN_MAX)
        np.testing.assert_array_equal(np.asarray(res.ok), ok_o,
                                      err_msg=f"window {w} ok")
        np.testing.assert_array_equal(np.asarray(res.value), val_o,
                                      err_msg=f"window {w} value")
        np.testing.assert_array_equal(np.asarray(res.rows), oracle.rows,
                                      err_msg=f"window {w} rows")


def test_scan_results_and_state_identical_across_modes():
    """The serialization contract: rows/ok/value and the final store view
    are a function of (batch, pre-state) only — never of the SyncMode."""
    kinds, keys, values = _scan_ops()
    outs = {}
    for mode in MODES:
        cfg = EngineConfig(n_slots=N_SLOTS, heap_slots=2048, mode=mode,
                           scan_max=SCAN_MAX)
        st, cr, _, _ = _init(cfg)
        stream = runner.make_stream(kinds, keys, values, n_cns=N_CNS)
        st, cr, res, io = runner.run_windows(cfg, st, cr, stream)
        outs[mode] = (np.asarray(res.rows), np.asarray(res.ok),
                      np.asarray(res.value), store_view(st))
    ref = outs[SyncMode.OSYNC]
    assert ref[0].sum() > 0, "stream produced no scan rows — test is vacuous"
    for mode in MODES[1:]:
        rows, ok, val, view = outs[mode]
        np.testing.assert_array_equal(rows, ref[0], err_msg=f"{mode} rows")
        np.testing.assert_array_equal(ok, ref[1], err_msg=f"{mode} ok")
        np.testing.assert_array_equal(val, ref[2], err_msg=f"{mode} value")
        for a, b in zip(view, ref[3]):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("mode", MODES)
def test_scan_sharded_bit_equal(mode):
    """Cross-shard scans: runs split at the partition boundaries, each shard
    counts its sub-run, and the psum-reassembled Results + the verb bill are
    bit-equal to the single-device run (the dist.store contract)."""
    mesh = make_local_mesh(data=4)
    kinds, keys, values = _scan_ops()
    cfg = EngineConfig(n_slots=N_SLOTS, heap_slots=2048, mode=mode,
                       scan_max=SCAN_MAX)
    st, cr, pop_keys, pop_vals = _init(cfg)
    stream = runner.make_stream(kinds, keys, values, n_cns=N_CNS)
    st1, cr1, res1, io1 = runner.run_windows(cfg, st, cr, stream,
                                             io_per_window=True)
    sst = dstore.sharded_populate(
        cfg, 4, dstore.sharded_store_init(cfg, 4), pop_keys, pop_vals)
    st2, cr2, res2, io2 = dstore.run_windows_sharded(
        cfg, mesh, sst, credit_init(256), stream, io_per_window=True)
    # the pinned lane scans [14, 22) across the slot-15/16 shard boundary
    assert int(np.asarray(res1.rows)[:, 1].sum()) > 0
    for f in dataclasses.fields(res1):
        np.testing.assert_array_equal(
            np.asarray(getattr(res1, f.name)), np.asarray(getattr(res2, f.name)),
            err_msg=f"Results.{f.name}")
    for f in dataclasses.fields(IOMetrics):
        np.testing.assert_array_equal(
            np.asarray(getattr(io1, f.name)), np.asarray(getattr(io2, f.name)),
            err_msg=f"IOMetrics.{f.name}")
    ex1, v1 = store_view(st1)
    ex2, v2 = dstore.sharded_store_view(cfg, 4, st2)
    np.testing.assert_array_equal(np.asarray(ex1), np.asarray(ex2))
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
    np.testing.assert_array_equal(np.asarray(cr1.credit), np.asarray(cr2.credit))


def test_scan_verb_bill_per_mode():
    """The documented per-mode traversal bill on a pure-scan window over a
    fully-populated store: probes = sum of counts, rows = probes, and the
    mode deltas are exactly version-re-reads (OSYNC), 2 lock CAS per leaf
    (SPIN), shared CAS+FAA per leaf (MCS), nothing for cold CIDER."""
    n = 32
    counts = np.array([4, 8, 2, 1], np.int32)
    starts = np.array([0, 8, 20, 28], np.int32)
    probes = int(counts.sum())
    bills = {}
    for mode in MODES:
        cfg = EngineConfig(n_slots=n, heap_slots=128, mode=mode, scan_max=8)
        st = populate(cfg, store_init(cfg), np.arange(n), np.arange(n))
        batch = OpBatch.make(np.full(4, OpKind.SCAN, np.int32), starts, counts,
                             n_cns=2)
        _, _, res, io = apply_batch(cfg, st, credit_init(64), batch)
        np.testing.assert_array_equal(np.asarray(res.rows), counts)
        bills[mode] = io.as_dict()
    base_reads = 4 * 1 + probes + probes          # index + leaf + value reads
    assert bills[SyncMode.CIDER]["reads"] == base_reads
    assert bills[SyncMode.CIDER]["cas"] == 0      # cold credit table: lock-free
    assert bills[SyncMode.CIDER]["faa"] == 0
    assert bills[SyncMode.OSYNC]["reads"] == base_reads + probes
    assert bills[SyncMode.SPIN]["cas"] == 2 * probes
    assert bills[SyncMode.MCS]["cas"] == probes
    assert bills[SyncMode.MCS]["faa"] == probes


def test_cider_hot_leaf_scans_pay_shared_queue_verbs():
    """Once credits mark a key hot, a scan crossing it pays the shared-mode
    CAS+FAA — and only for the hot leaves, not the whole run."""
    n = 32
    cfg = EngineConfig(n_slots=n, heap_slots=4096, mode=SyncMode.CIDER,
                       scan_max=8)
    st = populate(cfg, store_init(cfg), np.arange(n), np.arange(n))
    cr = credit_init(64)
    # warm the credit table: every CN hammers key 5 for a few windows
    hot = OpBatch.make(np.full(64, OpKind.UPDATE, np.int32),
                       np.full(64, 5, np.int32),
                       np.arange(64, dtype=np.int32), n_cns=8)
    for _ in range(4):
        st, cr, _, _ = apply_batch(cfg, st, cr, hot)
    assert int(np.asarray(cr.credit).sum()) > 0
    scan = OpBatch.make(np.full(1, OpKind.SCAN, np.int32),
                        np.array([2], np.int32), np.array([8], np.int32))
    _, _, res, io = apply_batch(cfg, st, cr, scan)
    assert int(np.asarray(res.rows)[0]) == 8
    d = io.as_dict()
    # exactly the credit-hot leaves of [2, 10) pay CAS+FAA; at least key 5
    # is hot, and never the whole run (6+ cold leaves stay lock-free)
    assert 1 <= d["cas"] <= 7 and d["cas"] == d["faa"]
    cold = OpBatch.make(np.full(1, OpKind.SCAN, np.int32),
                        np.array([20], np.int32), np.array([8], np.int32))
    _, _, _, io2 = apply_batch(cfg, st, cr, cold)
    assert io2.as_dict()["cas"] == 0              # cold run: lock-free


def test_scan_truncates_at_keyspace_end():
    cfg = EngineConfig(n_slots=16, heap_slots=64, mode=SyncMode.CIDER,
                       scan_max=8)
    st = populate(cfg, store_init(cfg), np.arange(16), np.arange(16))
    batch = OpBatch.make(np.full(1, OpKind.SCAN, np.int32),
                         np.array([14], np.int32), np.array([8], np.int32))
    _, _, res, _ = apply_batch(cfg, st, credit_init(64), batch)
    assert int(np.asarray(res.rows)[0]) == 2      # slots 14, 15 only


def test_scan_count_clipped_to_scan_max():
    cfg = EngineConfig(n_slots=64, heap_slots=256, mode=SyncMode.CIDER,
                       scan_max=4)
    st = populate(cfg, store_init(cfg), np.arange(64), np.arange(64))
    batch = OpBatch.make(np.full(1, OpKind.SCAN, np.int32),
                         np.array([0], np.int32), np.array([100], np.int32))
    _, _, res, _ = apply_batch(cfg, st, credit_init(64), batch)
    assert int(np.asarray(res.rows)[0]) == 4


def test_scan_reader_rank_counts_writers_ahead():
    """Queue order == batch position now includes reader ranks: a scan's
    anchor-leaf reader sits behind exactly the pessimistic writers with
    smaller positions on that slot."""
    cfg = EngineConfig(n_slots=16, heap_slots=64, mode=SyncMode.MCS,
                       scan_max=4)
    st = populate(cfg, store_init(cfg), np.arange(16), np.arange(16))
    kinds = np.array([OpKind.UPDATE, OpKind.UPDATE, OpKind.SCAN,
                      OpKind.UPDATE], np.int32)
    keys = np.array([3, 3, 3, 3], np.int32)
    values = np.array([7, 8, 2, 9], np.int32)
    batch = OpBatch.make(kinds, keys, values, n_cns=4)
    _, _, res, _ = apply_batch(cfg, st, credit_init(64), batch)
    assert int(np.asarray(res.rank)[2]) == 2      # behind the two pos<2 writers
    assert int(np.asarray(res.rows)[2]) == 2      # [3, 5): both present


def test_point_stores_reject_scan():
    kinds = np.array([OpKind.SCAN], np.int32)
    keys = np.array([0], np.int32)
    vals = np.array([4], np.int32)
    with pytest.raises(UnsupportedOpError, match="(?i)range"):
        PointerArray.create(64).apply(OpBatch.make(kinds, keys, vals))
    with pytest.raises(UnsupportedOpError, match="radix"):
        PointerArray.create(64).apply_stream(
            runner.make_stream(kinds[None], keys[None], vals[None]))
    with pytest.raises(UnsupportedOpError, match="hash"):
        RaceHash.create(64).apply(kinds, keys, vals)
    # the shared capability-rejection type stays catchable as the old ad-hoc
    # NotImplementedError for existing callers
    assert issubclass(UnsupportedOpError, NotImplementedError)


def test_smart_art_scan_stream_matches_oracle():
    """The radix store serves mixed scan streams through the fused runner;
    key runs ARE slot runs (in-order leaf addressing)."""
    rng = np.random.default_rng(3)
    nbits, b, w = 9, 128, 3
    n = 1 << nbits
    store = SmartART.create(key_bits=nbits, mode=SyncMode.CIDER, scan_max=8)
    pop = rng.choice(n, size=n // 2, replace=False)
    store = store.populate(pop, pop)
    oracle = OracleStore()
    oracle.populate(pop, pop)
    kinds = rng.choice([OpKind.SEARCH, OpKind.UPDATE, OpKind.SCAN,
                        OpKind.DELETE], size=(w, b),
                       p=(.3, .25, .3, .15)).astype(np.int32)
    keys = rng.integers(0, n, (w, b)).astype(np.int32)
    values = rng.integers(0, 10_000, (w, b)).astype(np.int32)
    scan = kinds == OpKind.SCAN
    values[scan] = rng.integers(1, 9, scan.sum())
    store, res, io = store.apply_stream(kinds, keys, values, n_cns=8)
    for i in range(w):
        ok_o, val_o = oracle.apply(kinds[i], keys[i], values[i], scan_max=8)
        np.testing.assert_array_equal(np.asarray(res.ok)[i], ok_o)
        np.testing.assert_array_equal(np.asarray(res.rows)[i], oracle.rows)


def test_modeled_latency_scan_orderings():
    """Scan-heavy stream: CIDER's lock-free cold traversal beats the re-read
    (OSYNC) and per-leaf locking (SPIN/MCS) on the modeled tail."""
    from repro.core.simnet import SimParams
    from repro.workloads.ycsb import YCSB, generate_ycsb_stream

    p = SimParams()
    ops = generate_ycsb_stream(YCSB["E"], 4, 256, 512, 64, seed=2)
    counts = np.where(ops.kinds == OpKind.SCAN, ops.values, 0)
    p99 = {}
    for mode in MODES:
        cfg = EngineConfig(n_slots=1024, heap_slots=2048, mode=mode,
                           scan_max=16)
        st = populate(cfg, store_init(cfg), np.arange(512), np.arange(512))
        stream = runner.make_stream(ops.kinds, ops.keys, ops.values, n_cns=64)
        _, _, res, _ = runner.run_windows(cfg, st, credit_init(256), stream)
        lat = runner.modeled_latency(cfg, ops.kinds, res, p,
                                     scan_counts=counts)
        assert np.isfinite(lat[~np.isnan(lat)]).all()
        p99[mode] = runner.latency_stats(lat).p99_us
    assert p99[SyncMode.CIDER] < p99[SyncMode.OSYNC]
    assert p99[SyncMode.CIDER] < p99[SyncMode.SPIN]
    assert p99[SyncMode.CIDER] < p99[SyncMode.MCS]
