"""Substrate tests: checkpoint save/restore (+elastic+async), deterministic
data pipeline, fleet monitor, serving scheduler + CIDER page table,
embedding-gradient combining, int8 compression, simulator invariants."""
from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.ckpt.checkpoint import AsyncCheckpointer, restore, save
from repro.core.sim import SimParams, make_streams, run_sim
from repro.core.types import SyncMode
from repro.data.pipeline import DataConfig, Pipeline
from repro.dist.compress import (ef_compress_tree, ef_decompress_tree,
                                 zeros_residuals)
from repro.dist.embed_grad import (apply_sparse_grad, combined_embed_grad,
                                   dense_embed_grad)
from repro.ft.failures import FleetMonitor
from repro.serving.pagetable import PageTable
from repro.serving.scheduler import Request, Scheduler
from repro.workloads.ycsb import WORKLOADS


def test_checkpoint_roundtrip(tmp_path):
    # the bf16 leaf matters: npz stores ml_dtypes as raw void bytes and
    # restore must reinterpret them (real param trees are bf16)
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32)},
            "w": jnp.full((4, 2), 1.5, jnp.bfloat16)}
    save(str(tmp_path), 7, tree)
    out, step = restore(str(tmp_path), jax.tree.map(jnp.zeros_like, tree))
    assert step == 7
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_checkpoint_async_and_latest(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path))
    tree = {"w": jnp.full((4,), 3.0)}
    ck.save_async(1, tree)
    ck.save_async(2, jax.tree.map(lambda x: x * 2, tree))
    ck.wait()
    out, step = restore(str(tmp_path), tree)
    assert step == 2
    np.testing.assert_allclose(np.asarray(out["w"]), 6.0)


def test_pipeline_deterministic_and_resumable():
    cfg = DataConfig(vocab=1000, seq_len=16, global_batch=4)
    p1, p2 = Pipeline(cfg), Pipeline(cfg)
    b1, b2 = p1.batch_at(13), p2.batch_at(13)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    # next-token alignment
    np.testing.assert_array_equal(np.asarray(b1["tokens"][:, 1:]),
                                  np.asarray(b1["targets"][:, :-1]))
    # hosts see disjoint streams
    b3 = Pipeline(DataConfig(vocab=1000, seq_len=16, global_batch=4,
                             n_hosts=2, host_id=1)).batch_at(13)
    assert not np.array_equal(np.asarray(b3["tokens"])[:2],
                              np.asarray(b1["tokens"])[:2])


def test_fleet_monitor_death_and_straggler():
    m = FleetMonitor(4, max_wait_s=10.0, strikes=2)
    for w in range(4):
        m.beat(w, step_time_s=1.0, now=0.0)
    assert m.dead_workers(now=5.0) == []
    # worker 2 stops beating
    for w in (0, 1, 3):
        m.beat(w, step_time_s=1.0, now=20.0)
    assert m.dead_workers(now=25.0) == [2]
    # worker 3 straggles twice -> excluded
    m.beat(3, step_time_s=10.0, now=21.0)
    m.beat(3, step_time_s=10.0, now=22.0)
    assert 3 in m.excluded
    assert set(m.active_set(now=25.0)) == {0, 1}


def test_pagetable_hit_miss_evict():
    pt = PageTable.create(n_slots=1024, block_tokens=4)
    toks = np.arange(16)
    keys = pt.block_keys(toks)
    _, hits, _ = pt.lookup(keys)
    assert not hits.any()
    ok, _ = pt.publish(keys, np.arange(len(keys)))
    assert ok.all()
    pages, hits, _ = pt.lookup(keys)
    assert hits.all()
    np.testing.assert_array_equal(pages, np.arange(len(keys)))
    ok, _ = pt.evict(keys[:1])
    assert ok.all()
    _, hits, _ = pt.lookup(keys)
    assert not hits[0] and hits[1:].all()


def test_scheduler_prefix_sharing():
    sched = Scheduler(n_slots=2, n_pages=64, page_size=4)
    shared = np.arange(8)
    for rid in range(3):
        sched.submit(Request(rid=rid,
                             tokens=np.concatenate([shared, [100 + rid] * 4]),
                             max_new=2))
    sched.step_admit()
    for slot, req in list(sched.active()):
        for _ in range(req.max_new):
            sched.complete_token(slot, 1)
    sched.step_admit()
    assert sched.stats["prefix_hits"] > 0       # later requests hit the prefix


def test_embed_grad_combining_equivalence():
    rng = np.random.default_rng(0)
    vocab, d, t = 64, 8, 256
    ids = jnp.asarray(rng.integers(0, 8, t), jnp.int32)   # heavy duplication
    g = jnp.asarray(rng.normal(size=(t, d)), jnp.float32)
    dense = dense_embed_grad(ids, g, vocab)
    hids, rows, uniq = combined_embed_grad(ids, g)
    table = jnp.zeros((vocab, d), jnp.float32)
    sparse = -apply_sparse_grad(table, hids, rows, uniq)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(sparse),
                               rtol=1e-5, atol=1e-5)
    assert int(uniq.sum()) == len(np.unique(np.asarray(ids)))  # I/O ∝ unique


def test_int8_error_feedback_reduces_bias():
    rng = np.random.default_rng(1)
    g = {"w": jnp.asarray(rng.normal(size=(64,)), jnp.float32)}
    res = zeros_residuals(g)
    acc = jnp.zeros((64,))
    for _ in range(50):
        q, s, res = ef_compress_tree(g, res)
        acc = acc + ef_decompress_tree(q, s)["w"]
    np.testing.assert_allclose(np.asarray(acc) / 50, np.asarray(g["w"]),
                               atol=2e-3)


@pytest.mark.slow
def test_sim_headline_ordering():
    """The paper's qualitative result: CIDER > MCS > OSYNC at 512 clients,
    OSYNC peaks early; CIDER p99 far below OSYNC."""
    p = SimParams(n_lanes=512, ticks=6144, max_ops=1024)
    streams = make_streams(p, WORKLOADS["write-intensive"], 1_000_000)
    r = {m: run_sim(p, m, streams, 512)
         for m in (SyncMode.OSYNC, SyncMode.MCS, SyncMode.CIDER)}
    o48 = run_sim(p, SyncMode.OSYNC, streams, 48)
    assert r[SyncMode.CIDER].throughput_mops > r[SyncMode.MCS].throughput_mops
    assert r[SyncMode.MCS].throughput_mops > r[SyncMode.OSYNC].throughput_mops
    assert o48.throughput_mops > 1.5 * r[SyncMode.OSYNC].throughput_mops
    assert r[SyncMode.CIDER].p99_us * 4 < r[SyncMode.OSYNC].p99_us


@pytest.mark.slow
def test_sim_deadlock_recovery():
    """§4.6: a client dying while holding the lock is detected via the
    stale epoch and the lock is repaired; the system keeps completing."""
    p = SimParams(n_lanes=64, ticks=6144, max_ops=512,
                  fail_lane=3, fail_tick=600, max_wait=512,
                  lanes_per_cn=1, local_wc=False)
    streams = make_streams(p, WORKLOADS["write-only"], 1)  # one key: all queue
    r = run_sim(p, SyncMode.MCS, streams, 16)
    assert r.deadlocks >= 1, "deadlock repair should have fired"
    assert r.ops_done > 100, "system should keep making progress after repair"


@pytest.mark.slow
def test_sim_multi_lane_crash_recovery():
    """§4.6 with a multi-CN crash: a SET of lanes dies at one tick
    (``SimParams.fail_lanes``); the one-key queue must be repaired past
    every dead lane's ticket (>= one repair per dead lane) and the
    survivors keep completing."""
    base = dict(n_lanes=64, ticks=6144, max_ops=512, fail_tick=600,
                max_wait=512, lanes_per_cn=1, local_wc=False)
    p = SimParams(**base, fail_lanes=(3, 5, 9))
    streams = make_streams(p, WORKLOADS["write-only"], 1)
    r = run_sim(p, SyncMode.MCS, streams, 16)
    assert r.deadlocks >= 3, "each dead lane's ticket needs a repair"
    assert r.ops_done > 100, "survivors should keep making progress"
