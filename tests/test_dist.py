"""Distribution-layer tests: sharding rule fallbacks, shard_map decode
attention vs the reference, activation ctx no-op without a mesh."""
from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.dist.ctx import shard, use_mesh
from repro.dist.sharding import spec_for
from repro.launch.mesh import make_local_mesh


def test_spec_for_divisibility_fallback():
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    class FakeMesh:
        shape = {"data": 16, "model": 16}

    m = FakeMesh()
    # kv=8 cannot shard 16 ways -> replicated
    assert spec_for((8, 128), ("kv", "head_dim"), m) == P(None, None)
    # heads=32 shards over model
    assert spec_for((32, 128), ("heads", "head_dim"), m) == P("model", None)
    # batch 256 shards over (pod, data) when present
    class PodMesh:
        shape = {"pod": 2, "data": 16, "model": 16}
    assert spec_for((256, 64), ("batch", None), PodMesh()) == P(("pod", "data"), None)
    # one mesh axis never assigned twice
    sp = spec_for((16, 16), ("heads", "kv"), m)
    axes_used = [a for a in sp if a is not None]
    assert len(axes_used) == len(set(axes_used))


def test_shard_noop_without_ctx():
    x = jnp.ones((4, 4))
    y = shard(x, ("act_batch", None))
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_decode_attn_spmd_matches_reference():
    from repro.dist.decode_attn import decode_attention_spmd
    from repro.models.attention import decode_attention
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ks = jax.random.split(jax.random.key(0), 3)
    b, h, kh, d, smax = 2, 8, 2, 32, 64
    q = jax.random.normal(ks[0], (b, 1, h, d), jnp.float32)
    kc = jax.random.normal(ks[1], (b, smax, kh, d), jnp.float32)
    vc = jax.random.normal(ks[2], (b, smax, kh, d), jnp.float32)
    length = jnp.int32(40)
    ref = decode_attention(q, kc, vc, length)
    out = decode_attention_spmd(mesh, q, kc, vc, length, seq_axis="model")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
