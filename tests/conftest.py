"""Shared pytest setup.

The XLA host device count must be pinned BEFORE jax initializes its backend
(first device query locks it), so the sharded-store tests get a real >=2-way
``data`` mesh on CPU.  conftest is imported before any test module, which is
the only reliable hook for this.
"""
import os

_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=8").strip()
