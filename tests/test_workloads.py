"""Workload-generator statistics: the Zipf samplers against the analytic
pmf (chi-square), skew ordering, and determinism of the jittable path."""
from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.workloads.zipf import (ZipfSampler, sample_zipf_jax, scramble,
                                  zipf_cdf_table)

N, THETA, DRAWS = 512, 0.99, 200_000


def _zipf_pmf(n, theta):
    w = np.arange(1, n + 1, dtype=np.float64) ** -theta
    return w / w.sum()


def _chi2(counts, probs, draws):
    exp = probs * draws
    return float(((counts - exp) ** 2 / np.maximum(exp, 1e-12)).sum())


def test_zipf_sampler_chi_square():
    """Rejection-inversion draws fit Zipf(theta) — chi-square over all 512
    ranks stays below the 99.9% critical value of chi2(511) (~625)."""
    x = ZipfSampler(N, THETA, seed=0).sample(DRAWS, scrambled=False)
    counts = np.bincount(x, minlength=N)
    assert counts.shape[0] == N          # never samples outside [0, n)
    chi2 = _chi2(counts, _zipf_pmf(N, THETA), DRAWS)
    assert chi2 < 650, f"chi2={chi2:.1f} for dof={N - 1}"


def test_zipf_table_sampler_chi_square_and_determinism():
    """The jittable CDF-table sampler matches the pmf on its exact head and
    is counter-based deterministic (same key -> same stream)."""
    cdf = jnp.asarray(zipf_cdf_table(N, THETA, head=N))
    u = jax.random.uniform(jax.random.key(1), (DRAWS,))
    ranks = np.asarray(jnp.searchsorted(cdf, u))
    counts = np.bincount(ranks, minlength=N + 1)[:N]
    chi2 = _chi2(counts, _zipf_pmf(N, THETA), DRAWS)
    assert chi2 < 650, f"chi2={chi2:.1f}"
    a = sample_zipf_jax(jax.random.key(7), (4096,), cdf, N, head=N)
    b = sample_zipf_jax(jax.random.key(7), (4096,), cdf, N, head=N)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_zipf_skew_ordering():
    """Higher theta -> more mass on the hottest key; theta=0 is uniform."""
    top_frac = {}
    for theta in (0.0, 0.8, 1.2):
        x = ZipfSampler(10_000, theta, seed=2).sample(100_000, scrambled=False)
        top_frac[theta] = float(np.mean(x == np.bincount(x).argmax()))
    assert top_frac[0.0] < top_frac[0.8] < top_frac[1.2]
    assert top_frac[0.0] < 5e-3          # uniform: no hot key
    assert top_frac[1.2] > 0.05          # heavy skew: one very hot key


def test_scramble_scatters_hot_ranks():
    """Hot ranks land far apart in key space and keep their identity (the
    scramble is a fixed function of the rank, not a fresh RNG draw)."""
    n = 1 << 20
    ranks = np.arange(16)
    ids = scramble(ranks, n)
    assert np.unique(ids).size == 16                     # no collisions here
    np.testing.assert_array_equal(ids, scramble(ranks, n))
    assert ids.max() - ids.min() > n // 8                # scattered, not adjacent


def test_generate_ops_write_partition_is_disjoint():
    """delete/insert/update partition the write fraction DISJOINTLY: with
    both fractions > 0 the delivered mix must match write_ratio * fraction
    (the old independent-mask draw let INSERT overwrite DELETE kinds)."""
    from repro.core.types import OpKind
    from repro.workloads.ycsb import WorkloadSpec, generate_ops

    n = 200_000
    spec = WorkloadSpec("mix", write_ratio=0.6, read_ratio=0.4,
                        delete_fraction=0.3, insert_fraction=0.2)
    ops = generate_ops(spec, n, 10_000, 8, seed=3)
    frac = {k: float(np.mean(ops.kinds == k))
            for k in (OpKind.SEARCH, OpKind.INSERT, OpKind.UPDATE,
                      OpKind.DELETE)}
    assert frac[OpKind.SEARCH] == pytest.approx(0.4, abs=0.01)
    assert frac[OpKind.DELETE] == pytest.approx(0.6 * 0.3, abs=0.01)
    assert frac[OpKind.INSERT] == pytest.approx(0.6 * 0.2, abs=0.01)
    assert frac[OpKind.UPDATE] == pytest.approx(0.6 * 0.5, abs=0.01)
    # INSERTs draw fresh keys beyond the populated universe; nobody else does
    ins = ops.kinds == OpKind.INSERT
    assert (ops.keys[ins] >= 10_000).all()
    assert (ops.keys[~ins] < 10_000).all()


def test_generate_ops_rejects_overfull_partition():
    from repro.workloads.ycsb import WorkloadSpec, generate_ops

    spec = WorkloadSpec("bad", write_ratio=1.0, read_ratio=0.0,
                        delete_fraction=0.7, insert_fraction=0.7)
    with pytest.raises(ValueError, match="must be <= 1"):
        generate_ops(spec, 10, 100, 1)
