"""Per-architecture smoke tests: reduced same-family configs, one forward +
one gradient step on CPU; shapes + finiteness.  Decode paths additionally
checked against prefill logits (state handoff consistency)."""
from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ALL_ARCHS, get_config
from repro.models.common import count_params, unbox
from repro.models.frontend import fake_frontend_batch
from repro.models.model import Model

B, S = 2, 32


def _batch(cfg, key):
    ks = jax.random.split(key, 3)
    s_text = S - (cfg.n_patches if cfg.frontend == "vision" else 0)
    batch = {}
    if cfg.family == "encoder":
        batch["tokens"] = jnp.zeros((B, 0), jnp.int32)
        batch["targets"] = jax.random.randint(ks[0], (B, S), 0, cfg.vocab)
    else:
        batch["tokens"] = jax.random.randint(ks[0], (B, s_text), 0, cfg.vocab)
        batch["targets"] = jax.random.randint(ks[1], (B, s_text), 0, cfg.vocab)
    fr = fake_frontend_batch(cfg, ks[2], B, S)
    if fr is not None:
        batch["frontend"] = fr
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_and_grad_step(arch):
    cfg = get_config(arch, smoke=True)
    model = Model(cfg)
    params = unbox(model.init(jax.random.key(0)))
    assert count_params(params) > 0
    batch = _batch(cfg, jax.random.key(1))

    (loss, metrics), grads = jax.value_and_grad(
        model.loss_fn, has_aux=True)(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    gnorm = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
                for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, f"{arch}: bad grads"
    # one SGD step changes the loss
    params2 = jax.tree.map(lambda p, g: p - 0.1 * g.astype(p.dtype),
                           params, grads)
    loss2, _ = model.loss_fn(params2, batch)
    assert np.isfinite(float(loss2))


@pytest.mark.parametrize("arch", [a for a in ALL_ARCHS
                                  if get_config(a, smoke=True).has_decode])
def test_decode_matches_prefill(arch):
    """prefill(tokens[:t]) logits == decode steps fed one token at a time."""
    cfg = get_config(arch, smoke=True)
    model = Model(cfg)
    params = unbox(model.init(jax.random.key(0)))
    s = 8
    s_text = s - (cfg.n_patches if cfg.frontend == "vision" else 0)
    tokens = jax.random.randint(jax.random.key(2), (B, s_text), 0, cfg.vocab)
    fr = fake_frontend_batch(cfg, jax.random.key(3), B, s)
    from repro.models import transformer as tfm
    logits_all, _, _ = tfm.forward(cfg, params, tokens, fr)
    # decode token-by-token (text part only, no image prefix for decode test)
    if cfg.frontend == "vision":
        pytest.skip("vlm decode covered via dense path; prefix handled in serve")
    state = model.init_decode_state(B, smax=s)
    outs = []
    for t in range(s_text):
        lg, state = model.decode_step(params, state, tokens[:, t:t + 1],
                                      jnp.int32(t))
        outs.append(lg[:, 0, :])
    dec = jnp.stack(outs, 1)
    # bf16 recurrences accumulate ~1% per layer; compare loosely but also
    # check argmax agreement (the decode-path semantic that matters)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(logits_all, np.float32),
                               rtol=1e-1, atol=1.5e-1)
    agree = (np.asarray(dec).argmax(-1) == np.asarray(logits_all).argmax(-1))
    assert agree.mean() >= 0.9


def test_ssd_chunked_matches_naive():
    from repro.models.ssm import ssd_chunked, ssd_naive
    key = jax.random.key(0)
    b, s, h, p, g, n = 2, 32, 4, 8, 2, 16
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    Bm = jax.random.normal(ks[3], (b, s, g, n)) * 0.5
    Cm = jax.random.normal(ks[4], (b, s, g, n)) * 0.5
    D = jnp.ones((h,))
    y1, st1 = ssd_chunked(x, dt, A, Bm, Cm, D, chunk=8)
    y2, st2 = ssd_naive(x, dt, A, Bm, Cm, D)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-3,
                               atol=1e-3)
    np.testing.assert_allclose(np.asarray(st1), np.asarray(st2), rtol=1e-3,
                               atol=1e-3)


def test_flash_attention_matches_naive():
    from repro.models.attention import gqa_attention
    key = jax.random.key(1)
    b, s, h, kh, d = 2, 64, 8, 2, 16
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kh, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kh, d), jnp.float32)
    for causal, window in [(True, 0), (False, 0), (True, 16)]:
        out = gqa_attention(q, k, v, causal=causal, window=window, chunk=16)
        # naive reference
        qg = q.reshape(b, s, kh, h // kh, d)
        sc = jnp.einsum("bqkgd,bckd->bqkgc", qg, k) * d ** -0.5
        qp, kp = jnp.arange(s), jnp.arange(s)
        valid = jnp.ones((s, s), bool)
        if causal:
            valid &= kp[None, :] <= qp[:, None]
        if window:
            valid &= kp[None, :] > qp[:, None] - window
        sc = jnp.where(valid[None, :, None, None, :], sc, -1e30)
        ref = jnp.einsum("bqkgc,bckd->bqkgd",
                         jax.nn.softmax(sc, -1), v).reshape(b, s, h, d)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)


def test_param_counts_match_estimates():
    """Full configs: analytic estimate vs exact tree count (within 2%)."""
    for arch in ["qwen3-0.6b", "mamba2-1.3b", "deepseek-moe-16b"]:
        cfg = get_config(arch)
        model = Model(cfg)
        exact = model.n_params()
        est = cfg.param_count_estimate()
        assert abs(exact - est) / est < 0.1, (arch, exact, est)


def test_full_config_param_scale():
    """Headline parameter counts are in the right ballpark."""
    checks = {"mistral-large-123b": (110e9, 135e9),
              "kimi-k2-1t-a32b": (0.9e12, 1.15e12),
              "qwen3-0.6b": (0.4e9, 0.8e9)}
    for arch, (lo, hi) in checks.items():
        n = Model(get_config(arch)).n_params()
        assert lo < n < hi, f"{arch}: {n/1e9:.1f}B outside [{lo/1e9},{hi/1e9}]B"
