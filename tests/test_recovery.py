"""Crash-recovery subsystem contracts (``repro.recovery`` + engine step 5b):
the liveness plane drops dead CNs' ops at the window boundary, orphaned
locks are repaired deterministically with the §4.6 mode asymmetry (MCS
strands a chain, CIDER/SPIN one lock per key), the 4-way failover bill is
bit-equal to the single-device drop-mask run, and modeled latency grows
monotonically with the lease."""
from __future__ import annotations

import dataclasses

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import runner
from repro.core.credits import CreditState, credit_init, credit_slot
from repro.core.engine import apply_batch, populate, store_init, store_view
from repro.core.simnet import SimParams
from repro.core.types import EngineConfig, IOMetrics, OpBatch, OpKind, SyncMode
from repro.dist import store as dstore
from repro.recovery import (FailoverEvent, crash, elastic, rolling,
                            run_recovery, run_recovery_sharded,
                            time_to_repair)
from repro.workloads.recovery import RECOVERY_SCENARIOS

W, B, NK, NCN = 8, 128, 256, 16
HEAP = NK + W * B


def _cfg(mode):
    return EngineConfig(n_slots=NK, heap_slots=HEAP, mode=mode)


def _warm_credits(keys, table=64, amount=100):
    credit = jnp.zeros((table,), jnp.int32).at[
        credit_slot(jnp.asarray(keys, jnp.int32), table)].set(amount)
    return CreditState(credit=credit, retry_record=jnp.zeros((table,), jnp.int32))


def _hot_batch(n_cns=4, key=5):
    """One UPDATE per CN on a single hot key (local WC cannot absorb it)."""
    kinds = np.full(n_cns, OpKind.UPDATE, np.int32)
    keys = np.full(n_cns, key, np.int32)
    vals = np.arange(n_cns, dtype=np.int32)
    return OpBatch.make(kinds, keys, vals, n_cns=n_cns)


def _crash_masks(n_cns, dead):
    alive = np.ones(n_cns, bool)
    alive[list(dead)] = False
    died = np.zeros(n_cns, bool)
    died[list(dead)] = True
    return jnp.asarray(alive), jnp.asarray(died)


# ---------------------------------------------------------------------------
# engine-level: the repair asymmetry and the stranding lifecycle
# ---------------------------------------------------------------------------

def test_mode_asymmetry_mcs_chain_vs_single_lock():
    """Two CNs die queued on one key: MCS repairs the whole dead chain (2
    break CASes), CIDER and SPIN repair the key's single lock word (1);
    SPIN additionally burns lease polls; OSYNC is lock-free (0)."""
    batch = _hot_batch(n_cns=4, key=5)
    alive, died = _crash_masks(4, dead=[1, 2])
    pk = np.arange(NK)
    repair = {}
    for mode in (SyncMode.OSYNC, SyncMode.SPIN, SyncMode.MCS, SyncMode.CIDER):
        cfg = _cfg(mode)
        st = populate(cfg, store_init(cfg), pk, pk)
        credits = _warm_credits([5])   # CIDER: the hot key is pessimistic
        _, _, res, io = apply_batch(cfg, st, credits, batch,
                                    alive=alive, died=died)
        repair[mode] = int(io.repair_cas)
        # dropped ops never complete
        assert not np.asarray(res.ok)[1] and not np.asarray(res.ok)[2]
        if mode != SyncMode.OSYNC:
            assert np.asarray(res.orphan_wait)[[0, 3]].min() > 0
    assert repair[SyncMode.OSYNC] == 0
    assert repair[SyncMode.MCS] == 2          # the dead chain
    assert repair[SyncMode.CIDER] == 1        # one lock entry per queue
    assert repair[SyncMode.SPIN] > repair[SyncMode.CIDER]  # + lease polls


def test_dead_delete_strands_its_own_node():
    """DELETEs are never locally combined on the live path (they lock
    independently), so a CN dying with an UPDATE and a DELETE in flight on
    the same key strands TWO MCS nodes, not one."""
    kinds = np.array([OpKind.UPDATE, OpKind.DELETE,
                      OpKind.UPDATE, OpKind.UPDATE], np.int32)
    keys = np.full(4, 5, np.int32)
    batch = OpBatch.make(kinds, keys, np.arange(4, dtype=np.int32), n_cns=2)
    alive, died = _crash_masks(2, dead=[0])   # CN0 = lanes 0,1 (UPDATE+DELETE)
    cfg = _cfg(SyncMode.MCS)
    pk = np.arange(NK)
    st = populate(cfg, store_init(cfg), pk, pk)
    _, _, res, io = apply_batch(cfg, st, credit_init(64), batch,
                                alive=alive, died=died)
    assert int(io.repair_cas) == 2
    # lane 2 is locally combined into lane 3 (same key, same CN) and never
    # touches the lock; the surviving executor waits out both dead nodes
    assert np.asarray(res.orphan_wait)[2:].tolist() == [0, 2]


def test_deferred_strand_and_lazy_repair():
    """A key whose only writers died has no waiter to break the lock: it
    stays in ``StoreState.stranded`` (counted in ``orphan_windows``) until
    the next locker arrives and repairs it."""
    cfg = _cfg(SyncMode.MCS)
    pk = np.arange(NK)
    st = populate(cfg, store_init(cfg), pk, pk)
    batch = _hot_batch(n_cns=4, key=7)
    alive, died = _crash_masks(4, dead=[0, 1, 2, 3])   # everyone dies
    st, _, res, io = apply_batch(cfg, st, credit_init(64), batch,
                                 alive=alive, died=died)
    assert int(io.repair_cas) == 0
    assert int(io.orphan_windows) == 1        # one slot stranded at window end
    assert int(st.stranded[7]) == 4           # the whole chain
    assert not np.asarray(res.ok).any()
    # next window: a live writer on the key repairs the chain lazily
    batch2 = _hot_batch(n_cns=4, key=7)
    live = jnp.ones(4, bool)
    st2, _, res2, io2 = apply_batch(cfg, st, credit_init(64), batch2,
                                    alive=live, died=jnp.zeros(4, bool))
    assert int(io2.repair_cas) == 4
    assert int(io2.orphan_windows) == 0
    assert int(st2.stranded[7]) == 0
    assert np.asarray(res2.orphan_wait).max() == 4


def test_all_alive_masks_are_failure_free_bitexact():
    """alive=ones / died=zeros must not change a single counter or result
    bit versus the legacy no-liveness call."""
    rng = np.random.default_rng(0)
    kinds = rng.choice([OpKind.SEARCH, OpKind.INSERT, OpKind.UPDATE,
                        OpKind.DELETE], size=B, p=(.3, .15, .4, .15))
    keys = rng.integers(0, NK, B)
    vals = rng.integers(0, 10_000, B)
    batch = OpBatch.make(kinds.astype(np.int32), keys, vals, n_cns=NCN)
    pk = np.arange(NK)
    for mode in (SyncMode.OSYNC, SyncMode.CIDER):
        cfg = _cfg(mode)
        st = populate(cfg, store_init(cfg), pk, pk)
        a = apply_batch(cfg, st, credit_init(64), batch)
        b = apply_batch(cfg, st, credit_init(64), batch,
                        alive=jnp.ones(NCN, bool), died=jnp.zeros(NCN, bool))
        for x, y in zip(a, b):
            for f in dataclasses.fields(x):
                np.testing.assert_array_equal(
                    np.asarray(getattr(x, f.name)),
                    np.asarray(getattr(y, f.name)), err_msg=f.name)


# ---------------------------------------------------------------------------
# liveness schedules
# ---------------------------------------------------------------------------

def test_liveness_builders_contracts():
    s = crash(W, NCN, dead_cns=[1, 3], at_window=2)
    assert s.alive.shape == (W, NCN)
    assert s.died()[0].sum() == 0                      # row 0: nothing in flight
    assert s.died()[2, [1, 3]].all() and s.died()[3:].sum() == 0
    assert s.first_crash_window() == 2
    r = rolling(12, 4, down_windows=2, start=1, group=1)
    assert (r.n_alive() <= 4).all() and r.alive[0].all()
    # every CN goes down exactly down_windows windows (12 windows fit the
    # full wave: start 1 + 4 groups * stagger 2 + down 2 <= 12)
    assert (r.alive.shape[0] - r.alive.sum(0) == 2).all()
    e = elastic(W, 4, events=[(2, [2, 3], True), (5, [0], False)],
                initial_alive=[0, 1])
    assert e.n_alive().tolist() == [2, 2, 4, 4, 4, 3, 3, 3]
    assert e.died()[5, 0] and not e.died()[2].any()    # join strands nothing


def test_dead_cn_ops_are_dropped_exactly_per_schedule():
    ops, sched = RECOVERY_SCENARIOS["crash_storm"].generate(
        W, B, NK, 16, NCN, seed=1, crash_window=3)
    stream = runner.make_stream(ops.kinds, ops.keys, ops.values, n_cns=NCN,
                                alive=sched.alive)
    cfg = _cfg(SyncMode.MCS)
    pk = np.arange(NK)
    run = run_recovery(cfg, populate(cfg, store_init(cfg), pk, pk),
                       credit_init(256), stream)
    ok = np.asarray(run.results.ok)
    dropped = ~sched.drop_mask(B)
    assert dropped.any()
    assert not ok[dropped].any()                # dead lanes never complete
    np.testing.assert_array_equal(
        run.valid, (np.asarray(ops.kinds) != OpKind.NOP) & ~dropped)


# ---------------------------------------------------------------------------
# orchestrated runs: determinism, failover equality, lease monotonicity
# ---------------------------------------------------------------------------

def test_orphan_repair_is_deterministic():
    outs = []
    for _ in range(2):
        ops, sched = RECOVERY_SCENARIOS["crash_storm"].generate(
            W, B, NK, 16, NCN, seed=9, crash_window=3)
        stream = runner.make_stream(ops.kinds, ops.keys, ops.values,
                                    n_cns=NCN, alive=sched.alive)
        cfg = _cfg(SyncMode.CIDER)
        pk = np.arange(NK)
        run = run_recovery(cfg, populate(cfg, store_init(cfg), pk, pk),
                           credit_init(256), stream)
        outs.append(run)
    for f in dataclasses.fields(IOMetrics):
        np.testing.assert_array_equal(
            np.asarray(getattr(outs[0].io, f.name)),
            np.asarray(getattr(outs[1].io, f.name)), err_msg=f.name)
    np.testing.assert_array_equal(np.asarray(outs[0].results.orphan_wait),
                                  np.asarray(outs[1].results.orphan_wait))
    t = time_to_repair(outs[0].io, 3)
    assert t["repair_cas"] > 0


@pytest.mark.parametrize("mode", [SyncMode.MCS, SyncMode.CIDER])
def test_failover_bill_equals_single_device_drop_mask_run(mode):
    """Shards 1,3 die at the crash window and survivors re-own their slots:
    the per-window bill, results, and final store view must be bit-equal to
    the single-device run with the same CN drop mask."""
    ops, sched = RECOVERY_SCENARIOS["crash_storm"].generate(
        W, B, NK, 16, NCN, seed=3, crash_window=4)
    cfg = _cfg(mode)
    pk = np.arange(NK)

    stream = runner.make_stream(ops.kinds, ops.keys, ops.values, n_cns=NCN,
                                alive=sched.alive)
    single = run_recovery(cfg, populate(cfg, store_init(cfg), pk, pk),
                          credit_init(256), stream)

    stream2 = runner.make_stream(ops.kinds, ops.keys, ops.values, n_cns=NCN,
                                 alive=sched.alive)
    sst = dstore.sharded_populate(cfg, 4, dstore.sharded_store_init(cfg, 4),
                                  pk, pk)
    sharded = run_recovery_sharded(cfg, 4, sst, credit_init(256), stream2,
                                   failovers=[FailoverEvent(4, (0, 2))])
    assert sharded.n_shards == 2
    assert sharded.recovery_io[0]["dead_shards"] == [1, 3]
    for f in dataclasses.fields(IOMetrics):
        np.testing.assert_array_equal(
            np.asarray(getattr(single.io, f.name)),
            np.asarray(getattr(sharded.io, f.name)),
            err_msg=f"IOMetrics.{f.name}")
    for f in dataclasses.fields(single.results):
        np.testing.assert_array_equal(
            np.asarray(getattr(single.results, f.name)),
            np.asarray(getattr(sharded.results, f.name)),
            err_msg=f"Results.{f.name}")
    ex1, v1 = store_view(single.state)
    ex2, v2 = dstore.sharded_store_view(cfg, 2, sharded.state)
    np.testing.assert_array_equal(np.asarray(ex1), np.asarray(ex2))
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))


def test_lease_expiry_latency_is_monotone():
    """p99 must grow strictly with the lease while orphan waits exist —
    the knob the operator trades detection speed against false repairs."""
    ops, sched = RECOVERY_SCENARIOS["crash_storm"].generate(
        W, B, NK, 16, NCN, seed=5, crash_window=3)
    cfg = _cfg(SyncMode.MCS)
    pk = np.arange(NK)
    stream = runner.make_stream(ops.kinds, ops.keys, ops.values, n_cns=NCN,
                                alive=sched.alive)
    run = run_recovery(cfg, populate(cfg, store_init(cfg), pk, pk),
                       credit_init(256), stream)
    assert np.asarray(run.results.orphan_wait).max() > 0
    kinds = np.asarray(ops.kinds)
    p99s = []
    for lease in (64, 256, 1024):
        p = dataclasses.replace(SimParams(), lease_us=lease)
        lat = runner.modeled_latency(cfg, kinds, run.results, p,
                                     valid=run.valid)
        p99s.append(runner.latency_stats(lat).p99_us)
    assert p99s[0] < p99s[1] < p99s[2]
