"""Dynamic-contention scenarios: generator contracts and the AIMD
adaptation regression — per-window ``pess_ratio`` must rise while a hotspot
is hot and the hotspot's credits must drain (multiplicative decrease)
within a bounded number of windows after it moves, for ``SyncMode.CIDER``
on both the single-device and the 4-way CPU-mesh paths."""
from __future__ import annotations

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import runner
from repro.core.credits import CreditState, _slot, credit_init
from repro.core.engine import apply_batch, populate, store_init
from repro.core.types import EngineConfig, OpBatch, OpKind, SyncMode
from repro.dist import store as dstore
from repro.launch.mesh import make_local_mesh
from repro.workloads.dynamic import (SCENARIOS, churn, flash_crowd,
                                     hotspot_shift, skew_drift)

W, B, NK, NC, HK, SHIFT, TBL = 14, 256, 512, 64, 4, 7, 1024
N_SHARDS = 4


# ---------------------------------------------------------------------------
# generator contracts
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", list(SCENARIOS))
def test_scenario_stream_contract(name):
    sc = SCENARIOS[name]
    ops = sc.generate(6, 128, NK, NC, seed=1)
    assert ops.kinds.shape == ops.keys.shape == ops.values.shape == (6, 128)
    assert set(np.unique(ops.kinds)) <= {int(k) for k in OpKind}
    assert ops.keys.min() >= 0 and ops.keys.max() < NK
    np.testing.assert_array_equal(ops.clients[0], np.arange(128) % NC)
    # drop-in for the fused runner
    stream = runner.make_stream(ops.kinds, ops.keys, ops.values, n_cns=16)
    assert stream.shape == (6, 128)


def test_hotspot_shift_moves_the_hot_set():
    ops, (set_a, set_b) = hotspot_shift(W, B, NK, NC, seed=5, hot_keys=HK,
                                        shift_window=SHIFT, return_sets=True)
    assert not set(set_a) & set(set_b)
    pre = np.bincount(ops.keys[:SHIFT].ravel(), minlength=NK)
    post = np.bincount(ops.keys[SHIFT:].ravel(), minlength=NK)
    # the hot mass moves from A to B...
    assert pre[set_a].sum() > 5 * pre[set_b].sum()
    assert post[set_b].sum() > 5 * post[set_a].sum()
    # ...but every old hot key keeps an UPDATE drain probe per window
    # (background Zipf traffic may add SEARCHes on A; the probes are writes)
    for w in range(SHIFT, W):
        upd_a = np.isin(ops.keys[w], set_a) & (ops.kinds[w] == OpKind.UPDATE)
        assert np.bincount(ops.keys[w][upd_a], minlength=NK)[set_a].min() >= 1


def test_flash_crowd_ramps_up_then_down():
    # 2 hot keys so the crowd's per-key peak clearly tops the Zipf head
    ops = flash_crowd(13, B, NK, NC, seed=2, peak_window=6, peak_frac=0.8,
                      hot_keys=2)
    top = [np.bincount(ops.keys[w], minlength=NK).max() for w in (0, 6, 12)]
    assert top[1] > 2 * top[0] and top[1] > 2 * top[2]


def test_churn_alternates_insert_delete_phases_on_empty_region():
    ops = churn(8, B, NK, NC, seed=3, phase_len=2, populated_frac=0.5)
    n_pop = NK // 2
    for w in range(8):
        ins = ops.kinds[w] == OpKind.INSERT
        dele = ops.kinds[w] == OpKind.DELETE
        churny = ins | dele
        assert churny.any()
        assert (ops.keys[w][churny] >= n_pop).all()    # only the empty region
        assert (ops.keys[w][~churny] < n_pop).all()    # base mix stays put
        if (w // 2) % 2 == 0:
            assert ins.any() and not dele.any()
        else:
            assert dele.any() and not ins.any()


def test_skew_drift_increases_concentration():
    ops = skew_drift(10, 2048, NK, NC, seed=4, theta0=0.2, theta1=1.2)
    first = np.bincount(ops.keys[0], minlength=NK).max()
    last = np.bincount(ops.keys[-1], minlength=NK).max()
    assert last > 2 * first


# ---------------------------------------------------------------------------
# AIMD adaptation end-to-end (the §4.3 path a stationary stream never takes)
# ---------------------------------------------------------------------------

def _cfg():
    return EngineConfig(n_slots=NK, heap_slots=NK + W * B,
                        mode=SyncMode.CIDER)


def _traced(path, cfg, credits, stream, pop_keys):
    if path == "single":
        st = populate(cfg, store_init(cfg), pop_keys, pop_keys)
        return runner.run_windows_traced(cfg, st, credits, stream)
    mesh = make_local_mesh(data=N_SHARDS)
    st = dstore.sharded_populate(
        cfg, N_SHARDS, dstore.sharded_store_init(cfg, N_SHARDS),
        pop_keys, pop_keys)
    return dstore.run_windows_sharded_traced(cfg, mesh, st, credits, stream)


@pytest.mark.parametrize("path", ["single", f"sharded{N_SHARDS}"])
def test_cider_adapts_across_hotspot_shift(path):
    ops, (set_a, set_b) = hotspot_shift(W, B, NK, NC, seed=5, hot_keys=HK,
                                        shift_window=SHIFT, return_sets=True)
    stream = runner.make_stream(ops.kinds, ops.keys, ops.values, n_cns=NC)
    cfg = _cfg()
    _, cr, res, _, mass = _traced(path, cfg, credit_init(TBL), stream,
                                  np.arange(NK))
    upd = np.asarray(ops.kinds) == OpKind.UPDATE
    pess_ratio = ((np.asarray(res.pessimistic) & upd).sum(-1)
                  / np.maximum(upd.sum(-1), 1))
    # cold start: the first window is fully optimistic, credits build after
    assert pess_ratio[0] == 0.0
    assert int(np.asarray(mass)[0]) == 0 < int(np.asarray(mass)[1])
    # hot phase: contention identified, most writes go pessimistic
    assert (pess_ratio[2:SHIFT] > 0.4).all()
    # the shift is *felt*: stale credits don't cover the new hot set
    assert pess_ratio[SHIFT] < 0.3
    # ...and re-identified within a bounded number of windows
    assert (pess_ratio[SHIFT + 3:] > 0.4).all()
    # old hot set fully drained by the end, new hot set carries the credits
    credit = np.asarray(cr.credit)
    assert credit[np.asarray(_slot(jnp.asarray(set_a, jnp.int32), TBL))].sum() == 0
    assert credit.sum() > 0


@pytest.mark.parametrize("path", ["single", f"sharded{N_SHARDS}"])
def test_cider_credits_drain_multiplicatively_after_shift(path):
    """Feed only the post-shift windows to a store whose credit table is
    warm on the OLD hot set: each window's lone drain probe per key takes
    the pessimistic path with WC batch 1, which must at least halve the
    credit (Algorithm 1's multiplicative decrease) until it hits 0."""
    ops, (set_a, _) = hotspot_shift(W, B, NK, NC, seed=5, hot_keys=HK,
                                    shift_window=SHIFT, return_sets=True)
    slots_a = np.asarray(_slot(jnp.asarray(set_a, jnp.int32), TBL))
    credit0 = jnp.zeros((TBL,), jnp.int32).at[slots_a].set(36)
    credits = CreditState(credit=credit0,
                          retry_record=jnp.zeros((TBL,), jnp.int32))
    cfg = _cfg()
    pop = np.arange(NK)
    if path == "single":
        st = populate(cfg, store_init(cfg), pop, pop)
    else:
        mesh = make_local_mesh(data=N_SHARDS)
        st = dstore.sharded_populate(
            cfg, N_SHARDS, dstore.sharded_store_init(cfg, N_SHARDS), pop, pop)
    masses = [int(np.asarray(credits.credit)[slots_a].sum())]
    for w in range(SHIFT, W):
        batch = OpBatch.make(ops.kinds[w], ops.keys[w], ops.values[w],
                             n_cns=NC)
        if path == "single":
            st, credits, _, _ = apply_batch(cfg, st, credits, batch)
        else:
            st, credits, _, _ = dstore.apply_batch_sharded(
                cfg, mesh, st, credits, batch)
        masses.append(int(np.asarray(credits.credit)[slots_a].sum()))
    assert masses[0] == 36 * HK
    for before, after in zip(masses, masses[1:]):
        if before > 0:
            assert after <= before // 2, masses
    # bounded drain: ceil(log2(36)) windows of halving reach 0 well before
    # the stream ends
    assert 0 in masses[:7], masses
