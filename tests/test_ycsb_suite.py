"""YCSB A-F composition contracts (DESIGN.md §9, docs/METRICS.md).

The suite generators are the input side of the headline benchmark — if a
mix drifts, every downstream number silently measures a different workload.
These tests pin: op-mix fractions per workload, E's scan-length
distribution, D's latest-key recency, F's read-modify-write pairing, and
the frontier rule (no point read ever targets a not-yet-inserted key).
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.types import OpKind
from repro.workloads.ycsb import YCSB, YCSBSpec, generate_ycsb_stream

W, B, NK, NC = 8, 2048, 4096, 64


def _stream(name, seed=11):
    return generate_ycsb_stream(YCSB[name], W, B, NK, NC, seed=seed)


def _frac(kinds, kind):
    return float((kinds == kind).mean())


# lane-level mixes implied by the request-level YCSB definitions; F's RMW
# requests occupy two lanes, so its lane mix is 2/3 SEARCH + 1/3 UPDATE
LANE_MIX = {
    "A": {OpKind.SEARCH: 0.50, OpKind.UPDATE: 0.50},
    "B": {OpKind.SEARCH: 0.95, OpKind.UPDATE: 0.05},
    "C": {OpKind.SEARCH: 1.00},
    "D": {OpKind.SEARCH: 0.95, OpKind.INSERT: 0.05},
    "E": {OpKind.SCAN: 0.95, OpKind.INSERT: 0.05},
    "F": {OpKind.SEARCH: 2 / 3, OpKind.UPDATE: 1 / 3},
}


@pytest.mark.parametrize("name", list(YCSB))
def test_op_mix_fractions(name):
    ops = _stream(name)
    for kind in (OpKind.SEARCH, OpKind.UPDATE, OpKind.INSERT, OpKind.SCAN,
                 OpKind.DELETE):
        want = LANE_MIX[name].get(kind, 0.0)
        assert _frac(ops.kinds, kind) == pytest.approx(want, abs=0.015), \
            f"{name}: {kind.name} fraction off"


def test_e_scan_length_distribution():
    """E's scan length ~ Uniform[1, scan_max]: full support, flat histogram
    (chi-square below the 99.9% critical value of chi2(15) ~ 37.7)."""
    spec = YCSB["E"]
    ops = _stream("E")
    lens = ops.values[ops.kinds == OpKind.SCAN]
    assert lens.min() == 1 and lens.max() == spec.scan_max
    assert lens.mean() == pytest.approx((1 + spec.scan_max) / 2, rel=0.03)
    counts = np.bincount(lens.astype(int), minlength=spec.scan_max + 1)[1:]
    exp = lens.size / spec.scan_max
    chi2 = float(((counts - exp) ** 2 / exp).sum())
    assert chi2 < 40, f"chi2={chi2:.1f} for dof={spec.scan_max - 1}"


def test_d_latest_key_recency():
    """D's reads follow the latest distribution: they track the insert
    frontier upward and concentrate on recently inserted keys."""
    ops = _stream("D")
    frontier = NK
    med = []
    for w in range(W):
        rd = ops.kinds[w] == OpKind.SEARCH
        keys = ops.keys[w][rd]
        assert keys.max() < frontier, "read of a not-yet-inserted key"
        assert keys.min() >= 0
        # >=70% of reads hit the most recent 10% of the current universe
        recent = float((keys >= frontier * 0.9).mean())
        assert recent > 0.70, f"window {w}: only {recent:.0%} recent"
        med.append(float(np.median(keys)))
        frontier += int((ops.kinds[w] == OpKind.INSERT).sum())
    assert med[-1] > med[0], "read keys must track the growing frontier"


def test_d_and_e_inserts_are_fresh_distinct_keys():
    for name in ("D", "E"):
        ops = _stream(name)
        frontier = NK
        for w in range(W):
            ins = ops.kinds[w] == OpKind.INSERT
            k = ops.keys[w][ins]
            np.testing.assert_array_equal(
                np.sort(k), frontier + np.arange(k.size),
                err_msg=f"{name} window {w}: inserts not fresh-distinct")
            frontier += k.size


def test_f_rmw_pairs_are_adjacent_same_key():
    ops = _stream("F")
    for w in range(W):
        kinds, keys = ops.kinds[w], ops.keys[w]
        upd = np.flatnonzero(kinds == OpKind.UPDATE)
        assert upd.size > 0
        assert (upd > 0).all()
        assert (kinds[upd - 1] == OpKind.SEARCH).all(), \
            "every RMW UPDATE must directly follow its read"
        np.testing.assert_array_equal(keys[upd - 1], keys[upd],
                                      err_msg="RMW pair must share its key")


def test_zipf_skew_of_point_reads():
    """A/B reads are Zipf-skewed over the populated universe: the hottest
    key absorbs far more than uniform mass and all keys are in-universe."""
    ops = _stream("A")
    rd = ops.keys[ops.kinds == OpKind.SEARCH]
    assert rd.min() >= 0 and rd.max() < NK
    top = np.bincount(rd.astype(int)).max() / rd.size
    assert top > 20 / NK, "no hot key — Zipf draw looks uniform"


def test_spec_validation():
    with pytest.raises(ValueError, match="sum to 1"):
        YCSBSpec("bad", read=0.5, update=0.4)


def test_determinism():
    a = _stream("E", seed=3)
    b = _stream("E", seed=3)
    np.testing.assert_array_equal(a.kinds, b.kinds)
    np.testing.assert_array_equal(a.keys, b.keys)
    np.testing.assert_array_equal(a.values, b.values)
