"""Replication-axis contracts (DESIGN.md §13): the R=1 bit-identity
regression suite (the replica fan-out must be a compile-time no-op at
``n_replicas=1``, single-device AND on the 4-way mesh), property tests for
MN crash/failover (random crash schedules at R in {2,3}: the post-failover
store must replay against the oracle, the per-replica verb bill must
conserve, and the orchestrated run must stay bit-equal to the segmented
``n_replicas``-swap reference), and the MN-liveness plane's own invariants.

The property tests run under Hypothesis when it is installed; otherwise a
deterministic seeded grid over the same generator exercises the identical
property function, so the suite loses breadth but not the contract.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import pytest

from repro.core import runner
from repro.core.credits import credit_init
from repro.core.engine import populate, store_init, store_view
from repro.core.oracle import OracleStore
from repro.core.sim import make_streams, run_sim
from repro.core.simnet import SimParams
from repro.core.types import (EngineConfig, IOMetrics, SyncMode,
                              per_replica_bill)
from repro.dist import store as dstore
from repro.launch.mesh import make_local_mesh
from repro.recovery import (MNLiveness, mn_always_alive, mn_crash,
                            run_recovery_replicated, slice_stream)
from repro.workloads.recovery import RECOVERY_SCENARIOS
from repro.workloads.ycsb import WORKLOADS

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                     # pragma: no cover
    HAVE_HYPOTHESIS = False

MODES = [SyncMode.OSYNC, SyncMode.SPIN, SyncMode.MCS, SyncMode.CIDER]
W, B, NK, NCN = 6, 64, 128, 8
HEAP = NK + W * B          # 512: divisible by the 4-way mesh
N_SHARDS = 4


def _cfg(mode: SyncMode, r: int = 1) -> EngineConfig:
    return EngineConfig(n_slots=NK, heap_slots=HEAP, mode=mode, n_replicas=r)


def _scenario(seed: int, crash_window: int = 3):
    ops, sched = RECOVERY_SCENARIOS["crash_storm"].generate(
        W, B, NK, 16, NCN, seed=seed, crash_window=crash_window)
    stream = runner.make_stream(ops.kinds, ops.keys, ops.values, n_cns=NCN,
                                alive=sched.alive)
    return ops, sched, stream


def _run_single(cfg: EngineConfig, stream):
    pk = np.arange(NK)
    st_ = populate(cfg, store_init(cfg), pk, pk)
    return runner.run_windows(cfg, st_, credit_init(256), stream,
                              io_per_window=True)


def _tree_equal(a, b, what: str):
    for f in dataclasses.fields(a):
        x, y = np.asarray(getattr(a, f.name)), np.asarray(getattr(b, f.name))
        assert np.array_equal(x, y), f"{what}: {type(a).__name__}.{f.name}"


# ---------------------------------------------------------------------------
# satellite 1: R=1 bit-identity — the replica axis must cost nothing at R=1
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", MODES)
def test_r1_bit_identity_single_device(mode):
    """An explicit ``n_replicas=1`` run (even with an absurd ``replica_rtt``
    in the cost model) must produce the full Results+IOMetrics tree of the
    default config — the fan-out block is a Python-level branch that never
    enters the compiled program at R=1."""
    ops, _, stream = _scenario(seed=11)
    _, _, res0, io0 = _run_single(EngineConfig(n_slots=NK, heap_slots=HEAP,
                                               mode=mode), stream)
    ops, _, stream = _scenario(seed=11)
    _, _, res1, io1 = _run_single(_cfg(mode, r=1), stream)
    _tree_equal(res0, res1, f"{mode.name}/single Results")
    _tree_equal(io0, io1, f"{mode.name}/single IOMetrics")
    p0 = SimParams()
    p1 = dataclasses.replace(SimParams(), n_replicas=1, replica_rtt=999)
    lat0 = runner.modeled_latency(_cfg(mode), ops.kinds, res0, p0)
    lat1 = runner.modeled_latency(_cfg(mode, r=1), ops.kinds, res1, p1)
    np.testing.assert_array_equal(lat0, lat1)


@pytest.mark.parametrize("mode", MODES)
def test_r1_bit_identity_sharded_mesh(mode):
    """Same contract on the 4-way mesh: the sharded scan with an explicit
    ``n_replicas=1`` config must match the default-config sharded bill."""
    pk = np.arange(NK)
    outs = []
    for cfg in (EngineConfig(n_slots=NK, heap_slots=HEAP, mode=mode),
                _cfg(mode, r=1)):
        _, _, stream = _scenario(seed=13)
        sst = dstore.sharded_populate(
            cfg, N_SHARDS, dstore.sharded_store_init(cfg, N_SHARDS), pk, pk)
        mesh = make_local_mesh(data=N_SHARDS)
        outs.append(dstore.run_windows_sharded(cfg, mesh, sst,
                                               credit_init(256), stream,
                                               io_per_window=True))
    _tree_equal(outs[0][2], outs[1][2], f"{mode.name}/sharded Results")
    _tree_equal(outs[0][3], outs[1][3], f"{mode.name}/sharded IOMetrics")


def test_r1_sim_path_tick_exact():
    """Protocol-simulator side of the same contract: at ``n_replicas=1`` the
    tick loop must be bit-identical no matter what ``replica_rtt`` says."""
    spec = WORKLOADS["write-intensive"]
    base = dict(n_lanes=64, ticks=2048, max_ops=256)
    p0 = SimParams(**base)
    p1 = SimParams(**base, n_replicas=1, replica_rtt=777)
    for mode in (SyncMode.OSYNC, SyncMode.CIDER):
        r0 = run_sim(p0, mode, make_streams(p0, spec, 512), 64)
        r1 = run_sim(p1, mode, make_streams(p1, spec, 512), 64)
        assert r0.throughput_mops == r1.throughput_mops
        assert r0.p99_us == r1.p99_us


# ---------------------------------------------------------------------------
# satellite 2: MN crash/failover property — oracle replay + bill conservation
# ---------------------------------------------------------------------------

def _sum_io(io: IOMetrics, lo: int, hi: int) -> IOMetrics:
    return IOMetrics(**{f.name: int(np.asarray(getattr(io, f.name))[lo:hi]
                                    .sum())
                        for f in dataclasses.fields(IOMetrics)})


def _check_mn_failover(mode: SyncMode, r: int, seed: int, mn: MNLiveness,
                       cn_crash_window: int = 3) -> None:
    """The satellite-2 property, shared by the Hypothesis and deterministic
    paths: under any fail-stop MN schedule ``mn`` (R -> survivors), the
    orchestrated failover run must (a) leave a store the oracle reproduces,
    (b) bill each constant-membership segment exactly R_s x the R=1 write
    bill / 1 x the read bill (``per_replica_bill`` accepts and re-sums it),
    and (c) report the promotion sweep in ``recovery_io``, one entry per
    crash edge."""
    ops, sched, stream = _scenario(seed, crash_window=cn_crash_window)
    cfg = _cfg(mode, r=r)
    pk = np.arange(NK)
    run = run_recovery_replicated(
        cfg, populate(cfg, store_init(cfg), pk, pk), credit_init(256),
        stream, mn)

    # (a) the surviving replica serves a store the oracle agrees with
    o = OracleStore()
    o.populate(pk, pk)
    kinds, keys, values = (np.asarray(ops.kinds), np.asarray(ops.keys),
                           np.asarray(ops.values))
    for w in range(W):
        o.apply(kinds[w], keys[w], values[w], valid=run.valid[w])
    ex_o, v_o = o.view(NK)
    ex, v = store_view(run.state)
    np.testing.assert_array_equal(np.asarray(ex), ex_o)
    np.testing.assert_array_equal(np.where(ex_o, np.asarray(v), 0),
                                  np.where(ex_o, v_o, 0))

    # (b) per-replica conservation, segment by segment, against an R=1 run
    _, _, stream1 = _scenario(seed, crash_window=cn_crash_window)
    _, _, _, io1 = _run_single(_cfg(mode, r=1), stream1)
    for lo, hi, survivors in mn.segments():
        one = _sum_io(io1, lo, hi)
        tot = _sum_io(run.io, lo, hi)
        bills = per_replica_bill(one, tot, len(survivors))
        assert len(bills) == len(survivors)
        summed = {k: sum(b[k] for b in bills) for k in bills[0]}
        assert summed == {k: v_ for k, v_ in tot.as_dict().items()
                          if k != "mn_iops"}

    # (c) one promotion per crash edge, billing the certification sweep
    n_edges = int(mn.died().any(axis=1).sum())
    assert len(run.recovery_io) == n_edges
    for rio in run.recovery_io:
        assert rio["promote_reads"] == NK
        assert rio["promote_bytes"] == NK * cfg.lock_bytes
        assert rio["promoted"] == min(rio["survivors"])


DET_CASES = [
    # (mode, R, seed, dead replicas, MN crash window)
    (SyncMode.OSYNC, 2, 0, (1,), 2),
    (SyncMode.SPIN, 2, 1, (0,), 4),
    (SyncMode.MCS, 2, 2, (1,), 3),
    (SyncMode.CIDER, 2, 3, (0,), 2),
    (SyncMode.OSYNC, 3, 4, (1, 2), 3),
    (SyncMode.SPIN, 3, 5, (2,), 2),
    (SyncMode.MCS, 3, 6, (0, 1), 4),
    (SyncMode.CIDER, 3, 7, (2,), 3),
]


@pytest.mark.parametrize("mode,r,seed,dead,at", DET_CASES)
def test_mn_failover_oracle_and_conservation(mode, r, seed, dead, at):
    _check_mn_failover(mode, r, seed, mn_crash(W, r, dead, at_window=at))


@pytest.mark.parametrize("mode", [SyncMode.MCS, SyncMode.CIDER])
def test_mn_failover_two_step_schedule(mode):
    """R=3 losing one replica, then another: two promotions, three
    segments, each at its own survivor count."""
    alive = np.ones((W, 3), bool)
    alive[2:, 2] = False
    alive[4:, 0] = False
    mn = MNLiveness(alive)
    assert [s[2] for s in mn.segments()] == [(0, 1, 2), (0, 1), (1,)]
    _check_mn_failover(mode, 3, seed=9, mn=mn)


if HAVE_HYPOTHESIS:
    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(0, 2**16), r=st.sampled_from([2, 3]),
           at=st.integers(1, W - 1), mode=st.sampled_from(MODES),
           data=st.data())
    def test_mn_failover_property_hypothesis(seed, r, at, mode, data):
        dead = data.draw(st.lists(st.integers(0, r - 1), min_size=1,
                                  max_size=r - 1, unique=True))
        _check_mn_failover(mode, r, seed,
                           mn_crash(W, r, tuple(dead), at_window=at))


# ---------------------------------------------------------------------------
# the MN-liveness plane's own invariants
# ---------------------------------------------------------------------------

def test_mn_liveness_requires_a_survivor():
    alive = np.ones((4, 2), bool)
    alive[2:, :] = False
    with pytest.raises(ValueError, match="surviving replica"):
        MNLiveness(alive)


def test_mn_liveness_forbids_rejoin():
    alive = np.ones((4, 2), bool)
    alive[1, 0] = False                      # down at 1, back at 2
    with pytest.raises(ValueError, match="no rejoin"):
        MNLiveness(alive)


def test_mn_liveness_segments_cover_the_stream():
    mn = mn_crash(8, 3, (2,), at_window=5)
    assert mn.segments() == [(0, 5, (0, 1, 2)), (5, 8, (0, 1))]
    assert mn.first_crash_window() == 5
    assert mn_always_alive(8, 3).segments() == [(0, 8, (0, 1, 2))]
    assert mn_always_alive(8, 3).first_crash_window() is None


def test_run_recovery_replicated_validates_shapes():
    _, _, stream = _scenario(seed=0)
    cfg = _cfg(SyncMode.CIDER, r=2)
    pk = np.arange(NK)
    st_ = populate(cfg, store_init(cfg), pk, pk)
    with pytest.raises(ValueError, match="windows"):
        run_recovery_replicated(cfg, st_, credit_init(256), stream,
                                mn_always_alive(W + 1, 2))
    with pytest.raises(ValueError, match="replicas"):
        run_recovery_replicated(cfg, st_, credit_init(256), stream,
                                mn_always_alive(W, 3))


def test_promote_replica_is_control_plane_only():
    """Promotion never mutates the store and rejects nonsense memberships."""
    cfg = _cfg(SyncMode.MCS, r=3)
    pk = np.arange(NK)
    st_ = populate(cfg, store_init(cfg), pk, pk)
    st2, rio = dstore.promote_replica(cfg, st_, survivors=(0, 1),
                                      dead_replicas=(2,))
    assert st2 is st_
    assert rio["promote_reads"] == NK
    assert rio["promote_bytes"] == NK * cfg.lock_bytes
    assert rio["repair_rearm_cas"] == 0      # nothing stranded
    with pytest.raises(ValueError, match="no surviving"):
        dstore.promote_replica(cfg, st_, survivors=(), dead_replicas=(0,))
    with pytest.raises(ValueError, match="both dead and surviving"):
        dstore.promote_replica(cfg, st_, survivors=(0, 1),
                               dead_replicas=(1,))


def test_promote_replica_rearms_stranded_locks():
    """A CN crash that leaves locks stranded at the MN-failover boundary
    must surface in the re-arm bill (one break CAS per survivor copy)."""
    ops, sched, stream = _scenario(seed=2, crash_window=2)
    cfg = _cfg(SyncMode.MCS, r=2)
    pk = np.arange(NK)
    # run only the pre-failover prefix so the strands are live at the cut
    seg = slice_stream(stream, 0, 3)
    st_, _, _, io = runner.run_windows(cfg, populate(cfg, store_init(cfg),
                                                     pk, pk),
                                       credit_init(256), seg,
                                       io_per_window=True)
    stranded = int(np.asarray(st_.stranded).sum())
    _, rio = dstore.promote_replica(cfg, st_, survivors=(0,),
                                    dead_replicas=(1,))
    assert rio["repair_rearm_cas"] == stranded * 1
    if stranded == 0:
        pytest.skip("seed left no stranded locks at the boundary")
