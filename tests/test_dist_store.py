"""Sharded-store equivalence: ``apply_batch`` under shard_map on a multi-way
``data`` mesh must be indistinguishable from the single-device engine — same
logical store view, same per-op results, same credit table, and an I/O bill
that sums per-shard to the single-device numbers, for all four SyncModes."""
from __future__ import annotations

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.credits import credit_init
from repro.core.engine import apply_batch, populate, store_init, store_view
from repro.core.types import EngineConfig, IOMetrics, OpBatch, OpKind, SyncMode
from repro.dist import store as dstore
from repro.launch.mesh import make_local_mesh

MODES = [SyncMode.OSYNC, SyncMode.SPIN, SyncMode.MCS, SyncMode.CIDER]

N_SLOTS, HEAP, B = 64, 1024, 256


def _mesh():
    n = 4 if jax.device_count() >= 4 else (2 if jax.device_count() >= 2 else 1)
    return make_local_mesh(data=n), n


def _random_ops(rng, b, n_slots):
    kinds = rng.choice(
        [OpKind.SEARCH, OpKind.INSERT, OpKind.UPDATE, OpKind.DELETE],
        size=b, p=(0.3, 0.15, 0.4, 0.15)).astype(np.int32)
    keys = rng.integers(0, n_slots, b).astype(np.int32)
    values = rng.integers(0, 10_000, b).astype(np.int32)
    return kinds, keys, values


def _assert_same(cfg, n_shards, single, sharded):
    st1, cr1, res1, io1 = single
    st2, cr2, res2, io2 = sharded
    ex1, v1 = store_view(st1)
    ex2, v2 = dstore.sharded_store_view(cfg, n_shards, st2)
    np.testing.assert_array_equal(np.asarray(ex1), np.asarray(ex2))
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
    np.testing.assert_array_equal(np.asarray(st1.ver), np.asarray(st2.ver))
    np.testing.assert_array_equal(np.asarray(st1.epoch), np.asarray(st2.epoch))
    for f in dataclasses.fields(res1):
        np.testing.assert_array_equal(
            np.asarray(getattr(res1, f.name)),
            np.asarray(getattr(res2, f.name)), err_msg=f"Results.{f.name}")
    for f in dataclasses.fields(IOMetrics):
        assert int(getattr(io1, f.name)) == int(getattr(io2, f.name)), \
            f"IOMetrics.{f.name}: {int(getattr(io1, f.name))} != " \
            f"{int(getattr(io2, f.name))}"
    np.testing.assert_array_equal(np.asarray(cr1.credit), np.asarray(cr2.credit))
    np.testing.assert_array_equal(np.asarray(cr1.retry_record),
                                  np.asarray(cr2.retry_record))


@pytest.mark.parametrize("mode", MODES)
def test_sharded_matches_single_device(mode):
    """Three consecutive windows (so CIDER's credits warm up and the
    pessimistic path actually runs) on a >=2-way mesh when available."""
    mesh, n_shards = _mesh()
    rng = np.random.default_rng(0)
    cfg = EngineConfig(n_slots=N_SLOTS, heap_slots=HEAP, mode=mode)
    pop_keys = rng.choice(N_SLOTS, size=N_SLOTS // 2, replace=False)
    pop_vals = rng.integers(0, 10_000, pop_keys.shape[0])

    st1 = populate(cfg, store_init(cfg), pop_keys, pop_vals)
    cr1 = credit_init(256)
    st2 = dstore.sharded_populate(
        cfg, n_shards, dstore.sharded_store_init(cfg, n_shards),
        pop_keys, pop_vals)
    cr2 = credit_init(256)

    for _window in range(4):
        kinds, keys, values = _random_ops(rng, B, N_SLOTS)
        # one FIXED hot key, STRIDED so the writers span all CNs (positions
        # map to CNs in blocks): same-CN duplicates are eaten by local WC
        # before the credit plane ever sees them, and CIDER needs two
        # consecutive cross-CN high-retry windows on a slot before credits
        # promote it to the pessimistic path — the path worth shard-testing
        keys[::4] = 5
        kinds[::4] = OpKind.UPDATE
        batch = OpBatch.make(kinds, keys, values, n_cns=4)
        st1, cr1, res1, io1 = apply_batch(cfg, st1, cr1, batch)
        st2, cr2, res2, io2 = dstore.apply_batch_sharded(
            cfg, mesh, st2, cr2, batch)
        _assert_same(cfg, n_shards, (st1, cr1, res1, io1),
                     (st2, cr2, res2, io2))
    if mode == SyncMode.CIDER:
        # the credits warmed up and the global-WC pessimistic path ran
        assert int(np.asarray(res2.pessimistic).sum()) > 0


def test_sharded_requires_divisibility():
    cfg = EngineConfig(n_slots=65, heap_slots=1024, mode=SyncMode.CIDER)
    with pytest.raises(ValueError):
        dstore.shard_extents(cfg, 2)


def test_sharded_valid_mask_respected():
    """NOP padding + an explicit valid mask behave as on a single device."""
    mesh, n_shards = _mesh()
    cfg = EngineConfig(n_slots=N_SLOTS, heap_slots=HEAP, mode=SyncMode.MCS)
    kinds = np.full(16, OpKind.UPDATE, np.int32)
    kinds[8:] = OpKind.NOP
    keys = np.arange(16, dtype=np.int32) * 4 % N_SLOTS
    values = np.arange(16, dtype=np.int32)
    valid = np.ones(16, bool)
    valid[:2] = False
    batch = OpBatch.make(kinds, keys, values, n_cns=2)
    st1 = populate(cfg, store_init(cfg), np.arange(N_SLOTS),
                   np.zeros(N_SLOTS, np.int32))
    st2 = dstore.sharded_populate(
        cfg, n_shards, dstore.sharded_store_init(cfg, n_shards),
        np.arange(N_SLOTS), np.zeros(N_SLOTS, np.int32))
    out1 = apply_batch(cfg, st1, credit_init(64), batch,
                       valid=jnp.asarray(valid))
    out2 = dstore.apply_batch_sharded(cfg, mesh, st2, credit_init(64), batch,
                                      valid=jnp.asarray(valid))
    _assert_same(cfg, n_shards, out1, out2)
