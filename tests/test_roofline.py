"""HLO parser validation: trip-count multiplication vs XLA's scan-once
cost_analysis, collective wire formulas, and an end-to-end FLOPs
cross-check against 6*N*D."""
from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.rooflines.hlo_parser import cost_dict, parse_hlo
from repro.rooflines.roofline import model_flops, roofline


def _compile(fn, *args):
    lowered = jax.jit(fn).lower(*args)
    return lowered.compile()


def test_scan_trip_count_multiplied():
    """A 10-step scan of a fixed matmul: parser FLOPs must be ~10x the
    single-step count (XLA cost_analysis counts the body once)."""
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 128), jnp.float32)

    def one(wv, xv):
        return xv @ wv

    def scanned(wv, xv):
        def body(c, _):
            return c @ wv, None
        out, _ = jax.lax.scan(body, xv, None, length=10)
        return out

    f1 = parse_hlo(_compile(one, w, x).as_text()).dot_flops
    f10 = parse_hlo(_compile(scanned, w, x).as_text()).dot_flops
    assert f1 > 0
    assert 8.0 <= f10 / f1 <= 12.0, (f1, f10)
    # XLA's own analysis counts the body once (the thing we correct for)
    xla = cost_dict(_compile(scanned, w, x))
    if xla and xla.get("flops", 0) > 0:
        assert xla["flops"] < 0.5 * f10


def test_dot_flops_formula():
    a = jax.ShapeDtypeStruct((64, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 32), jnp.float32)
    cost = parse_hlo(_compile(lambda x, y: x @ y, a, b).as_text())
    assert cost.dot_flops == pytest.approx(2 * 64 * 256 * 32, rel=0.01)


def test_end_to_end_flops_vs_6nd():
    """Tiny model train step: parsed global FLOPs within a small factor of
    6*N*D (remat + attention + f32 CE explain the >1 ratio)."""
    from repro.configs import get_config
    from repro.models.common import unbox
    from repro.models.model import Model
    from repro.train.optimizer import adamw_init, adamw_update

    cfg = get_config("qwen3-0.6b", smoke=True)
    model = Model(cfg)
    params = unbox(model.init(jax.random.key(0)))
    opt = adamw_init(params)
    batch = {"tokens": jnp.zeros((2, 32), jnp.int32),
             "targets": jnp.zeros((2, 32), jnp.int32)}

    def step(p, o, b):
        (loss, _), g = jax.value_and_grad(model.loss_fn, has_aux=True)(p, b)
        p, o, _ = adamw_update(p, g, o)
        return p, o, loss

    cost = parse_hlo(_compile(step, params, opt, batch).as_text())
    n = model.n_params()
    mf = model_flops(cfg, "train", 32, 2, n)
    ratio = cost.dot_flops / mf
    assert 0.8 < ratio < 8.0, ratio


def test_roofline_terms_and_bottleneck():
    t = roofline(chip_flops=197e12, chip_hbm_bytes=819e9 * 2,
                 chip_wire_bytes=50e9 * 0.5, model_flops=197e12 * 256,
                 chips=256)
    assert t.compute_s == pytest.approx(1.0)
    assert t.memory_s == pytest.approx(2.0)
    assert t.collective_s == pytest.approx(0.5)
    assert t.bottleneck == "memory"
    assert t.roofline_fraction == pytest.approx(0.5)


def test_collective_wire_bytes_allreduce():
    """psum over 4 shards: AR wire bytes = 2*(g-1)/g * buffer."""
    if jax.device_count() < 4:
        pytest.skip("needs >=4 devices (dry-run env only)")
    mesh = jax.make_mesh((4,), ("x",))
    from jax.sharding import NamedSharding, PartitionSpec as P
    x = jax.ShapeDtypeStruct((1024, 256), jnp.float32)

    def f(v):
        return jnp.sum(v * 2.0, axis=0)

    with mesh:
        lowered = jax.jit(
            f, in_shardings=NamedSharding(mesh, P("x", None))).lower(x)
    cost = parse_hlo(lowered.compile().as_text())
    expect = 2 * (4 - 1) / 4 * 256 * 4  # output row f32
    assert cost.coll_bytes == pytest.approx(expect, rel=0.5)
