"""Per-kernel validation: shape/dtype sweeps, interpret=True vs pure-jnp
oracle (assert_allclose), plus hypothesis property tests on wc_combine."""
from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.ops import (flash_attention_op,
                                               flash_attention_ref)
from repro.kernels.paged_attention.ops import (paged_attention_op,
                                               paged_attention_ref)
from repro.kernels.wc_combine.ops import wc_combine_op, wc_combine_ref

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except ImportError:  # pragma: no cover
    HAVE_HYP = False


@pytest.mark.parametrize("b,h,kh,s,d", [
    (2, 4, 2, 256, 64), (1, 8, 8, 128, 128), (2, 4, 1, 256, 64),
    (1, 2, 2, 512, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 64)])
def test_flash_attention_sweep(b, h, kh, s, d, dtype, causal, window):
    ks = jax.random.split(jax.random.key(b * 1000 + h * 100 + s), 3)
    q = jax.random.normal(ks[0], (b, h, s, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, kh, s, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, kh, s, d), jnp.float32).astype(dtype)
    out = flash_attention_op(q, k, v, causal=causal, window=window,
                             block_q=64, block_k=64, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("b,h,kh,d,page,np_", [
    (2, 8, 2, 64, 16, 8), (1, 4, 4, 128, 32, 4), (3, 16, 1, 64, 16, 4),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_sweep(b, h, kh, d, page, np_, dtype):
    rng = np.random.default_rng(b * 10 + h)
    npool = b * np_ + 4
    ks = jax.random.split(jax.random.key(7), 3)
    q = jax.random.normal(ks[0], (b, h, d), jnp.float32).astype(dtype)
    kp = jax.random.normal(ks[1], (npool, page, kh, d), jnp.float32).astype(dtype)
    vp = jax.random.normal(ks[2], (npool, page, kh, d), jnp.float32).astype(dtype)
    # each sequence gets distinct pages, random lengths
    bt = rng.permutation(npool)[: b * np_].reshape(b, np_).astype(np.int32)
    lengths = rng.integers(1, np_ * page + 1, b).astype(np.int32)
    out = paged_attention_op(q, kp, vp, jnp.asarray(bt), jnp.asarray(lengths),
                             interpret=True)
    ref = paged_attention_ref(q, kp, vp, jnp.asarray(bt), jnp.asarray(lengths))
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("n,block", [(256, 64), (1024, 128), (64, 64)])
def test_wc_combine_sweep(n, block):
    rng = np.random.default_rng(n)
    keys = np.sort(rng.integers(0, n // 4, n)).astype(np.int32)
    f1, l1, r1 = wc_combine_op(jnp.asarray(keys), block=block, interpret=True)
    f2, l2, r2 = wc_combine_ref(jnp.asarray(keys))
    np.testing.assert_array_equal(np.asarray(f1), np.asarray(f2))
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))


if HAVE_HYP:
    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.sampled_from([64, 128]),
           st.sampled_from([1, 3, 17]))
    def test_wc_combine_property(seed, n, key_space):
        """Invariants: ranks restart at run heads, one tail per unique key,
        rank of tail + 1 == run length."""
        rng = np.random.default_rng(seed)
        keys = np.sort(rng.integers(0, key_space, n)).astype(np.int32)
        f, l, r = map(np.asarray, wc_combine_op(jnp.asarray(keys), block=64,
                                                interpret=True))
        assert f.sum() == len(np.unique(keys))
        assert l.sum() == len(np.unique(keys))
        assert (r[f] == 0).all()
        for k in np.unique(keys):
            run = keys == k
            assert r[run].max() + 1 == run.sum()
