"""Per-kernel validation: shape/dtype sweeps, interpret=True vs pure-jnp
oracle (assert_allclose), plus hypothesis property tests on wc_combine."""
from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.ops import (flash_attention_op,
                                               flash_attention_ref)
from repro.kernels.paged_attention.ops import (paged_attention_op,
                                               paged_attention_ref)
from repro.kernels.scan_probe.ops import scan_probe_op, scan_probe_ref
from repro.kernels.wc_combine.ops import wc_combine_op, wc_combine_ref

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except ImportError:  # pragma: no cover
    HAVE_HYP = False


@pytest.mark.parametrize("b,h,kh,s,d", [
    (2, 4, 2, 256, 64), (1, 8, 8, 128, 128), (2, 4, 1, 256, 64),
    (1, 2, 2, 512, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 64)])
def test_flash_attention_sweep(b, h, kh, s, d, dtype, causal, window):
    ks = jax.random.split(jax.random.key(b * 1000 + h * 100 + s), 3)
    q = jax.random.normal(ks[0], (b, h, s, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, kh, s, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, kh, s, d), jnp.float32).astype(dtype)
    out = flash_attention_op(q, k, v, causal=causal, window=window,
                             block_q=64, block_k=64, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("b,h,kh,d,page,np_", [
    (2, 8, 2, 64, 16, 8), (1, 4, 4, 128, 32, 4), (3, 16, 1, 64, 16, 4),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_sweep(b, h, kh, d, page, np_, dtype):
    rng = np.random.default_rng(b * 10 + h)
    npool = b * np_ + 4
    ks = jax.random.split(jax.random.key(7), 3)
    q = jax.random.normal(ks[0], (b, h, d), jnp.float32).astype(dtype)
    kp = jax.random.normal(ks[1], (npool, page, kh, d), jnp.float32).astype(dtype)
    vp = jax.random.normal(ks[2], (npool, page, kh, d), jnp.float32).astype(dtype)
    # each sequence gets distinct pages, random lengths
    bt = rng.permutation(npool)[: b * np_].reshape(b, np_).astype(np.int32)
    lengths = rng.integers(1, np_ * page + 1, b).astype(np.int32)
    out = paged_attention_op(q, kp, vp, jnp.asarray(bt), jnp.asarray(lengths),
                             interpret=True)
    ref = paged_attention_ref(q, kp, vp, jnp.asarray(bt), jnp.asarray(lengths))
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("n,block", [
    (256, 64), (1024, 128), (64, 64),
    # padded-tail cases (DESIGN.md §10.1): n not a block multiple
    (100, 64), (130, 64), (257, 128), (4100, 1024),
])
def test_wc_combine_sweep(n, block):
    rng = np.random.default_rng(n)
    keys = np.sort(rng.integers(0, n // 4, n)).astype(np.int32)
    f1, l1, r1 = wc_combine_op(jnp.asarray(keys), block=block, interpret=True)
    f2, l2, r2 = wc_combine_ref(jnp.asarray(keys))
    np.testing.assert_array_equal(np.asarray(f1), np.asarray(f2))
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))


def test_wc_combine_all_invalid():
    """A batch of nothing but the +inf invalid-key sentinel — the padding
    value itself — must still produce a well-formed single run."""
    keys = np.full(100, 2**31 - 1, np.int32)
    f, l, r = map(np.asarray,
                  wc_combine_op(jnp.asarray(keys), block=64, interpret=True))
    assert f.sum() == 1 and f[0]
    assert l.sum() == 1 and l[-1]
    np.testing.assert_array_equal(r, np.arange(100))


def test_wc_combine_duplicate_heavy():
    """One giant run spanning many blocks plus tiny runs at both ends."""
    keys = np.concatenate([[0], np.full(1000, 7, np.int32), [9, 9, 11]])
    keys = np.sort(keys).astype(np.int32)
    f1, l1, r1 = map(np.asarray,
                     wc_combine_op(jnp.asarray(keys), block=64, interpret=True))
    f2, l2, r2 = map(np.asarray, wc_combine_ref(jnp.asarray(keys)))
    np.testing.assert_array_equal(f1, f2)
    np.testing.assert_array_equal(l1, l2)
    np.testing.assert_array_equal(r1, r2)


def _scan_probe_oracle(keys, setcode, writer, e_init):
    """Brute-force per-lane oracle for the fused reader-probe pass."""
    n = len(keys)
    e_before = np.zeros(n, bool)
    waits = np.zeros(n, np.int32)
    for i in range(n):
        e = bool(e_init[i])
        w = 0
        for j in range(i):
            if keys[j] != keys[i]:
                continue
            if setcode[j] >= 0:
                e = setcode[j] == 1
            w += int(writer[j])
        e_before[i] = e
        waits[i] = w
    return e_before, waits


def _scan_probe_case(seed, n, key_space):
    rng = np.random.default_rng(seed)
    keys = np.sort(rng.integers(0, key_space, n)).astype(np.int32)
    setcode = rng.choice([-1, -1, 0, 1], n).astype(np.int32)
    writer = rng.integers(0, 2, n).astype(bool)
    e_init = rng.integers(0, 2, n).astype(bool)
    return keys, setcode, writer, e_init


@pytest.mark.parametrize("n,block", [
    (256, 64), (1024, 128), (64, 64),
    (100, 64), (257, 128), (4100, 1024),   # padded tails
])
def test_scan_probe_sweep(n, block):
    keys, setcode, writer, e_init = _scan_probe_case(n * 7 + block, n, n // 4)
    eb1, w1 = scan_probe_op(jnp.asarray(keys), jnp.asarray(setcode),
                            jnp.asarray(writer), jnp.asarray(e_init),
                            block=block, interpret=True)
    eb2, w2 = scan_probe_ref(jnp.asarray(keys), jnp.asarray(setcode),
                             jnp.asarray(writer), jnp.asarray(e_init))
    np.testing.assert_array_equal(np.asarray(eb1), np.asarray(eb2))
    np.testing.assert_array_equal(np.asarray(w1), np.asarray(w2))
    eb3, w3 = _scan_probe_oracle(keys, setcode, writer, e_init)
    np.testing.assert_array_equal(np.asarray(eb2), eb3)
    np.testing.assert_array_equal(np.asarray(w2), w3)


def test_scan_probe_giant_run():
    """One run spanning every block: the SMEM carry must thread the
    last-setter and writer count across all block boundaries."""
    n = 1000
    rng = np.random.default_rng(3)
    keys = np.zeros(n, np.int32)
    setcode = rng.choice([-1, 0, 1], n).astype(np.int32)
    writer = rng.integers(0, 2, n).astype(bool)
    e_init = np.ones(n, bool)
    eb1, w1 = scan_probe_op(jnp.asarray(keys), jnp.asarray(setcode),
                            jnp.asarray(writer), jnp.asarray(e_init),
                            block=64, interpret=True)
    eb3, w3 = _scan_probe_oracle(keys, setcode, writer, e_init)
    np.testing.assert_array_equal(np.asarray(eb1), eb3)
    np.testing.assert_array_equal(np.asarray(w1), w3)


if HAVE_HYP:
    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.integers(33, 200),
           st.sampled_from([1, 3, 17]))
    def test_wc_combine_padded_property(seed, n, key_space):
        """Arbitrary (non-block-multiple) n against the reference — the
        padded dispatch (DESIGN.md §10.1) must be invisible."""
        rng = np.random.default_rng(seed)
        keys = np.sort(rng.integers(0, key_space, n)).astype(np.int32)
        out_k = wc_combine_op(jnp.asarray(keys), block=64, interpret=True)
        out_r = wc_combine_ref(jnp.asarray(keys))
        for a, b in zip(out_k, out_r):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.integers(33, 150),
           st.sampled_from([1, 3, 17]))
    def test_scan_probe_padded_property(seed, n, key_space):
        keys, setcode, writer, e_init = _scan_probe_case(seed, n, key_space)
        eb, w = scan_probe_op(jnp.asarray(keys), jnp.asarray(setcode),
                              jnp.asarray(writer), jnp.asarray(e_init),
                              block=64, interpret=True)
        eb3, w3 = _scan_probe_oracle(keys, setcode, writer, e_init)
        np.testing.assert_array_equal(np.asarray(eb), eb3)
        np.testing.assert_array_equal(np.asarray(w), w3)

    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.sampled_from([64, 128]),
           st.sampled_from([1, 3, 17]))
    def test_wc_combine_property(seed, n, key_space):
        """Invariants: ranks restart at run heads, one tail per unique key,
        rank of tail + 1 == run length."""
        rng = np.random.default_rng(seed)
        keys = np.sort(rng.integers(0, key_space, n)).astype(np.int32)
        f, l, r = map(np.asarray, wc_combine_op(jnp.asarray(keys), block=64,
                                                interpret=True))
        assert f.sum() == len(np.unique(keys))
        assert l.sum() == len(np.unique(keys))
        assert (r[f] == 0).all()
        for k in np.unique(keys):
            run = keys == k
            assert r[run].max() + 1 == run.sum()
