"""Fused multi-window runner equivalence: ``run_windows`` (one jitted scan)
must be bit-identical, window by window, to the per-window Python loop over
``apply_batch`` — Results, I/O bill, credit table, and store view — for all
four SyncModes, unsharded and under ``dist.store.run_windows_sharded`` on a
multi-way CPU mesh.  Plus the MN-IOPS throughput model and the stacked-window
stream generator."""
from __future__ import annotations

import dataclasses

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import runner
from repro.core.credits import credit_init
from repro.core.engine import apply_batch, populate, store_init, store_view
from repro.core.simnet import SimParams
from repro.core.types import (EngineConfig, IOMetrics, OpBatch, OpKind,
                              SyncMode)
from repro.dist import store as dstore
from repro.launch.mesh import make_local_mesh
from repro.workloads.ycsb import WORKLOADS, generate_ops, generate_window_stream

MODES = [SyncMode.OSYNC, SyncMode.SPIN, SyncMode.MCS, SyncMode.CIDER]
W, B, N_SLOTS, HEAP, N_CNS = 4, 256, 64, 1024, 4


def _ops():
    """(W, B) op arrays with a strided cross-CN hot key so CIDER's credits
    warm up and the pessimistic global-WC path actually runs (see
    tests/test_dist_store.py for why striding matters)."""
    rng = np.random.default_rng(0)
    kinds = rng.choice(
        [OpKind.SEARCH, OpKind.INSERT, OpKind.UPDATE, OpKind.DELETE],
        size=(W, B), p=(0.3, 0.15, 0.4, 0.15)).astype(np.int32)
    keys = rng.integers(0, N_SLOTS, (W, B)).astype(np.int32)
    values = rng.integers(0, 10_000, (W, B)).astype(np.int32)
    keys[:, ::4] = 5
    kinds[:, ::4] = OpKind.UPDATE
    return kinds, keys, values


def _init(cfg):
    rng = np.random.default_rng(1)
    pop_keys = rng.choice(N_SLOTS, size=N_SLOTS // 2, replace=False)
    pop_vals = rng.integers(0, 10_000, pop_keys.shape[0])
    return (populate(cfg, store_init(cfg), pop_keys, pop_vals),
            credit_init(256), pop_keys, pop_vals)


def _loop(cfg, state, credits, kinds, keys, values):
    """The reference per-window Python loop the runner replaces."""
    ress, ios = [], []
    for w in range(W):
        batch = OpBatch.make(kinds[w], keys[w], values[w], n_cns=N_CNS)
        state, credits, res, io = apply_batch(cfg, state, credits, batch)
        ress.append(res)
        ios.append(io)
    return state, credits, ress, ios


def _assert_windows_equal(ress, ios, res2, ios2, cr1, cr2):
    for w in range(W):
        for f in dataclasses.fields(ress[w]):
            np.testing.assert_array_equal(
                np.asarray(getattr(ress[w], f.name)),
                np.asarray(getattr(res2, f.name))[w],
                err_msg=f"window {w} Results.{f.name}")
        for f in dataclasses.fields(IOMetrics):
            assert (int(getattr(ios[w], f.name))
                    == int(np.asarray(getattr(ios2, f.name))[w])), \
                f"window {w} IOMetrics.{f.name}"
    np.testing.assert_array_equal(np.asarray(cr1.credit),
                                  np.asarray(cr2.credit))
    np.testing.assert_array_equal(np.asarray(cr1.retry_record),
                                  np.asarray(cr2.retry_record))


@pytest.mark.parametrize("mode", MODES)
def test_run_windows_matches_python_loop(mode):
    kinds, keys, values = _ops()
    cfg = EngineConfig(n_slots=N_SLOTS, heap_slots=HEAP, mode=mode)
    st0, cr0, _, _ = _init(cfg)
    st1, cr1, ress, ios = _loop(cfg, st0, cr0, kinds, keys, values)

    st0, cr0, _, _ = _init(cfg)   # fresh buffers: run_windows donates its args
    stream = runner.make_stream(kinds, keys, values, n_cns=N_CNS)
    st2, cr2, res2, ios2 = runner.run_windows(cfg, st0, cr0, stream,
                                              io_per_window=True)
    _assert_windows_equal(ress, ios, res2, ios2, cr1, cr2)
    ex1, v1 = store_view(st1)
    ex2, v2 = store_view(st2)
    np.testing.assert_array_equal(np.asarray(ex1), np.asarray(ex2))
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
    np.testing.assert_array_equal(np.asarray(st1.ver), np.asarray(st2.ver))
    np.testing.assert_array_equal(np.asarray(st1.epoch), np.asarray(st2.epoch))
    if mode == SyncMode.CIDER:
        assert int(np.asarray(res2.pessimistic).sum()) > 0

    # the default (summed) bill is the sum of the per-window bills
    st0, cr0, _, _ = _init(cfg)
    _, _, _, io_sum = runner.run_windows(cfg, st0, cr0, stream)
    for f in dataclasses.fields(IOMetrics):
        assert int(getattr(io_sum, f.name)) == sum(
            int(getattr(io, f.name)) for io in ios), f"summed {f.name}"


@pytest.mark.parametrize("mode", MODES)
def test_run_windows_sharded_matches_python_loop(mode):
    mesh = make_local_mesh(data=4)   # conftest pins 8 host devices
    kinds, keys, values = _ops()
    cfg = EngineConfig(n_slots=N_SLOTS, heap_slots=HEAP, mode=mode)
    st0, cr0, pop_keys, pop_vals = _init(cfg)
    st1, cr1, ress, ios = _loop(cfg, st0, cr0, kinds, keys, values)

    sst = dstore.sharded_populate(
        cfg, 4, dstore.sharded_store_init(cfg, 4), pop_keys, pop_vals)
    stream = runner.make_stream(kinds, keys, values, n_cns=N_CNS)
    st2, cr2, res2, ios2 = dstore.run_windows_sharded(
        cfg, mesh, sst, credit_init(256), stream, io_per_window=True)
    _assert_windows_equal(ress, ios, res2, ios2, cr1, cr2)
    ex1, v1 = store_view(st1)
    ex2, v2 = dstore.sharded_store_view(cfg, 4, st2)
    np.testing.assert_array_equal(np.asarray(ex1), np.asarray(ex2))
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))


def test_make_stream_matches_opbatch_make():
    kinds, keys, values = _ops()
    stream = runner.make_stream(kinds, keys, values, n_cns=N_CNS)
    for w in range(W):
        ref = OpBatch.make(kinds[w], keys[w], values[w], n_cns=N_CNS)
        for f in dataclasses.fields(OpBatch):
            np.testing.assert_array_equal(
                np.asarray(getattr(stream.batch, f.name))[w],
                np.asarray(getattr(ref, f.name)), err_msg=f.name)
    assert stream.shape == (W, B)


def test_generate_window_stream_replays_per_window_seeds():
    spec = WORKLOADS["write-intensive"]
    ops = generate_window_stream(spec, 3, 128, 512, 16, seed=7)
    assert ops.kinds.shape == (3, 128)
    for w in range(3):
        ref = generate_ops(spec, 128, 512, 16, seed=7 + w)
        np.testing.assert_array_equal(ops.kinds[w], ref.kinds)
        np.testing.assert_array_equal(ops.keys[w], ref.keys)
        np.testing.assert_array_equal(ops.values[w], ref.values)


def test_modeled_throughput_iops_and_bandwidth_bounds():
    p = SimParams()
    z = jnp.zeros((), jnp.int32)
    io = IOMetrics(reads=jnp.int32(3200), writes=z, cas=z, faa=z, cn_msgs=z,
                   mn_bytes=jnp.int32(100), retries=z, combined=z, executed=z)
    m = runner.modeled_throughput(io, p, n_ops=1000)
    # 3200 verbs / 32 per us = 100 us -> 10 ops/us = 10 Mops/s, IOPS-bound
    assert m["bound"] == "iops"
    assert m["modeled_ticks_us"] == pytest.approx(100.0)
    assert m["modeled_mops"] == pytest.approx(10.0)
    io_bw = dataclasses.replace(io, reads=jnp.int32(1),
                                mn_bytes=jnp.int32(2_500_000))
    m2 = runner.modeled_throughput(io_bw, p, n_ops=1000)
    assert m2["bound"] == "bandwidth"          # 2.5MB / 12500 B/us = 200 us
    assert m2["modeled_ticks_us"] == pytest.approx(200.0)
    # fewer MN verbs for the same ops => strictly higher modeled throughput
    io_less = dataclasses.replace(io, reads=jnp.int32(1600))
    assert (runner.modeled_throughput(io_less, p, 1000)["modeled_mops"]
            > m["modeled_mops"])
