"""Fused multi-window runner equivalence: ``run_windows`` (one jitted scan)
must be bit-identical, window by window, to the per-window Python loop over
``apply_batch`` — Results, I/O bill, credit table, and store view — for all
four SyncModes, unsharded and under ``dist.store.run_windows_sharded`` on a
multi-way CPU mesh.  Plus the MN-IOPS throughput model and the stacked-window
stream generator."""
from __future__ import annotations

import dataclasses

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import runner
from repro.core.credits import credit_init
from repro.core.engine import apply_batch, populate, store_init, store_view
from repro.core.simnet import SimParams
from repro.core.types import (EngineConfig, IOMetrics, OpBatch, OpKind,
                              SyncMode)
from repro.dist import store as dstore
from repro.launch.mesh import make_local_mesh
from repro.workloads.ycsb import WORKLOADS, generate_ops, generate_window_stream

MODES = [SyncMode.OSYNC, SyncMode.SPIN, SyncMode.MCS, SyncMode.CIDER]
W, B, N_SLOTS, HEAP, N_CNS = 4, 256, 64, 1024, 4


def _ops():
    """(W, B) op arrays with a strided cross-CN hot key so CIDER's credits
    warm up and the pessimistic global-WC path actually runs (see
    tests/test_dist_store.py for why striding matters)."""
    rng = np.random.default_rng(0)
    kinds = rng.choice(
        [OpKind.SEARCH, OpKind.INSERT, OpKind.UPDATE, OpKind.DELETE],
        size=(W, B), p=(0.3, 0.15, 0.4, 0.15)).astype(np.int32)
    keys = rng.integers(0, N_SLOTS, (W, B)).astype(np.int32)
    values = rng.integers(0, 10_000, (W, B)).astype(np.int32)
    keys[:, ::4] = 5
    kinds[:, ::4] = OpKind.UPDATE
    return kinds, keys, values


def _init(cfg):
    rng = np.random.default_rng(1)
    pop_keys = rng.choice(N_SLOTS, size=N_SLOTS // 2, replace=False)
    pop_vals = rng.integers(0, 10_000, pop_keys.shape[0])
    return (populate(cfg, store_init(cfg), pop_keys, pop_vals),
            credit_init(256), pop_keys, pop_vals)


def _loop(cfg, state, credits, kinds, keys, values):
    """The reference per-window Python loop the runner replaces."""
    ress, ios = [], []
    for w in range(W):
        batch = OpBatch.make(kinds[w], keys[w], values[w], n_cns=N_CNS)
        state, credits, res, io = apply_batch(cfg, state, credits, batch)
        ress.append(res)
        ios.append(io)
    return state, credits, ress, ios


def _assert_windows_equal(ress, ios, res2, ios2, cr1, cr2):
    for w in range(W):
        for f in dataclasses.fields(ress[w]):
            np.testing.assert_array_equal(
                np.asarray(getattr(ress[w], f.name)),
                np.asarray(getattr(res2, f.name))[w],
                err_msg=f"window {w} Results.{f.name}")
        for f in dataclasses.fields(IOMetrics):
            assert (int(getattr(ios[w], f.name))
                    == int(np.asarray(getattr(ios2, f.name))[w])), \
                f"window {w} IOMetrics.{f.name}"
    np.testing.assert_array_equal(np.asarray(cr1.credit),
                                  np.asarray(cr2.credit))
    np.testing.assert_array_equal(np.asarray(cr1.retry_record),
                                  np.asarray(cr2.retry_record))


@pytest.mark.parametrize("mode", MODES)
def test_run_windows_matches_python_loop(mode):
    kinds, keys, values = _ops()
    cfg = EngineConfig(n_slots=N_SLOTS, heap_slots=HEAP, mode=mode)
    st0, cr0, _, _ = _init(cfg)
    st1, cr1, ress, ios = _loop(cfg, st0, cr0, kinds, keys, values)

    st0, cr0, _, _ = _init(cfg)   # fresh buffers: run_windows donates its args
    stream = runner.make_stream(kinds, keys, values, n_cns=N_CNS)
    st2, cr2, res2, ios2 = runner.run_windows(cfg, st0, cr0, stream,
                                              io_per_window=True)
    _assert_windows_equal(ress, ios, res2, ios2, cr1, cr2)
    ex1, v1 = store_view(st1)
    ex2, v2 = store_view(st2)
    np.testing.assert_array_equal(np.asarray(ex1), np.asarray(ex2))
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
    np.testing.assert_array_equal(np.asarray(st1.ver), np.asarray(st2.ver))
    np.testing.assert_array_equal(np.asarray(st1.epoch), np.asarray(st2.epoch))
    if mode == SyncMode.CIDER:
        assert int(np.asarray(res2.pessimistic).sum()) > 0

    # the default (summed) bill is the sum of the per-window bills
    st0, cr0, _, _ = _init(cfg)
    _, _, _, io_sum = runner.run_windows(cfg, st0, cr0, stream)
    for f in dataclasses.fields(IOMetrics):
        assert int(getattr(io_sum, f.name)) == sum(
            int(getattr(io, f.name)) for io in ios), f"summed {f.name}"


@pytest.mark.parametrize("mode", MODES)
def test_run_windows_sharded_matches_python_loop(mode):
    mesh = make_local_mesh(data=4)   # conftest pins 8 host devices
    kinds, keys, values = _ops()
    cfg = EngineConfig(n_slots=N_SLOTS, heap_slots=HEAP, mode=mode)
    st0, cr0, pop_keys, pop_vals = _init(cfg)
    st1, cr1, ress, ios = _loop(cfg, st0, cr0, kinds, keys, values)

    sst = dstore.sharded_populate(
        cfg, 4, dstore.sharded_store_init(cfg, 4), pop_keys, pop_vals)
    stream = runner.make_stream(kinds, keys, values, n_cns=N_CNS)
    st2, cr2, res2, ios2 = dstore.run_windows_sharded(
        cfg, mesh, sst, credit_init(256), stream, io_per_window=True)
    _assert_windows_equal(ress, ios, res2, ios2, cr1, cr2)
    ex1, v1 = store_view(st1)
    ex2, v2 = dstore.sharded_store_view(cfg, 4, st2)
    np.testing.assert_array_equal(np.asarray(ex1), np.asarray(ex2))
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))


def test_make_stream_matches_opbatch_make():
    kinds, keys, values = _ops()
    stream = runner.make_stream(kinds, keys, values, n_cns=N_CNS)
    for w in range(W):
        ref = OpBatch.make(kinds[w], keys[w], values[w], n_cns=N_CNS)
        for f in dataclasses.fields(OpBatch):
            np.testing.assert_array_equal(
                np.asarray(getattr(stream.batch, f.name))[w],
                np.asarray(getattr(ref, f.name)), err_msg=f.name)
    assert stream.shape == (W, B)


def test_generate_window_stream_replays_per_window_seeds():
    spec = WORKLOADS["write-intensive"]
    ops = generate_window_stream(spec, 3, 128, 512, 16, seed=7)
    assert ops.kinds.shape == (3, 128)
    for w in range(3):
        ref = generate_ops(spec, 128, 512, 16, seed=7 + w)
        np.testing.assert_array_equal(ops.kinds[w], ref.kinds)
        np.testing.assert_array_equal(ops.keys[w], ref.keys)
        np.testing.assert_array_equal(ops.values[w], ref.values)


@pytest.mark.parametrize("mode", MODES)
def test_run_windows_traced_matches_loop_and_credit_mass(mode):
    kinds, keys, values = _ops()
    cfg = EngineConfig(n_slots=N_SLOTS, heap_slots=HEAP, mode=mode)
    st0, cr0, _, _ = _init(cfg)
    st1, cr1, ress, ios = _loop(cfg, st0, cr0, kinds, keys, values)
    # credit mass AFTER each window, from the reference loop
    st0, cr0, _, _ = _init(cfg)
    cr, masses = cr0, []
    for w in range(W):
        batch = OpBatch.make(kinds[w], keys[w], values[w], n_cns=N_CNS)
        st0, cr, _, _ = apply_batch(cfg, st0, cr, batch)
        masses.append(int(jnp.sum(cr.credit)))

    st0, cr0, _, _ = _init(cfg)
    stream = runner.make_stream(kinds, keys, values, n_cns=N_CNS)
    _, cr2, res2, ios2, mass2 = runner.run_windows_traced(cfg, st0, cr0,
                                                          stream)
    _assert_windows_equal(ress, ios, res2, ios2, cr1, cr2)
    assert [int(m) for m in np.asarray(mass2)] == masses


def test_sharded_traced_matches_single_device():
    mesh = make_local_mesh(data=4)
    kinds, keys, values = _ops()
    cfg = EngineConfig(n_slots=N_SLOTS, heap_slots=HEAP, mode=SyncMode.CIDER)
    st0, cr0, pop_keys, pop_vals = _init(cfg)
    stream = runner.make_stream(kinds, keys, values, n_cns=N_CNS)
    _, cr1, res1, ios1, mass1 = runner.run_windows_traced(cfg, st0, cr0,
                                                          stream)
    sst = dstore.sharded_populate(
        cfg, 4, dstore.sharded_store_init(cfg, 4), pop_keys, pop_vals)
    _, cr2, res2, ios2, mass2 = dstore.run_windows_sharded_traced(
        cfg, mesh, sst, credit_init(256), stream)
    for f in dataclasses.fields(res1):
        np.testing.assert_array_equal(np.asarray(getattr(res1, f.name)),
                                      np.asarray(getattr(res2, f.name)),
                                      err_msg=f"Results.{f.name}")
    for f in dataclasses.fields(IOMetrics):
        np.testing.assert_array_equal(np.asarray(getattr(ios1, f.name)),
                                      np.asarray(getattr(ios2, f.name)),
                                      err_msg=f"IOMetrics.{f.name}")
    np.testing.assert_array_equal(np.asarray(mass1), np.asarray(mass2))
    np.testing.assert_array_equal(np.asarray(cr1.credit),
                                  np.asarray(cr2.credit))


def test_modeled_latency_uncontended_searches_exact():
    """Distinct populated keys, SEARCH only: latency is the closed-form
    index READ + value READ chain plus each op's place in the NIC queue."""
    p = SimParams()
    cfg = EngineConfig(n_slots=16, heap_slots=64, mode=SyncMode.CIDER)
    st = populate(cfg, store_init(cfg), np.arange(16), np.arange(16))
    kinds = np.full(8, OpKind.SEARCH, np.int32)
    kinds[-1] = OpKind.NOP
    batch = OpBatch.make(kinds, np.arange(8), np.zeros(8), n_cns=2)
    _, _, res, _ = apply_batch(cfg, st, credit_init(64), batch)
    lat = runner.modeled_latency(cfg, kinds, res, p)
    # op i: 2 RTTs (index + value read) + 2i/mn_cap backlog behind i earlier
    # 2-verb SEARCHes
    want = p.rtt * 2.0 + 2.0 * np.arange(7) / p.mn_cap
    np.testing.assert_allclose(lat[:7], want)
    assert np.isnan(lat[7])                      # NOP lane masked out
    stats = runner.latency_stats(lat)
    assert stats.n_ops == 7 and stats.p50_us == pytest.approx(want[3], abs=.2)


def _hot_update_stream(w=8, b=256, n_slots=64, seed=0):
    """SEARCH/UPDATE mix with a strided cross-CN hot key and thin CNs (the
    paper's 4-clients-per-CN shape, so local WC can't absorb the queue) —
    enough windows for CIDER's credits to warm up."""
    rng = np.random.default_rng(seed)
    kinds = rng.choice([OpKind.SEARCH, OpKind.UPDATE], size=(w, b),
                       p=(0.5, 0.5)).astype(np.int32)
    keys = rng.integers(0, n_slots, (w, b)).astype(np.int32)
    values = rng.integers(0, 10_000, (w, b)).astype(np.int32)
    keys[:, ::4] = 5
    kinds[:, ::4] = OpKind.UPDATE
    return kinds, keys, values


def test_modeled_latency_contended_ordering():
    """On a contended write stream the modeled tail must reproduce the
    paper's ordering (Figs 11-12): CIDER's combining flattens p99 below
    OSYNC's CAS-retry storm and below the lock-queue modes."""
    kinds, keys, values = _hot_update_stream()
    n_slots, heap = 64, 64 + kinds.size
    p = SimParams()
    p99, lats, ress = {}, {}, {}
    for mode in MODES:
        cfg = EngineConfig(n_slots=n_slots, heap_slots=heap, mode=mode)
        pop = np.arange(n_slots)
        st = populate(cfg, store_init(cfg), pop, pop)
        stream = runner.make_stream(kinds, keys, values, n_cns=64)
        _, _, res, _ = runner.run_windows(cfg, st, credit_init(256), stream)
        lat = runner.modeled_latency(cfg, kinds, res, p)
        assert np.isfinite(lat[~np.isnan(lat)]).all()
        p99[mode], lats[mode], ress[mode] = (runner.latency_stats(lat).p99_us,
                                             lat, res)
    assert p99[SyncMode.CIDER] < p99[SyncMode.OSYNC]
    assert p99[SyncMode.CIDER] < p99[SyncMode.SPIN]
    assert p99[SyncMode.CIDER] < p99[SyncMode.MCS]
    # rank-r optimistic writers wait r failed CAS rounds: latency grows with
    # rank on the hot key under OSYNC
    res, lat = ress[SyncMode.OSYNC], lats[SyncMode.OSYNC]
    hot = (keys == 5) & (kinds == OpKind.UPDATE) & ~np.asarray(res.combined)
    ranks = np.asarray(res.rank)[hot]
    assert np.corrcoef(ranks, lat[hot])[0, 1] > 0.9


def test_latency_stats_empty():
    stats = runner.latency_stats(np.array([np.nan, np.nan]))
    assert stats.n_ops == 0 and stats.p99_us == 0.0


def test_modeled_throughput_iops_and_bandwidth_bounds():
    p = SimParams()
    z = jnp.zeros((), jnp.int32)
    io = IOMetrics(reads=jnp.int32(3200), writes=z, cas=z, faa=z, cn_msgs=z,
                   mn_bytes=jnp.int32(100), retries=z, combined=z, executed=z,
                   repair_cas=z, orphan_windows=z)
    m = runner.modeled_throughput(io, p, n_ops=1000)
    # 3200 verbs / 32 per us = 100 us -> 10 ops/us = 10 Mops/s, IOPS-bound
    assert m["bound"] == "iops"
    assert m["modeled_ticks_us"] == pytest.approx(100.0)
    assert m["modeled_mops"] == pytest.approx(10.0)
    io_bw = dataclasses.replace(io, reads=jnp.int32(1),
                                mn_bytes=jnp.int32(2_500_000))
    m2 = runner.modeled_throughput(io_bw, p, n_ops=1000)
    assert m2["bound"] == "bandwidth"          # 2.5MB / 12500 B/us = 200 us
    assert m2["modeled_ticks_us"] == pytest.approx(200.0)
    # fewer MN verbs for the same ops => strictly higher modeled throughput
    io_less = dataclasses.replace(io, reads=jnp.int32(1600))
    assert (runner.modeled_throughput(io_less, p, 1000)["modeled_mops"]
            > m["modeled_mops"])
