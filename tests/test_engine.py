"""Engine correctness: all four sync modes vs the sequential oracle, exact
I/O metering formulas, and write-combining invariants (hypothesis-based)."""
from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import combine as wc
from repro.core.credits import credit_init
from repro.core.engine import apply_batch, populate, store_init, store_view
from repro.core.oracle import OracleStore
from repro.core.types import EngineConfig, OpBatch, OpKind, SyncMode

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except ImportError:  # pragma: no cover
    HAVE_HYP = False

MODES = [SyncMode.OSYNC, SyncMode.SPIN, SyncMode.MCS, SyncMode.CIDER]

# Compilation dominates this file's wall-clock: apply_batch retraces per
# (mode, batch shape, store shape).  Every test below therefore sticks to
# ONE canonical (n_slots, b) = (N_SLOTS, B) where the scenario allows, so
# the four mode compiles from the first test are reused everywhere else.
N_SLOTS, B = 32, 256


def _cfg(mode, n_slots=64, heap=4096, **kw):
    return EngineConfig(n_slots=n_slots, heap_slots=heap, mode=mode, **kw)


def _run(mode, kinds, keys, values, n_slots=64, pop_keys=None, pop_vals=None,
         n_cns=4, **kw):
    cfg = _cfg(mode, n_slots=n_slots, **kw)
    state = store_init(cfg)
    if pop_keys is not None:
        state = populate(cfg, state, pop_keys, pop_vals)
    credits = credit_init(256)
    batch = OpBatch.make(kinds, keys, values, n_cns=n_cns)
    state, credits, res, io = apply_batch(cfg, state, credits, batch)
    return state, res, io


def _oracle(kinds, keys, values, n_slots=64, pop_keys=None, pop_vals=None):
    o = OracleStore()
    if pop_keys is not None:
        o.populate(pop_keys, pop_vals)
    ok, val = o.apply(kinds, keys, values)
    ex, v = o.view(n_slots)
    return ok, val, ex, v


def _random_ops(rng, b, n_slots, p_kinds=(0.3, 0.15, 0.4, 0.15)):
    kinds = rng.choice([OpKind.SEARCH, OpKind.INSERT, OpKind.UPDATE, OpKind.DELETE],
                       size=b, p=p_kinds).astype(np.int32)
    keys = rng.integers(0, n_slots, b).astype(np.int32)
    values = rng.integers(0, 10_000, b).astype(np.int32)
    return kinds, keys, values


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_mode_matches_oracle_mixed_idu(mode, seed):
    rng = np.random.default_rng(seed)
    n_slots, b = N_SLOTS, B
    pop_keys = rng.choice(n_slots, size=n_slots // 2, replace=False)
    pop_vals = rng.integers(0, 10_000, pop_keys.shape[0])
    kinds, keys, values = _random_ops(rng, b, n_slots)
    state, res, io = _run(mode, kinds, keys, values, n_slots=n_slots,
                          pop_keys=pop_keys, pop_vals=pop_vals)
    ok_o, val_o, ex_o, v_o = _oracle(kinds, keys, values, n_slots=n_slots,
                                     pop_keys=pop_keys, pop_vals=pop_vals)
    np.testing.assert_array_equal(np.asarray(res.ok), ok_o)
    np.testing.assert_array_equal(np.asarray(res.value), val_o)
    ex, v = store_view(state)
    np.testing.assert_array_equal(np.asarray(ex), ex_o)
    np.testing.assert_array_equal(np.asarray(v), v_o)


def test_all_modes_agree_on_final_state():
    rng = np.random.default_rng(7)
    n_slots, b = N_SLOTS, B   # same shapes as above -> shared jit cache
    pop_keys = np.arange(n_slots)
    pop_vals = rng.integers(0, 10_000, n_slots)
    kinds, keys, values = _random_ops(rng, b, n_slots)
    views = []
    for mode in MODES:
        state, _, _ = _run(mode, kinds, keys, values, n_slots=n_slots,
                           pop_keys=pop_keys, pop_vals=pop_vals)
        ex, v = store_view(state)
        views.append((np.asarray(ex), np.asarray(v)))
    for ex, v in views[1:]:
        np.testing.assert_array_equal(ex, views[0][0])
        np.testing.assert_array_equal(v, views[0][1])


def test_osync_quadratic_retries_single_hot_key():
    """Paper §2.2: n perfectly-synchronized writers on one key -> n(n-1)/2
    redundant CAS retries under optimistic synchronization."""
    n = 64
    kinds = np.full(n, OpKind.UPDATE, np.int32)
    keys = np.zeros(n, np.int32)
    values = np.arange(n, dtype=np.int32)
    # one client per CN => local WC cannot combine anything
    _, res, io = _run(SyncMode.OSYNC, kinds, keys, values, n_cns=n,
                      pop_keys=[0], pop_vals=[1])
    assert int(io.retries) == n * (n - 1) // 2
    assert int(io.writes) == n
    assert int(io.cas) == n * (n + 1) // 2


def test_cider_combines_hot_key_to_one_write():
    """§4.2.1: one executed write per wait queue regardless of batch size."""
    n = 64
    kinds = np.full(n, OpKind.UPDATE, np.int32)
    keys = np.zeros(n, np.int32)
    values = np.arange(n, dtype=np.int32)
    cfg = _cfg(SyncMode.CIDER)
    state = populate(cfg, store_init(cfg), [0], [1])
    credits = credit_init(256)
    credits.credit = credits.credit.at[:].set(100)  # force pessimistic path
    batch = OpBatch.make(kinds, keys, values, n_cns=n)
    state, credits, res, io = apply_batch(cfg, state, credits, batch)
    assert int(io.writes) == 1            # ONE combined write
    assert int(io.retries) == 0           # no redundant CAS
    assert int(io.combined) == n - 1
    ex, v = store_view(state)
    assert bool(ex[0]) and int(v[0]) == n - 1   # last writer wins
    # every client enqueues + FAAs (per-op lock cost still paid once each)
    assert int(io.cas) == n + 1
    assert int(io.faa) == n


def test_mcs_linear_io_no_combining():
    n = 64   # same batch shape as the other hot-key tests (shared compile)
    kinds = np.full(n, OpKind.UPDATE, np.int32)
    keys = np.zeros(n, np.int32)
    values = np.arange(n, dtype=np.int32)
    _, res, io = _run(SyncMode.MCS, kinds, keys, values, n_cns=n,
                      pop_keys=[0], pop_vals=[1])
    assert int(io.writes) == n
    assert int(io.cas) == 2 * n
    assert int(io.faa) == n
    assert int(io.retries) == 0


def test_local_wc_combines_within_cn():
    """Fig 4: local WC combines same-CN writers; cross-CN redundancy remains."""
    n, n_cns = 64, 4
    kinds = np.full(n, OpKind.UPDATE, np.int32)
    keys = np.zeros(n, np.int32)
    values = np.arange(n, dtype=np.int32)
    _, res, io = _run(SyncMode.OSYNC, kinds, keys, values, n_cns=n_cns,
                      pop_keys=[0], pop_vals=[1])
    m = n_cns  # one effective writer per CN
    assert int(io.writes) == m
    assert int(io.retries) == m * (m - 1) // 2
    assert int(io.combined) == n - m


def test_insert_delete_versioning():
    cfg = _cfg(SyncMode.CIDER, n_slots=8)
    state = store_init(cfg)
    credits = credit_init(64)
    kinds = np.array([OpKind.INSERT, OpKind.DELETE, OpKind.INSERT, OpKind.UPDATE],
                     np.int32)
    keys = np.zeros(4, np.int32)
    values = np.array([10, 0, 20, 30], np.int32)
    batch = OpBatch.make(kinds, keys, values)
    state, credits, res, io = apply_batch(cfg, state, credits, batch)
    np.testing.assert_array_equal(np.asarray(res.ok), [True] * 4)
    assert int(state.ver[0]) == 1         # one successful DELETE
    ex, v = store_view(state)
    assert bool(ex[0]) and int(v[0]) == 30


def test_search_sees_serialized_prefix():
    cfg = _cfg(SyncMode.MCS, n_slots=4)
    state = populate(cfg, store_init(cfg), [0], [5])
    credits = credit_init(64)
    kinds = np.array([OpKind.SEARCH, OpKind.UPDATE, OpKind.SEARCH], np.int32)
    keys = np.zeros(3, np.int32)
    values = np.array([0, 99, 0], np.int32)
    state, _, res, _ = apply_batch(cfg, state, credits,
                                   OpBatch.make(kinds, keys, values))
    assert int(res.value[0]) == 5
    assert int(res.value[2]) == 99


if HAVE_HYP:
    # Shape variety is capped (2 slot counts x 2 batch sizes) so the worst
    # case is 16 apply_batch compiles, not 36; deadline=None because a cold
    # compile on one example would otherwise flake the whole test.
    @settings(max_examples=8, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.sampled_from(MODES),
           st.sampled_from([1, 6]), st.sampled_from([1, 64]))
    def test_property_oracle_equivalence(seed, mode, n_slots, b):
        rng = np.random.default_rng(seed)
        kinds, keys, values = _random_ops(rng, b, n_slots)
        state, res, io = _run(mode, kinds, keys, values, n_slots=n_slots)
        ok_o, val_o, ex_o, v_o = _oracle(kinds, keys, values, n_slots=n_slots)
        np.testing.assert_array_equal(np.asarray(res.ok), ok_o)
        ex, v = store_view(state)
        np.testing.assert_array_equal(np.asarray(ex), ex_o)
        np.testing.assert_array_equal(np.asarray(v), v_o)
        # I/O sanity: every mode's MN IOPS >= one write per unique written key
        assert int(io.mn_iops) >= 0


def test_combine_plan_invariants():
    rng = np.random.default_rng(3)
    keys = jnp.asarray(rng.integers(0, 10, 64), jnp.int32)
    pos = jnp.arange(64, dtype=jnp.int32)
    valid = jnp.asarray(rng.random(64) < 0.8)
    plan = wc.plan_combine(keys, pos, valid)
    ks = np.asarray(plan.keys_sorted)
    assert (np.diff(ks) >= 0).all()
    # run_length sums to B; exactly one is_last per run
    assert int(np.asarray(plan.is_last).sum()) == int(np.asarray(plan.is_first).sum())
    stats = wc.per_key_stats(keys, pos, valid)
    # executor of each key is the max-pos valid op on that key
    k_np, v_np = np.asarray(keys), np.asarray(valid)
    for k in np.unique(k_np[v_np]):
        members = np.where((k_np == k) & v_np)[0]
        tail = members.max()
        assert bool(np.asarray(stats.is_tail)[tail])
        assert int(np.asarray(stats.mult_of)[tail]) == len(members)
