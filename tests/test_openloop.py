"""Open-loop arrival streams (DESIGN.md §12): the Poisson arrival-count law
(chi-square, mirroring the zipf tests), MMPP burst-phase composition, the
padding-plane invariants (invalid lanes never bill), and the dense-repack
bit-equality contract on the single-device and 8-way sharded paths."""
from __future__ import annotations

import math

import numpy as np

import jax

from repro.core import runner
from repro.core.credits import credit_init
from repro.core.engine import populate, store_init
from repro.core.types import EngineConfig, OpKind, SyncMode
from repro.dist import store as dstore
from repro.launch.mesh import make_local_mesh
from repro.workloads.openloop import (OpenLoopSpec, dense_repack,
                                      generate_openloop_stream,
                                      open_loop_latency)

N_CNS, LANES = 4, 16
B = N_CNS * LANES


def _spec(**kw):
    base = dict(n_cns=N_CNS, lanes_per_cn=LANES, windows=12, rho=0.8,
                n_keys=512, seed=3)
    base.update(kw)
    return OpenLoopSpec(**base)


def _poisson_pmf(lam, kmax):
    k = np.arange(kmax, dtype=np.float64)
    logp = k * math.log(lam) - lam - [math.lgamma(x + 1) for x in k]
    return np.exp(logp)


# ------------------------------------------------------------- arrival law


def test_poisson_arrival_count_law_chi_square():
    """Per-(window, CN) arrival counts fit Poisson(rho * lanes_per_cn):
    mean == variance, and chi-square over the binned count distribution
    stays below the 99.9% critical value (same pattern as the zipf tests)."""
    ol = generate_openloop_stream(_spec(windows=4000, rho=0.8, seed=0))
    lam = 0.8 * LANES
    draws = ol.arrivals.ravel().astype(np.int64)
    assert abs(draws.mean() - lam) < 0.15
    assert abs(draws.var() / draws.mean() - 1.0) < 0.05   # dispersion == 1
    kmax = int(draws.max()) + 1
    pmf = _poisson_pmf(lam, kmax)
    # lump the far tail so every expected bin count stays >~5
    counts = np.bincount(draws, minlength=kmax).astype(np.float64)
    keep = pmf * draws.size >= 5
    counts = np.concatenate([counts[keep], [counts[~keep].sum()]])
    pmf = np.concatenate([pmf[keep], [pmf[~keep].sum()]])
    chi2 = float(((counts - pmf * draws.size) ** 2
                  / np.maximum(pmf * draws.size, 1e-12)).sum())
    # dof ~ len(counts)-1 (~35); 99.9% critical value of chi2(40) is ~73
    assert chi2 < 90, f"chi2={chi2:.1f} over {len(counts)} bins"


def test_mmpp_burst_phase_composition():
    """The 2-state MMPP: both phases occur, the burst phase's stationary
    share matches p_enter/(p_enter+p_exit), burst windows carry ~burst_mult
    more arrivals than quiet ones, and the normalization keeps the OVERALL
    mean at rho*lanes_per_cn — rho stays comparable across processes."""
    sp = _spec(windows=6000, rho=0.7, arrival="mmpp", burst_mult=4.0,
               p_enter_burst=0.1, p_exit_burst=0.3, seed=1)
    ol = generate_openloop_stream(sp)
    ph = ol.phases.astype(bool)
    assert ph.any() and (~ph).any()
    pi_b = 0.1 / 0.4
    assert abs(ph.mean() - pi_b) < 0.03
    quiet = ol.arrivals[~ph].mean()
    burst = ol.arrivals[ph].mean()
    assert abs(burst / quiet - 4.0) < 0.25
    assert abs(ol.arrivals.mean() - 0.7 * LANES) < 0.2


def test_mmpp_overdispersed_vs_poisson():
    """Bursty arrivals are the point: MMPP's count variance exceeds its mean
    (index of dispersion > 1), unlike the Poisson stream's."""
    ol = generate_openloop_stream(_spec(windows=4000, arrival="mmpp", seed=2))
    d = ol.arrivals.ravel()
    assert d.var() / d.mean() > 1.5


def test_spec_validation():
    import pytest
    with pytest.raises(ValueError):
        _spec(arrival="bursty")
    with pytest.raises(ValueError):
        _spec(rho=0.0)


# ------------------------------------------------- queueing / padding plane


def test_fifo_conservation_and_delay():
    """Offered arrivals are either delivered into lanes or left as backlog;
    at rho > 1 the backlog grows and per-op queueing delay appears."""
    ol = generate_openloop_stream(_spec(rho=0.7, windows=40, seed=5))
    assert ol.offered == ol.delivered + int(ol.backlog_end.sum())
    hot = generate_openloop_stream(_spec(rho=1.3, windows=40, seed=5))
    assert hot.offered == hot.delivered + int(hot.backlog_end.sum())
    assert hot.backlog_end.sum() > 0
    assert hot.delay_windows.max() > ol.delay_windows.max()
    # overloaded CNs issue full windows once the backlog builds
    assert hot.valid[-1].all()


def test_padding_plane_shape_and_layout():
    """Invalid lanes are NOP with zeroed planes; each CN's issued ops pack
    the front of its own lane block; the CN plane is the block map."""
    ol = generate_openloop_stream(_spec(seed=7))
    assert ol.kinds.shape == (12, B)
    assert ((ol.kinds == OpKind.NOP) == ~ol.valid).all()
    assert (ol.delay_windows[~ol.valid] == 0).all()
    assert (ol.keys[~ol.valid] == 0).all()
    cn = np.repeat(np.arange(N_CNS), LANES)
    assert (ol.cn == cn[None, :]).all()
    for c in range(N_CNS):
        block = ol.valid[:, c * LANES:(c + 1) * LANES]
        # valid lanes are a prefix of the block in every window
        assert (np.sort(block, axis=1)[:, ::-1] == block).all()


def _run(cfg, ol, n_cns=N_CNS, lanes=LANES):
    st = populate(cfg, store_init(cfg), np.arange(cfg.n_slots),
                  np.arange(cfg.n_slots))
    stream = runner.make_stream(ol.kinds, ol.keys % cfg.n_slots, ol.values,
                                n_cns=n_cns, lanes_per_cn=lanes,
                                valid=ol.valid, cn=ol.cn)
    return runner.run_windows(cfg, st, credit_init(cfg.n_slots), stream)


def test_invalid_lanes_never_bill():
    """The bill must be a function of the VALID lanes only: scrambling the
    padding lanes' keys/values/kinds changes nothing — not the bill, not
    the store, not the valid lanes' results."""
    ol = generate_openloop_stream(_spec(seed=9))
    cfg = EngineConfig(n_slots=1024, heap_slots=4096, mode=SyncMode.CIDER)
    st1, cr1, res1, io1 = _run(cfg, ol)

    rng = np.random.default_rng(0)
    garbled = generate_openloop_stream(_spec(seed=9))
    inv = ~garbled.valid
    garbled.keys[inv] = rng.integers(0, 1024, inv.sum())
    garbled.values[inv] = rng.integers(1, 2**30, inv.sum())
    st2, cr2, res2, io2 = _run(cfg, garbled)

    for f in io1.__dataclass_fields__:
        np.testing.assert_array_equal(np.asarray(getattr(io1, f)),
                                      np.asarray(getattr(io2, f)), f)
    for f in st1.__dataclass_fields__:
        np.testing.assert_array_equal(np.asarray(getattr(st1, f)),
                                      np.asarray(getattr(st2, f)), f)
    ok = np.asarray(res1.ok)
    np.testing.assert_array_equal(ok[ol.valid], np.asarray(res2.ok)[ol.valid])


# --------------------------------------------------- dense-repack contract


def _assert_same_run(ol, rp, run_a, run_b):
    st1, cr1, res1, io1 = run_a
    st2, cr2, res2, io2 = run_b
    for f in io1.__dataclass_fields__:
        np.testing.assert_array_equal(np.asarray(getattr(io1, f)),
                                      np.asarray(getattr(io2, f)), f)
    np.testing.assert_array_equal(np.asarray(cr1.credit),
                                  np.asarray(cr2.credit))
    # per-op results land at permuted lanes: repacked lane b holds what
    # original lane order[w, b] held
    for f in res1.__dataclass_fields__:
        a, b = np.asarray(getattr(res1, f)), np.asarray(getattr(res2, f))
        if a.ndim >= 2 and a.shape[:2] == ol.valid.shape:
            moved = np.take_along_axis(a, rp.order, axis=1)
            np.testing.assert_array_equal(moved[rp.valid], b[rp.valid], f)


def test_dense_repack_bit_equality_single_device():
    """DESIGN.md §12: packing valid lanes to the front (stable, CN plane
    carried) is invisible — bill, store, credits, and per-op results are
    bit-identical modulo the recorded lane permutation.  All four modes."""
    ol = generate_openloop_stream(_spec(seed=11))
    rp = dense_repack(ol)
    assert (np.sort(rp.valid, axis=1)[:, ::-1] == rp.valid).all()
    assert rp.delivered == ol.delivered
    for mode in SyncMode:
        cfg = EngineConfig(n_slots=1024, heap_slots=4096, mode=mode)
        a, b = _run(cfg, ol), _run(cfg, rp)
        _assert_same_run(ol, rp, a, b)
        for f in a[0].__dataclass_fields__:
            np.testing.assert_array_equal(np.asarray(getattr(a[0], f)),
                                          np.asarray(getattr(b[0], f)), f)


def test_dense_repack_bit_equality_sharded_8way():
    """The same contract through the 8-way shard_map runner: partially
    filled windows and their dense re-pack produce the identical global
    bill and logical store view."""
    ol = generate_openloop_stream(_spec(seed=13))
    rp = dense_repack(ol)
    cfg = EngineConfig(n_slots=1024, heap_slots=4096, mode=SyncMode.CIDER)
    mesh = make_local_mesh(data=8)
    pk = np.arange(cfg.n_slots)

    def run(s):
        st = dstore.sharded_populate(
            cfg, 8, dstore.sharded_store_init(cfg, 8), pk, pk)
        stream = runner.make_stream(s.kinds, s.keys % cfg.n_slots, s.values,
                                    n_cns=N_CNS, lanes_per_cn=LANES,
                                    valid=s.valid, cn=s.cn)
        return dstore.run_windows_sharded(cfg, mesh, st,
                                          credit_init(cfg.n_slots), stream)

    st1, _, _, io1 = run(ol)
    st2, _, _, io2 = run(rp)
    for f in io1.__dataclass_fields__:
        np.testing.assert_array_equal(np.asarray(getattr(io1, f)),
                                      np.asarray(getattr(io2, f)), f)
    for a, b in zip(dstore.sharded_store_view(cfg, 8, st1),
                    dstore.sharded_store_view(cfg, 8, st2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_per_shard_io_sums_to_replicated_bill():
    """per_shard_io appends an (n_shards,) axis whose sum recovers the
    replicated global bill bit-exactly — the weak-scaling benchmark's
    hottest-shard metric rests on this."""
    ol = generate_openloop_stream(_spec(seed=15))
    cfg = EngineConfig(n_slots=1024, heap_slots=4096, mode=SyncMode.CIDER)
    mesh = make_local_mesh(data=8)
    pk = np.arange(cfg.n_slots)

    def run(per_shard):
        st = dstore.sharded_populate(
            cfg, 8, dstore.sharded_store_init(cfg, 8), pk, pk)
        stream = runner.make_stream(ol.kinds, ol.keys % cfg.n_slots,
                                    ol.values, n_cns=N_CNS,
                                    lanes_per_cn=LANES, valid=ol.valid,
                                    cn=ol.cn)
        return dstore.run_windows_sharded(cfg, mesh, st,
                                          credit_init(cfg.n_slots), stream,
                                          per_shard_io=per_shard)

    _, _, _, io_s = run(True)
    _, _, _, io_g = run(False)
    for f in io_s.__dataclass_fields__:
        a = np.asarray(getattr(io_s, f))
        assert a.shape[-1] == 8, f
        np.testing.assert_array_equal(a.sum(-1),
                                      np.asarray(getattr(io_g, f)), f)


# ------------------------------------------------------- latency semantics


def test_open_loop_latency_adds_queue_delay():
    """Total latency = delay_windows * window_us + in-window modeled
    latency; invalid lanes come back NaN."""
    from repro.core.simnet import SimParams
    ol = generate_openloop_stream(_spec(rho=1.2, windows=20, seed=17))
    cfg = EngineConfig(n_slots=1024, heap_slots=8192, mode=SyncMode.CIDER)
    _, _, res, _ = _run(cfg, ol)
    lat = runner.modeled_latency(cfg, ol.kinds, res, SimParams(),
                                 valid=ol.valid)
    total = open_loop_latency(ol, lat, window_us=100.0)
    assert np.isnan(total[~ol.valid]).all()
    lat2 = np.asarray(lat).reshape(ol.valid.shape)
    delayed = ol.valid & (ol.delay_windows > 0)
    assert delayed.any()
    np.testing.assert_allclose(
        total[delayed] - lat2[delayed],
        ol.delay_windows[delayed].astype(np.float64) * 100.0)


def test_make_stream_rejects_bad_cn_plane():
    import pytest
    ol = generate_openloop_stream(_spec(seed=19))
    with pytest.raises(ValueError, match="cn plane"):
        runner.make_stream(ol.kinds, ol.keys, ol.values, n_cns=N_CNS,
                           valid=ol.valid, cn=ol.cn[:, :8])
