"""Store/index correctness: RACE hash + SMART ART vs the dict oracle, under
every sync mode; reservation/overflow behaviour; heap reclaim."""
from __future__ import annotations

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.oracle import OracleStore
from repro.core.types import OpKind, SyncMode
from repro.stores import PointerArray, RaceHash, SmartART
from repro.stores.heap import reclaim
from repro.core import engine
from repro.core.types import OpBatch

MODES = [SyncMode.OSYNC, SyncMode.MCS, SyncMode.CIDER]


def _ops(rng, b, key_space):
    kinds = rng.choice([OpKind.SEARCH, OpKind.INSERT, OpKind.UPDATE, OpKind.DELETE],
                       size=b, p=(0.3, 0.25, 0.3, 0.15)).astype(np.int32)
    keys = rng.integers(0, key_space, b).astype(np.int32)
    values = rng.integers(0, 10_000, b).astype(np.int32)
    return kinds, keys, values


@pytest.mark.parametrize("mode", MODES)
def test_race_hash_vs_oracle(mode):
    rng = np.random.default_rng(0)
    store = RaceHash.create(1024, mode=mode)
    oracle = OracleStore()
    key_space = 5_000  # sparse keys -> exercises insert/absent paths
    for step in range(4):
        kinds, keys, values = _ops(rng, 256, key_space)
        store, res, io, ovf = store.apply(kinds, keys, values, n_cns=8)
        assert not bool(np.asarray(ovf).any()), "no overflow at low load"
        ok_o, val_o = oracle.apply(kinds, keys, values)
        np.testing.assert_array_equal(np.asarray(res.ok), ok_o,
                                      err_msg=f"step {step}")
        np.testing.assert_array_equal(np.asarray(res.value), val_o)


@pytest.mark.parametrize("mode", MODES)
def test_smart_art_vs_oracle(mode):
    rng = np.random.default_rng(1)
    store = SmartART.create(key_bits=12, mode=mode)
    oracle = OracleStore()
    for _step in range(4):
        kinds, keys, values = _ops(rng, 256, 1 << 12)
        store, res, io = store.apply(kinds, keys, values, n_cns=8)
        ok_o, val_o = oracle.apply(kinds, keys, values)
        np.testing.assert_array_equal(np.asarray(res.ok), ok_o)
        np.testing.assert_array_equal(np.asarray(res.value), val_o)


def test_race_populate_then_search():
    rng = np.random.default_rng(2)
    keys = rng.choice(100_000, size=512, replace=False)
    vals = rng.integers(0, 10_000, 512)
    store = RaceHash.create(2048).populate(keys, vals, chunk=256)
    kinds = np.full(512, OpKind.SEARCH, np.int32)
    store, res, io, _ = store.apply(kinds, keys, vals)
    assert bool(np.asarray(res.ok).all())
    np.testing.assert_array_equal(np.asarray(res.value), vals)


def test_race_overflow_on_full_bucket():
    store = RaceHash.create(32, ways=2)  # 16 buckets x 2 ways
    rng = np.random.default_rng(3)
    kinds = np.full(512, OpKind.INSERT, np.int32)
    keys = rng.integers(0, 1 << 28, 512).astype(np.int32)
    values = np.ones(512, np.int32)
    store, res, io, ovf = store.apply(kinds, keys, values)
    assert bool(np.asarray(ovf).any())          # table can't fit 512 keys
    # every non-overflowed distinct key is findable
    ok_keys = np.asarray(keys)[np.asarray(res.ok)]
    if ok_keys.size:
        s2 = np.full(ok_keys.size, OpKind.SEARCH, np.int32)
        _, res2, _, _ = store.apply(s2, ok_keys, np.zeros(ok_keys.size, np.int32))
        assert bool(np.asarray(res2.ok).all())


def test_race_overflow_keys_absent_then_recoverable():
    """Overflow semantics end-to-end: an op either overflows OR executes
    (never both), overflowed keys stay absent, and freeing ways via DELETE
    makes previously-overflowing keys insertable again."""
    store = RaceHash.create(16, ways=2)          # 8 buckets x 2 ways
    keys = (np.arange(64, dtype=np.int64) * 2654435761 % (1 << 20)).astype(np.int32)
    kinds = np.full(64, OpKind.INSERT, np.int32)
    vals = np.arange(64, dtype=np.int32)
    store, res, io, ovf = store.apply(kinds, keys, vals)
    ovf, ok = np.asarray(ovf), np.asarray(res.ok)
    assert ovf.any() and ok.any()
    assert not (ovf & ok).any()
    bad = keys[ovf]
    _, res2, _, _ = store.apply(np.full(bad.size, OpKind.SEARCH, np.int32),
                                bad, np.zeros(bad.size, np.int32))
    assert not np.asarray(res2.ok).any()
    # free every occupied way, then retry a handful of overflowed keys:
    # rank-0 reservations in an empty table must succeed
    good = keys[ok]
    store, res3, _, _ = store.apply(
        np.full(good.size, OpKind.DELETE, np.int32), good,
        np.zeros(good.size, np.int32))
    assert bool(np.asarray(res3.ok).all())
    retry = bad[:4]
    store, res4, _, ovf4 = store.apply(
        np.full(retry.size, OpKind.INSERT, np.int32), retry,
        np.arange(retry.size, dtype=np.int32))
    assert bool(np.asarray(res4.ok).any())
    assert not (np.asarray(ovf4) & np.asarray(res4.ok)).any()


def test_race_index_io_metered():
    store = RaceHash.create(1024)
    kinds = np.full(64, OpKind.SEARCH, np.int32)
    keys = np.arange(64, dtype=np.int32)
    store, res, io, _ = store.apply(kinds, keys, keys)
    # 2 bucket reads per op + 1 KV read per found op (none found here)
    assert int(io.reads) == 64 * 2


def test_smart_slot_bijection():
    store = SmartART.create(key_bits=16)
    keys = jnp.arange(1 << 16, dtype=jnp.int32)
    slots = np.asarray(store.slots(keys))
    assert np.unique(slots).size == 1 << 16


def test_heap_reclaim_preserves_view():
    cfg_store = PointerArray.create(64, mode=SyncMode.CIDER)
    rng = np.random.default_rng(4)
    store = cfg_store.populate(np.arange(64), rng.integers(0, 100, 64))
    for _ in range(6):
        kinds, keys, values = _ops(rng, 128, 64)
        batch = OpBatch.make(kinds, keys, values, n_cns=4)
        store, res, io = store.apply(batch)
    ex0, v0 = store.view()
    state2 = reclaim(store.state)
    ex1, v1 = engine.store_view(state2)
    np.testing.assert_array_equal(np.asarray(ex0), np.asarray(ex1))
    np.testing.assert_array_equal(np.asarray(v0), np.asarray(v1))
    assert int(state2.heap_top) == int(np.asarray(ex0).sum())
