"""FleetMonitor contracts (launcher-level §4.6): a worker that never beats
is dead relative to monitor start, stragglers are judged against their OWN
EWMA (deadline-missing samples never fold in), and the active set shrinks
and regrows elastically."""
from __future__ import annotations

from repro.ft.failures import FleetMonitor


def test_dead_at_start_without_any_beat():
    m = FleetMonitor(3, max_wait_s=60.0, now=0.0)
    assert m.dead_workers(now=59.0) == []
    # silence since monitor start counts as staleness — not innocence
    assert m.dead_workers(now=61.0) == [0, 1, 2]


def test_beat_revives_and_staleness_redeclares():
    m = FleetMonitor(3, max_wait_s=60.0, now=0.0)
    m.beat(1, now=30.0)
    assert m.dead_workers(now=61.0) == [0, 2]
    assert m.active_set(now=61.0) == [1]
    # worker 1 goes silent for max_wait after its last beat -> dead again
    assert 1 in m.dead_workers(now=91.0)


def test_straggler_uses_own_ewma_and_excludes_after_strikes():
    m = FleetMonitor(2, max_wait_s=1e9, now=0.0)
    for i in range(10):
        m.beat(0, step_time_s=1.0, now=float(i))
        m.beat(1, step_time_s=1.0, now=float(i))
    # worker 1 degrades to 10x; worker 0 stays at pace
    for i in range(3):
        m.beat(0, step_time_s=1.0, now=10.0 + i)
        m.beat(1, step_time_s=10.0, now=10.0 + i)
    assert m.excluded == {1}
    assert m.active_set(now=13.0) == [0]


def test_strike_samples_do_not_inflate_the_ewma():
    """The old fleet-global EWMA absorbed the slow samples, so a degrading
    worker raised its own deadline and masked itself.  Per-worker EWMA with
    strike samples kept out must keep striking at the old pace."""
    m = FleetMonitor(1, now=0.0)
    m.beat(0, step_time_s=1.0, now=0.0)
    m.beat(0, step_time_s=10.0, now=1.0)        # strike 1
    assert m._ewma[0] == 1.0                    # sample NOT folded in
    m.beat(0, step_time_s=10.0, now=2.0)        # still 10 > 3 * 1.0
    m.beat(0, step_time_s=10.0, now=3.0)        # third strike
    assert m.excluded == {0}


def test_fast_sample_resets_strikes():
    m = FleetMonitor(1, strikes=3, now=0.0)
    m.beat(0, step_time_s=1.0, now=0.0)
    m.beat(0, step_time_s=10.0, now=1.0)
    m.beat(0, step_time_s=10.0, now=2.0)
    m.beat(0, step_time_s=1.0, now=3.0)         # recovered: strikes reset
    m.beat(0, step_time_s=10.0, now=4.0)
    m.beat(0, step_time_s=10.0, now=5.0)
    assert m.excluded == set()


def test_one_slow_worker_does_not_mask_itself_or_others():
    """Regression for the shared-EWMA bug: worker 1's slowness must neither
    raise worker 0's deadline nor its own."""
    m = FleetMonitor(2, now=0.0)
    m.beat(0, step_time_s=1.0, now=0.0)
    m.beat(1, step_time_s=100.0, now=0.0)       # first sample seeds its OWN ewma
    # worker 0's 2.0s step is fine against ITS ewma (2 < 3*1), even though
    # worker 1's ewma is 100
    m.beat(0, step_time_s=2.0, now=1.0)
    assert m._miss[0] == 0
    # worker 1 returning to 100s steps is on-pace for worker 1
    m.beat(1, step_time_s=100.0, now=1.0)
    assert m._miss[1] == 0
