"""Tests for the repro.analysis invariant auditor (DESIGN.md §11).

Two obligations per pass: it is CLEAN on the real tree, and it FAILS LOUDLY
on an injected violation — a gate that cannot fail proves nothing.  The
injections are fixtures (in-memory sources for the lint, toy jitted
functions for the jaxpr audit, seeded-bug ``ModelFlags`` for the model
checker); the real tree is never mutated.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import pytest

from benchmarks.provenance import provenance
from repro.analysis import ANALYSIS_VERSION, PASSES, analysis_provenance
from repro.analysis import bill_lint, jaxpr_check, race_check
from repro.analysis.race_check import (
    ModelFlags, ReplScenario, Scenario, explore, explore_replicated,
    repl_scenarios)
from repro.core.types import OpKind, SyncMode

# ---------------------------------------------------------------- plumbing


def test_provenance_names_the_passes():
    p = analysis_provenance()
    assert p["version"] == ANALYSIS_VERSION
    assert tuple(p["passes"]) == PASSES == (
        "jaxpr_check", "bill_lint", "race_check")
    # and the benchmark config blocks carry it (satellite: every BENCH_*
    # JSON records which invariant gates its generating tree was under)
    assert provenance()["analysis"] == p


# ---------------------------------------------------------------- bill lint

METRICS_OK = """
## 1. IOMetrics

| field | unit | meaning |
|---|---|---|
| `reads` | verbs | pointer READs |
| `faa` | verbs | credit FAAs |

## 2. other
"""

RUNNER_OK = """
def modeled_throughput(io: IOMetrics):
    return io.reads + io.mn_iops
"""

TYPES_OK = """
class IOMetrics:
    @property
    def mn_iops(self):
        return self.reads + self.writes + self.cas + self.faa
"""


def test_bill_lint_clean_on_real_tree():
    assert bill_lint.run() == []


def test_bill_lint_rejects_undocumented_field():
    out = bill_lint.lint_sources(
        {"src/repro/core/engine.py": "io = IOMetrics(reads=r, cas=c)"},
        metrics_md=METRICS_OK, runner_source=RUNNER_OK,
        types_source=TYPES_OK, store_sources={},
        whitelist={})
    assert any("'cas'" in v.message and "no row" in v.message for v in out)


def test_bill_lint_rejects_unconsumed_unwhitelisted_field():
    md = METRICS_OK.replace(
        "| `faa` | verbs | credit FAAs |",
        "| `faa` | verbs | credit FAAs |\n| `retries` | count | waste |")
    src = "io = IOMetrics(reads=r, retries=w)"
    out = bill_lint.lint_sources(
        {"src/repro/core/engine.py": src}, metrics_md=md,
        runner_source=RUNNER_OK, types_source=TYPES_OK,
        store_sources={}, whitelist={})
    assert any("'retries'" in v.message and "never consumed" in v.message
               for v in out)
    # whitelisting with a reason silences exactly that violation
    ok = bill_lint.lint_sources(
        {"src/repro/core/engine.py": src}, metrics_md=md,
        runner_source=RUNNER_OK, types_source=TYPES_OK,
        store_sources={}, whitelist={"retries": "waste diagnostic"})
    assert not any("'retries'" in v.message for v in ok)


def test_bill_lint_rejects_stale_whitelist_entry():
    out = bill_lint.lint_sources(
        {}, metrics_md=METRICS_OK, runner_source=RUNNER_OK,
        types_source=TYPES_OK, store_sources={},
        whitelist={"not_a_field": "stale"})
    assert any("stale whitelist" in v.message for v in out)


def test_bill_lint_rejects_bare_notimplementederror_in_stores():
    src = ("def apply(self, kinds):\n"
           "    raise NotImplementedError('no SCAN')\n")
    out = bill_lint.lint_sources(
        {}, metrics_md=METRICS_OK, runner_source=RUNNER_OK,
        types_source=TYPES_OK,
        store_sources={"src/repro/stores/toy.py": src})
    assert any("UnsupportedOpError" in v.message for v in out)


def test_bill_lint_consumption_via_derived_metric_and_annotation_guard():
    derived = bill_lint.derived_field_map(
        open("src/repro/core/types.py").read())
    assert derived["mn_iops"] == {"reads", "writes", "cas", "faa"}
    # attribute reads on a non-IOMetrics-annotated param must NOT count
    sneaky = """
def modeled_throughput(res, io: IOMetrics):
    return res.retries + io.reads
"""
    got = bill_lint.consumed_fields(sneaky, derived={})
    assert got == {"reads"}


# ---------------------------------------------------------------- jaxpr pass


def test_jaxpr_contract_constants_match_types():
    # 5 StoreState + 2 CreditState donated leaves (ver+stranded packed into
    # one meta word); 9 Results + 11 IOMetrics psums — derived from the live
    # dataclasses, so a new field moves both the contract and the audit
    # together
    assert jaxpr_check.expected_donation_pairs() == 7
    assert jaxpr_check.expected_psums() == 20


def test_jaxpr_audit_flags_injected_f64():
    def leaky(x):
        return x.astype("float64") * 2.0

    with jax.experimental.enable_x64():
        closed = jax.make_jaxpr(leaky)(jnp.ones((4,), jnp.float32))
        viols = jaxpr_check.audit_graph(closed, "toy")
    assert any("float64" in v.message for v in viols)


def test_jaxpr_audit_clean_on_allowed_dtypes():
    def fine(x):
        return (x * 2).astype(jnp.uint32)

    closed = jax.make_jaxpr(fine)(jnp.ones((4,), jnp.int32))
    assert jaxpr_check.audit_graph(closed, "toy") == []


def test_jaxpr_census_counts_injected_extra_psum():
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P

    if jax.device_count() < 2:
        pytest.skip("needs >=2 devices")
    mesh = Mesh(jax.devices()[:2], ("data",))

    def one_psum(x):
        return jax.lax.psum(x, "data")

    def two_psums(x):
        return jax.lax.psum(x, "data") + jax.lax.psum(x * 2, "data")

    arg = jnp.ones((2, 4), jnp.float32)
    for fn, want in ((one_psum, 1), (two_psums, 2)):
        sharded = shard_map(fn, mesh=mesh, in_specs=P("data"),
                            out_specs=P())
        census = jaxpr_check.collective_census(jax.make_jaxpr(sharded)(arg))
        assert census.get("psum", 0) == want
    # the contract comparison is exact: an extra collective is a mismatch
    assert {"psum": 2} != {"psum": 1}


def test_jaxpr_donation_detector():
    @jax.jit
    def f(a, b):
        return a + b

    args = (jnp.ones((8,), jnp.float32),) * 2
    plain = f.lower(*args).compile().as_text()
    assert jaxpr_check.donation_pairs(plain) == 0
    donated = jax.jit(lambda a, b: a + b, donate_argnums=(0,)).lower(
        *args).compile().as_text()
    assert jaxpr_check.donation_pairs(donated) == 1


def test_jaxpr_digest_is_stable_and_discriminating():
    def f(x):
        return x * 3 + 1

    a = jaxpr_check.jaxpr_digest(jax.make_jaxpr(f)(jnp.ones((4,), jnp.int32)))
    b = jaxpr_check.jaxpr_digest(jax.make_jaxpr(f)(jnp.ones((4,), jnp.int32)))
    c = jaxpr_check.jaxpr_digest(
        jax.make_jaxpr(f)(jnp.ones((5,), jnp.int32)))
    assert a == b != c


# ------------------------------------------------------------- race checker


def _clean(sc):
    viols, states = explore(sc)
    assert viols == [], [str(v) for v in viols]
    return states


def test_race_check_clean_on_real_machines_subset():
    u0, d0, i0 = (OpKind.UPDATE, 0), (OpKind.DELETE, 0), (OpKind.INSERT, 0)
    for mode in SyncMode:
        hot = (True, True) if mode == SyncMode.CIDER else (False, False)
        _clean(Scenario(mode, (u0, d0), (0,), hot))
        _clean(Scenario(mode, (i0, i0), (), hot))
        _clean(Scenario(mode, (u0, u0, d0), (0,), hot))
    # SCAN vs concurrent INSERT/DELETE replays exactly against the oracle
    _clean(Scenario(SyncMode.CIDER, ((OpKind.SCAN, 0), i0,
                                     (OpKind.DELETE, 1)), (1,),
                    (True, True)))


def test_race_check_detects_lost_delete_bug():
    sc = Scenario(SyncMode.CIDER, ((OpKind.UPDATE, 0), (OpKind.DELETE, 0)),
                  (0,), hot=(True, True),
                  flags=ModelFlags(combine_covers_deletes=True))
    viols, _ = explore(sc)
    assert any("0 committed events" in v.message and "DELETE" in v.message
               for v in viols), [str(v) for v in viols]


def test_race_check_detects_live_lock_break():
    for mode, needle in ((SyncMode.SPIN, "mutual exclusion"),
                         (SyncMode.MCS, "wait-queue rank")):
        sc = Scenario(mode, ((OpKind.UPDATE, 0), (OpKind.UPDATE, 0)), (0,),
                      flags=ModelFlags(repair_requires_dead_holder=False))
        viols, _ = explore(sc)
        msgs = [v.message for v in viols]
        assert any(needle in m for m in msgs), msgs
        assert any("LIVE lock" in m for m in msgs), msgs


def test_race_check_crash_repair_is_safe():
    # crash-at-any-step exploration: every recorded §4.6 repair names a
    # crashed owner, and survivors still serialize per the oracle
    for mode in (SyncMode.SPIN, SyncMode.MCS, SyncMode.CIDER):
        hot = (True, True) if mode == SyncMode.CIDER else (False, False)
        sc = Scenario(mode, ((OpKind.UPDATE, 0),) * 3, (0,), hot)
        viols, states = explore(sc, allow_crash=True)
        assert viols == [], [str(v) for v in viols]
        assert states > 100   # crash branching actually explored


def test_race_check_replicated_clean_on_real_machine():
    # the full quick replicated space (DESIGN.md §13 client-centric
    # replication, crash-at-any-step between primary CAS and fan-out)
    # is clean under the REAL flags, and the crash branching is explored
    n = states = 0
    for sc in repl_scenarios(quick=True):
        viols, s = explore_replicated(sc)
        assert viols == [], (sc.describe(), [str(v) for v in viols])
        n += 1
        states += s
    assert n >= 100 and states > 5_000


def test_race_check_replicated_crash_leaves_repairable_divergence():
    # a writer crashing between primary CAS and fan-out leaves the
    # replicas divergent; the REAL reader must resolve max-version and
    # roll the committed write forward — zero violations, and the crash
    # branch is genuinely in the explored space
    sc = ReplScenario(((OpKind.UPDATE, 0), (OpKind.SEARCH, 0)), (0,))
    viols, states = explore_replicated(sc, allow_crash=True)
    assert viols == [], [str(v) for v in viols]
    no_crash_states = explore_replicated(sc, allow_crash=False)[1]
    assert states > no_crash_states


def test_race_check_detects_stale_replica_read():
    # seeded bug: a read served from one arbitrary replica instead of
    # max-version resolution — caught twice (oracle replay divergence +
    # an explicit record naming the divergent replicas), and the
    # interleaving alone exposes it even with crashes disabled
    sc = ReplScenario(((OpKind.UPDATE, 0), (OpKind.SEARCH, 0)), (0,),
                      flags=ModelFlags(stale_replica_read=True))
    for allow_crash in (True, False):
        viols, _ = explore_replicated(sc, allow_crash=allow_crash)
        msgs = [v.message for v in viols]
        assert any("stale-replica read" in m and "replicas diverge" in m
                   for m in msgs), msgs
        assert any("oracle replay diverges" in m for m in msgs), msgs


def test_race_check_tick_conformance():
    # the shipped del_q gate on the REAL protocol.tick machine agrees with
    # the model: no combined batch over a queued DELETE, gate drains, and
    # the delete-free control still combines
    assert race_check._sim_conformance(None) == []
