"""Unit tests for the CI perf gate's pure check logic — synthetic dicts, no
benchmark runs: the modeled-mops floor/ordering checks and the new
wall-clock floors (gated on backend provenance, DESIGN.md §10)."""
from __future__ import annotations

from benchmarks.check_regression import check, check_wall

PROV = {"jax_backend": "cpu", "kernel_impl": "jnp", "kernel_interpret": False}


def _engine(mops, prov=PROV):
    out = {"config": {"provenance": dict(prov)}}
    for m, v in mops.items():
        out[m] = {"throughput_mops": v}
    return out


def _wall_baseline(mops, prov=PROV):
    return {"_wall_engine": {"provenance": dict(prov),
                             "throughput_mops": dict(mops)}}


FLOORS = {"OSYNC": 0.8, "SPIN": 0.5, "MCS": 0.5, "CIDER": 0.6}


def test_wall_passes_at_floor():
    assert check_wall(_engine(FLOORS), _wall_baseline(FLOORS), 0.5) == []


def test_wall_fails_on_injected_slowdown():
    slow = {m: v / 3 for m, v in FLOORS.items()}   # 3x slower than the floor
    fails = check_wall(_engine(slow), _wall_baseline(FLOORS), 0.5)
    assert len(fails) == 4
    assert all("wall/engine/" in f for f in fails)


def test_wall_tolerance_band():
    # 40% below the floor is inside the default 50% band; 60% is not
    near = {m: v * 0.6 for m, v in FLOORS.items()}
    far = {m: v * 0.4 for m, v in FLOORS.items()}
    assert check_wall(_engine(near), _wall_baseline(FLOORS), 0.5) == []
    assert len(check_wall(_engine(far), _wall_baseline(FLOORS), 0.5)) == 4


def test_wall_skipped_on_backend_mismatch(capsys):
    """A TPU-recorded floor must not gate (or pass) a CPU run — skip."""
    tpu = {"jax_backend": "tpu", "kernel_impl": "pallas",
           "kernel_interpret": False}
    slow = {m: v / 10 for m, v in FLOORS.items()}
    fails = check_wall(_engine(slow), _wall_baseline(FLOORS, prov=tpu), 0.5)
    assert fails == []
    assert "SKIPPED" in capsys.readouterr().out


def test_wall_missing_baseline_fails():
    fails = check_wall(_engine(FLOORS), {}, 0.5)
    assert len(fails) == 1 and "_wall_engine" in fails[0]


def test_modeled_check_still_gates():
    actual = {"engine": {"OSYNC": 1.0, "SPIN": 1.0, "MCS": 1.0,
                         "CIDER": 2.0}}
    baseline = {"engine": {"CIDER": 2.0}}
    assert check(actual, baseline, 0.10) == []
    # regression past tolerance
    worse = {"engine": {**actual["engine"], "CIDER": 1.5}}
    assert any("regressed" in f for f in check(worse, baseline, 0.10))
    # losing the ordering
    lost = {"engine": {**actual["engine"], "OSYNC": 2.5}}
    assert any("no longer leads" in f for f in check(lost, baseline, 0.10))
    # baselined benchmark vanishing from the JSONs is a failure, not a pass
    assert any("no matching benchmark" in f
               for f in check({}, baseline, 0.10))
    # underscore-prefixed keys (e.g. _wall_engine) are not benchmarks
    assert check(actual, {**baseline, "_wall_engine": {}}, 0.10) == []
