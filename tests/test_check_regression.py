"""Unit tests for the CI perf gate's pure check logic — synthetic dicts, no
benchmark runs: the modeled-mops floor/ordering checks, the wall-clock
floors (gated on backend provenance, DESIGN.md §10), the weak-scaling /
open-loop floors (``check_scale``), the replication-contract floors
(``check_replication``, DESIGN.md §13), and the markdown gate summary."""
from __future__ import annotations

import copy

from benchmarks.check_regression import (check, check_replication,
                                         check_scale, check_wall,
                                         summary_rows, write_summary)

PROV = {"jax_backend": "cpu", "kernel_impl": "jnp", "kernel_interpret": False}


def _engine(mops, prov=PROV):
    out = {"config": {"provenance": dict(prov)}}
    for m, v in mops.items():
        out[m] = {"throughput_mops": v}
    return out


def _wall_baseline(mops, prov=PROV):
    return {"_wall_engine": {"provenance": dict(prov),
                             "throughput_mops": dict(mops)}}


FLOORS = {"OSYNC": 0.8, "SPIN": 0.5, "MCS": 0.5, "CIDER": 0.6}


def test_wall_passes_at_floor():
    assert check_wall(_engine(FLOORS), _wall_baseline(FLOORS), 0.5) == []


def test_wall_fails_on_injected_slowdown():
    slow = {m: v / 3 for m, v in FLOORS.items()}   # 3x slower than the floor
    fails = check_wall(_engine(slow), _wall_baseline(FLOORS), 0.5)
    assert len(fails) == 4
    assert all("wall/engine/" in f for f in fails)


def test_wall_tolerance_band():
    # 40% below the floor is inside the default 50% band; 60% is not
    near = {m: v * 0.6 for m, v in FLOORS.items()}
    far = {m: v * 0.4 for m, v in FLOORS.items()}
    assert check_wall(_engine(near), _wall_baseline(FLOORS), 0.5) == []
    assert len(check_wall(_engine(far), _wall_baseline(FLOORS), 0.5)) == 4


def test_wall_skipped_on_backend_mismatch(capsys):
    """A TPU-recorded floor must not gate (or pass) a CPU run — skip."""
    tpu = {"jax_backend": "tpu", "kernel_impl": "pallas",
           "kernel_interpret": False}
    slow = {m: v / 10 for m, v in FLOORS.items()}
    fails = check_wall(_engine(slow), _wall_baseline(FLOORS, prov=tpu), 0.5)
    assert fails == []
    assert "SKIPPED" in capsys.readouterr().out


def test_wall_missing_baseline_fails():
    fails = check_wall(_engine(FLOORS), {}, 0.5)
    assert len(fails) == 1 and "_wall_engine" in fails[0]


def _scale_json(eff_cider=0.8, mops=None, p99=None):
    """Minimal BENCH_scale-shaped dict.  Defaults: CIDER leads everywhere."""
    mops = mops or {"OSYNC": 1.0, "SPIN": 0.8, "MCS": 1.2, "CIDER": 2.0}
    p99 = p99 or {"OSYNC": 140.0, "SPIN": 170.0, "MCS": 200.0, "CIDER": 105.0}
    return {
        "config": {"gated_meshes": [1, 4]},
        "efficiency": {"CIDER": {"1": 1.0, "4": eff_cider}},
        "weak_scaling": {
            "1": {m: {"modeled_mops": v} for m, v in mops.items()},
            "4": {m: {"modeled_mops": v * 3} for m, v in mops.items()},
        },
        "open_loop": {"curves": {
            m: [{"rho": 0.7, "p99_us": v / 2}, {"rho": 1.05, "p99_us": v}]
            for m, v in p99.items()}},
    }


def _scale_baseline(floors=None):
    return {"_scale": {"gated_meshes": [1, 4],
                       "efficiency_CIDER": floors or {"1": 1.0, "4": 0.8}}}


def test_scale_passes_at_floor():
    assert check_scale(_scale_json(), _scale_baseline(), 0.10) == []


def test_scale_fails_on_injected_efficiency_collapse():
    """The acceptance check: an injected weak-scaling efficiency collapse
    (hot-shard serialization regression) must fail the gate."""
    fails = check_scale(_scale_json(eff_cider=0.3), _scale_baseline(), 0.10)
    assert len(fails) == 1 and "efficiency" in fails[0] and "mesh4" in fails[0]
    # just inside the tolerance band passes
    assert check_scale(_scale_json(eff_cider=0.73), _scale_baseline(),
                       0.10) == []


def test_scale_missing_gated_mesh_fails():
    """Dropping a baselined mesh from the JSON is a gate bypass, not a pass."""
    shrunk = _scale_json()
    del shrunk["efficiency"]["CIDER"]["4"]
    fails = check_scale(shrunk, _scale_baseline(), 0.10)
    assert len(fails) == 1 and "missing" in fails[0]


def test_scale_fails_on_lost_mops_lead():
    slow = _scale_json(mops={"OSYNC": 1.0, "SPIN": 0.8, "MCS": 2.5,
                             "CIDER": 2.0})
    fails = check_scale(slow, _scale_baseline(), 0.10)
    assert len(fails) == 2          # both meshes report MCS ahead
    assert all("no longer leads MCS" in f for f in fails)
    # ties pass (read-heavy cells bill identically under every mode)
    tie = _scale_json(mops={"OSYNC": 2.0, "SPIN": 0.8, "MCS": 1.2,
                            "CIDER": 2.0})
    assert check_scale(tie, _scale_baseline(), 0.10) == []


def test_scale_fails_on_lost_open_loop_tail_lead():
    """Only the TOP offered load is gated — losing p99 at rho 1.05 fails,
    a mid-curve wobble does not."""
    slow = _scale_json(p99={"OSYNC": 100.0, "SPIN": 170.0, "MCS": 200.0,
                            "CIDER": 105.0})
    fails = check_scale(slow, _scale_baseline(), 0.10)
    assert len(fails) == 1 and "p99 tail lead" in fails[0]
    wobble = _scale_json()
    wobble["open_loop"]["curves"]["CIDER"][0]["p99_us"] = 999.0
    assert check_scale(wobble, _scale_baseline(), 0.10) == []


def test_scale_missing_baseline_block_fails():
    fails = check_scale(_scale_json(), {}, 0.10)
    assert len(fails) == 1 and "_scale" in fails[0]


def _repl_cell(reads=100, writes=10, cas=8, faa=2, retries=4, repair_cas=1,
               mn_bytes=1000, r=1, **over):
    """A consistent replication cell: write verbs xR, reads x1, bytes
    ro + R*wr with ro=600, wr=400 at R=1."""
    d = {"reads": reads, "writes": writes * r, "cas": cas * r, "faa": faa * r,
         "cn_msgs": 5, "mn_bytes": 600 + r * (mn_bytes - 600), "retries":
         retries * r, "combined": 3, "executed": writes, "repair_cas":
         repair_cas * r, "orphan_windows": 0, "mn_iops": 0,
         "modeled_mops": 10.0 / r, "modeled_p50_us": 50.0,
         "modeled_p99_us": 120.0}
    d["mn_iops"] = d["reads"] + d["writes"] + d["cas"] + d["faa"]
    d.update(over)
    return d


def _repl_json():
    out = {"config": {"fast": True}, "replicas": {}, "mn_crash": {"modes": {
        m: {"modeled_mops": 6.0, "asserted_equal": True} for m in
        ("OSYNC", "SPIN", "MCS", "CIDER")}}}
    for r in (1, 2, 3):
        cells = {m: _repl_cell(r=r) for m in ("OSYNC", "SPIN", "MCS",
                                              "CIDER")}
        out["replicas"][str(r)] = {"single": cells,
                                   "sharded4": copy.deepcopy(cells)}
    return out


def _repl_engine():
    return {"config": {"fast": True},
            **{m: _repl_cell() for m in ("OSYNC", "SPIN", "MCS", "CIDER")}}


def test_replication_passes_when_consistent():
    assert check_replication(_repl_json(), _repl_engine()) == []


def test_replication_fails_on_injected_cas_cost_omission():
    """The acceptance check: an engine change that forgets the replicated
    CAS fan-out (R>1 cells billing the R=1 CAS cost) must fail the gate."""
    bad = _repl_json()
    for m in bad["replicas"]["2"]["single"]:
        bad["replicas"]["2"]["single"][m]["cas"] //= 2   # drop back to R=1
    fails = check_replication(bad, _repl_engine())
    assert len(fails) == 4
    assert all("'cas'" in f and "x2 fan-out" in f for f in fails)


def test_replication_fails_on_r1_drift():
    """R=1 must reproduce the engine benchmark to the digit — any drift
    means the replica axis is no longer a byte-identical no-op."""
    bad = _repl_json()
    bad["replicas"]["1"]["single"]["CIDER"]["mn_iops"] += 1
    fails = check_replication(bad, _repl_engine())
    assert any("byte-identical no-op" in f and "mn_iops" in f for f in fails)


def test_replication_fails_on_read_fanout():
    """Reads bill to ONE replica; xR reads would double-charge the model."""
    bad = _repl_json()
    bad["replicas"]["3"]["single"]["MCS"]["reads"] *= 3
    fails = check_replication(bad, _repl_engine())
    assert any("'reads'" in f and "one replica" in f for f in fails)


def test_replication_fails_on_missing_failover_witness():
    bad = _repl_json()
    bad["mn_crash"]["modes"]["SPIN"]["asserted_equal"] = False
    fails = check_replication(bad, _repl_engine())
    assert len(fails) == 1 and "bit-equality witness" in fails[0]


def test_replication_fails_on_size_mismatch():
    """A fast replication JSON cannot be R=1-matched against a full-size
    engine JSON — that must fail loudly, not diff garbage."""
    eng = _repl_engine()
    eng["config"]["fast"] = False
    fails = check_replication(_repl_json(), eng)
    assert len(fails) == 1 and "size mismatch" in fails[0]


def test_summary_rows_include_replication_gates(tmp_path, monkeypatch):
    actual = {"engine": {"OSYNC": 1.0, "SPIN": 1.0, "MCS": 1.0, "CIDER": 2.0}}
    baseline = {"engine": {"CIDER": 2.0}}
    recovery = {"scenarios": {}}
    rows = summary_rows(actual, baseline, _repl_engine(), _scale_json(),
                        recovery, 0.10, 0.50, replication=_repl_json())
    by = {(r[0], r[1]): r[4] for r in rows}
    assert by[("replication/R1", "bit-identity vs engine")] == "PASS"
    assert by[("replication/R2", "xR write conservation")] == "PASS"
    assert by[("replication/R3", "xR write conservation")] == "PASS"
    assert by[("replication/mn_crash", "failover bit-equality")] == "PASS"
    bad = _repl_json()
    bad["replicas"]["2"]["single"]["CIDER"]["cas"] //= 2
    rows = summary_rows(actual, baseline, _repl_engine(), _scale_json(),
                        recovery, 0.10, 0.50, replication=bad)
    by = {(r[0], r[1]): r[4] for r in rows}
    assert by[("replication/R2", "xR write conservation")] == "FAIL"
    assert by[("replication/R3", "xR write conservation")] == "PASS"


def test_summary_rows_and_markdown_table(tmp_path, monkeypatch):
    """summary_rows restates every gate as a (check, metric, floor, actual,
    status) row and write_summary renders them to $GITHUB_STEP_SUMMARY with
    ::error annotations for the failures."""
    actual = {"engine": {"OSYNC": 1.0, "SPIN": 1.0, "MCS": 1.0, "CIDER": 2.0}}
    baseline = {"engine": {"CIDER": 2.0}, **_wall_baseline(FLOORS),
                **_scale_baseline()}
    recovery = {"scenarios": {"crash": {"modes": {
        "CIDER": {"repair_cas": 1, "p99_post_crash_us": 50.0},
        "MCS": {"repair_cas": 9, "p99_post_crash_us": 90.0},
        "SPIN": {"repair_cas": 7, "p99_post_crash_us": 80.0}}}}}
    # wall provenance mismatch -> those rows must read SKIP, not PASS/FAIL
    tpu_engine = _engine(FLOORS, prov={**PROV, "jax_backend": "tpu"})
    rows = summary_rows(actual, baseline, tpu_engine, _scale_json(),
                        recovery, 0.10, 0.50)
    by = {(r[0], r[1]): r[4] for r in rows}
    assert by[("engine", "CIDER modeled_mops")] == "PASS"
    assert by[("engine", "CIDER lead")] == "PASS"
    assert by[("wall/engine/CIDER", "throughput_mops")] == "SKIP"
    assert by[("recovery/crash", "CIDER repair_cas")] == "PASS"
    assert by[("scale/mesh4", "CIDER weak-scaling efficiency")] == "PASS"
    assert by[("scale/open_loop", "CIDER p99 @ top load")] == "PASS"

    out = tmp_path / "summary.md"
    monkeypatch.setenv("GITHUB_STEP_SUMMARY", str(out))
    write_summary(rows, ["engine: CIDER modeled_mops regressed 25.0%"])
    md = out.read_text()
    assert "## Perf regression gate: FAIL" in md
    assert "| check | metric | floor | actual | status |" in md
    assert md.count("|") >= 6 * (len(rows) + 2)
    assert "⏭️ SKIP" in md


def test_write_summary_error_annotations(capsys, monkeypatch):
    monkeypatch.delenv("GITHUB_STEP_SUMMARY", raising=False)
    write_summary([("engine", "CIDER modeled_mops", 2.0, 1.5, "FAIL")],
                  ["engine: regressed"])
    out = capsys.readouterr().out
    assert "::error title=perf regression gate::engine: regressed" in out
    assert "## Perf regression gate: FAIL" in out


def test_modeled_check_still_gates():
    actual = {"engine": {"OSYNC": 1.0, "SPIN": 1.0, "MCS": 1.0,
                         "CIDER": 2.0}}
    baseline = {"engine": {"CIDER": 2.0}}
    assert check(actual, baseline, 0.10) == []
    # regression past tolerance
    worse = {"engine": {**actual["engine"], "CIDER": 1.5}}
    assert any("regressed" in f for f in check(worse, baseline, 0.10))
    # losing the ordering
    lost = {"engine": {**actual["engine"], "OSYNC": 2.5}}
    assert any("no longer leads" in f for f in check(lost, baseline, 0.10))
    # baselined benchmark vanishing from the JSONs is a failure, not a pass
    assert any("no matching benchmark" in f
               for f in check({}, baseline, 0.10))
    # underscore-prefixed keys (e.g. _wall_engine) are not benchmarks
    assert check(actual, {**baseline, "_wall_engine": {}}, 0.10) == []
