PYTHONPATH := src
export PYTHONPATH

.PHONY: test test-fast coverage bench-smoke bench-kernels-smoke \
    bench-ycsb-smoke bench-scenarios-smoke bench-recovery-smoke \
    bench-scale-smoke bench-replication-smoke \
    check-regression lint docs-check analyze typecheck

# tier-1 verify (ROADMAP.md)
test:
	python -m pytest -x -q

# tier-1 suite under line coverage of src/repro with the committed floor
# (COV_FLOOR, also recorded in README's gate list); writes the htmlcov/
# report CI uploads as an artifact.  Falls back to the plain suite on
# machines without pytest-cov so `make coverage` never blocks local work.
COV_FLOOR := 70
coverage:
	@if python -c "import pytest_cov" 2>/dev/null; then \
	    python -m pytest -x -q --cov=src/repro --cov-branch \
	        --cov-report=term-missing:skip-covered --cov-report=html \
	        --cov-fail-under=$(COV_FLOOR); \
	else \
	    echo "pytest-cov not installed; running the plain suite"; \
	    python -m pytest -x -q; \
	fi

# quick signal: engine + runner + dist + stores + workloads + the Pallas
# wc_combine kernel that mirrors the engine's combine contract
test-fast:
	python -m pytest -x -q tests/test_engine.py tests/test_runner.py \
	    tests/test_dist.py tests/test_dist_store.py tests/test_stores.py \
	    tests/test_workloads.py tests/test_dynamic.py tests/test_kernels.py \
	    tests/test_recovery.py tests/test_ft.py tests/test_scan.py \
	    tests/test_ycsb_suite.py

# tiny engine benchmark on the fused runner -> BENCH_engine.fast.json
# (the committed full-size baseline BENCH_engine.json is regenerated with
#  `python -m benchmarks.run --only engine_json`, no --fast)
bench-smoke:
	python -m benchmarks.run --only engine_json --fast

# kernel-dispatch seam smoke (DESIGN.md §10): the fast engine benchmark on
# BOTH kernel backends (jnp reference + forced Pallas, interpret off-TPU),
# asserting the verb bills and Results bit-equal -> BENCH_kernels.fast.json
bench-kernels-smoke:
	python -m benchmarks.run --only kernels_json

# YCSB core suite (A-F) x SyncMode x {single, 4-way} -> BENCH_ycsb.fast.json,
# including the sharded-scan bill-equality assertion (committed full-size
# baseline: `python -m benchmarks.run --only ycsb_json`, no --fast)
bench-ycsb-smoke:
	python -m benchmarks.run --only ycsb_json --fast

# dynamic-contention scenario matrix -> BENCH_scenarios.fast.json
# (committed full-size baseline: `python -m benchmarks.scenarios`, no --fast)
bench-scenarios-smoke:
	python -m benchmarks.scenarios --fast

# crash-recovery scenario matrix -> BENCH_recovery.fast.json, including the
# 4-way failover-bill-equality assertion (committed full-size baseline:
# `python -m benchmarks.recovery`, no --fast)
bench-recovery-smoke:
	python -m benchmarks.recovery --fast

# replication matrix R in {1,2,3} x SyncMode x {single, sharded4} + the
# MN-crash failover cell -> BENCH_replication.fast.json, including the
# sharded-bill, xR-conservation, and failover bit-equality assertions
# (committed full-size baseline: `python -m benchmarks.replication`, no --fast)
bench-replication-smoke:
	python -m benchmarks.replication --fast

# weak-scaling meshes {1,2,4} + open-loop arrival sweep -> BENCH_scale.fast.json,
# including the dense-repack and sharded-vs-single bit-identity assertions
# (committed full-size baseline: `python -m benchmarks.scale`, no --fast,
#  which scales to the 16-way mesh and a 2M-key store)
bench-scale-smoke:
	python -m benchmarks.scale --fast

# perf-regression gate over the fast JSONs (CI fails on >10% CIDER
# modeled-mops drop, on CIDER losing the paper's mode ordering, on CIDER
# losing its recovery-overhead lead, or on a same-backend wall-clock
# collapse past the _wall_engine floors); depends on the smoke targets —
# including the kernel bit-identity smoke — so it never gates against
# stale JSONs
check-regression: bench-smoke bench-kernels-smoke bench-ycsb-smoke \
    bench-scenarios-smoke bench-recovery-smoke bench-scale-smoke \
    bench-replication-smoke
	python -m benchmarks.check_regression

# docs gate: markdown link check over README/DESIGN/docs/ + every
# `DESIGN.md §N` reference cited in source docstrings must exist
docs-check:
	python tools/check_docs.py

# static-analysis gate (DESIGN.md §11): jaxpr/HLO invariant audit (donation,
# dtype discipline, exact collective census vs the credit-plane contract,
# compile-cache stability), verb-bill conservation lint (every IOMetrics
# field documented + priced or whitelisted), and the exhaustive protocol
# race-checker (every interleaving of the 2-3 client model vs the oracle,
# crash-at-any-step included) -> ANALYZE_REPORT.json
analyze:
	python tools/analyze.py

# mypy over the layers with the strictest internal contracts (core + dist);
# same graceful fallback pattern as `lint` for machines without mypy
typecheck:
	@command -v mypy >/dev/null 2>&1 \
	    && mypy --config-file mypy.ini src/repro/core src/repro/dist \
	    || { echo "mypy not installed; falling back to compileall"; \
	         python -m compileall -q src/repro/core src/repro/dist; }

lint:
	@command -v ruff >/dev/null 2>&1 \
	    && ruff check src tests benchmarks \
	    || { echo "ruff not installed; falling back to compileall"; \
	         python -m compileall -q src tests benchmarks; }
