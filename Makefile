PYTHONPATH := src
export PYTHONPATH

.PHONY: test test-fast bench-smoke lint

# tier-1 verify (ROADMAP.md)
test:
	python -m pytest -x -q

# quick signal: engine + runner + dist + stores + workloads only
test-fast:
	python -m pytest -x -q tests/test_engine.py tests/test_runner.py \
	    tests/test_dist.py tests/test_dist_store.py tests/test_stores.py \
	    tests/test_workloads.py

# tiny engine benchmark on the fused runner -> BENCH_engine.fast.json
# (the committed full-size baseline BENCH_engine.json is regenerated with
#  `python -m benchmarks.run --only engine_json`, no --fast)
bench-smoke:
	python -m benchmarks.run --only engine_json --fast

lint:
	@command -v ruff >/dev/null 2>&1 \
	    && ruff check src tests benchmarks \
	    || { echo "ruff not installed; falling back to compileall"; \
	         python -m compileall -q src tests benchmarks; }
