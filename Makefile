PYTHONPATH := src
export PYTHONPATH

.PHONY: test test-fast bench-smoke lint

# tier-1 verify (ROADMAP.md)
test:
	python -m pytest -x -q

# quick signal: engine + dist + stores + workloads only
test-fast:
	python -m pytest -x -q tests/test_engine.py tests/test_dist.py \
	    tests/test_dist_store.py tests/test_stores.py tests/test_workloads.py

# tiny engine benchmark -> BENCH_engine.json (perf trajectory file)
bench-smoke:
	python -m benchmarks.run --only engine_json --fast

lint:
	@command -v ruff >/dev/null 2>&1 \
	    && ruff check src tests benchmarks \
	    || { echo "ruff not installed; falling back to compileall"; \
	         python -m compileall -q src tests benchmarks; }
